"""End-to-end serving driver: batched requests, by_blocks chunked prefill,
find_first early-exit decode — then the same requests through the
continuous-batching engine.

    PYTHONPATH=src python examples/serve_early_exit.py

Serves a small randomly-initialized model (structure, not quality, is the
point): requests of mixed lengths are admitted under the ``cap`` adaptor,
prompts prefill in geometric chunks, decoding stops at EOS with the wasted
work measured against the paper's bound.  The continuous engine replays
the same workload with per-slot retirement and interleaved prefill
(src/repro/serve/DESIGN.md).
"""

import numpy as np

import jax

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.serve.engine import (ContinuousEngine, Engine, EngineConfig,
                                Request)

cfg = ModelConfig(name="serve-demo", family="dense", num_layers=4,
                  d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
                  d_ff=1024, vocab_size=4096, loss_chunk=1024,
                  # fp32 so batched == continuous == one-at-a-time exactly
                  # (bf16 rounds differently across batch paddings)
                  param_dtype="float32", compute_dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"[serve] model: {cfg.param_count()/1e6:.1f}M params")

engine = Engine(model, params, EngineConfig(max_batch=4, eos_id=11,
                                            max_seq=512))
rng = np.random.RandomState(0)
for rid in range(10):
    plen = int(rng.randint(8, 64))
    engine.submit(Request(rid=rid,
                          prompt=rng.randint(3, cfg.vocab_size,
                                             plen).astype(np.int32),
                          max_new=48))

finished = []
round_no = 0
while True:
    batch = engine.step()
    if not batch:
        break
    round_no += 1
    for r in batch:
        finished.append(r)
        print(f"[serve] round {round_no} req {r.rid}: "
              f"{len(r.result)} tokens "
              f"(decode blocks={r.stats.blocks}, "
              f"wasted={r.stats.wasted_fraction:.1%})")

assert len(finished) == 10
print(f"[serve] served {len(finished)} requests in {round_no} rounds — OK")

# --- the same workload, continuously batched --------------------------------
cont = ContinuousEngine(model, params,
                        EngineConfig(max_batch=4, eos_id=11, max_seq=512,
                                     decode_tick=8, prefill_block_budget=2))
rng = np.random.RandomState(0)
for rid in range(10):
    plen = int(rng.randint(8, 64))
    cont.submit(Request(rid=rid,
                        prompt=rng.randint(3, cfg.vocab_size,
                                           plen).astype(np.int32),
                        max_new=48))
served = {}
while cont.pending:
    for r in cont.step():
        served[r.rid] = r
        print(f"[serve] continuous req {r.rid}: {len(r.result)} tokens "
              f"(ticks={r.stats.blocks}, wasted={r.stats.wasted_tokens})")
assert len(served) == 10
for r in finished:                       # same tokens as the batch engine
    assert np.array_equal(r.result, served[r.rid].result), r.rid
snap = cont.telemetry.snapshot()
print(f"[serve] continuous: {snap['ticks']} ticks, "
      f"{snap['prefill_preemptions']} prefill preemptions, "
      f"cap peak {snap['cap_live_peak']}, results identical — OK")
