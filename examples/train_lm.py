"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 30
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

``100m`` is the assignment's ~100M-parameter configuration (12L, d=768,
GQA 12/4, 32k vocab — GPT-2-small-class); ``tiny`` finishes in seconds on
CPU for CI.  Features exercised: Kvik microbatch plan, atomic+async
checkpoints, preemption-safe exit (Ctrl-C), resume (rerun the same command),
straggler telemetry.
"""

import argparse

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import microbatch_plan

PRESETS = {
    "tiny": ModelConfig(name="tiny-lm", family="dense", num_layers=2,
                        d_model=128, num_heads=4, num_kv_heads=2,
                        head_dim=32, d_ff=512, vocab_size=2048,
                        loss_chunk=512),
    "100m": ModelConfig(name="lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4,
                        head_dim=64, d_ff=3072, vocab_size=32768,
                        loss_chunk=1024),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = Model(cfg)
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(10, args.steps // 20),
                          decay_steps=args.steps)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=0)
    n_mb = microbatch_plan(args.global_batch, dp=1,
                           tokens_per_seq=args.seq_len,
                           target_tokens_per_replica=args.global_batch
                           * args.seq_len // 2)
    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=max(10, args.steps // 4),
                          ckpt_dir=args.ckpt_dir, log_every=5,
                          num_microbatches=n_mb)
    trainer = Trainer(model, opt_cfg, data_cfg, loop_cfg)
    trainer.install_signal_handlers()
    state = trainer.run()
    losses = [m["loss"] for m in trainer.metrics_log]
    if len(losses) >= 2:
        print(f"[train_lm] loss {losses[0]:.3f} → {losses[-1]:.3f} "
              f"({'improved' if losses[-1] < losses[0] else 'check config'})")


if __name__ == "__main__":
    main()
