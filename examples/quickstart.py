"""Quickstart: Kvik's composable scheduling policies in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's API surface: a Divisible, adaptors nested over it, three
schedulers executing the same work, and the policy driving a real JAX
computation (microbatched gradient accumulation).
"""

import jax
import jax.numpy as jnp

from repro.core import (AdaptivePolicy, BatchWork, ByBlocksPolicy, CostModel,
                        DepJoinPolicy, JoinPolicy, Runtime, WorkRange,
                        bound_depth, build_plan, by_blocks, demand_split,
                        even_levels, simulate, thief_splitting, wrap_iter)

# --- 1. a Divisible + nested adaptors (paper §3.1/§3.3) --------------------
work = thief_splitting(bound_depth(BatchWork(0, 256), 5), p=16)
plan = build_plan(work)
print("plan:", plan.describe())

# --- 2. the same computation under three schedulers ------------------------
total = wrap_iter(thief_splitting(WorkRange(0, 10_000), p=8)).map_reduce(
    lambda leaf: sum(leaf.indices()), lambda a, b: a + b)
print("wrap_iter map-reduce:", total, "== ", sum(range(10_000)))

adaptive_plan = demand_split(WorkRange(0, 10_000), demand=6)
print("adaptive (demand=6):", adaptive_plan.describe())

bb = by_blocks(first=16)
_, stats = bb.run(WorkRange(0, 10_000),
                  lambda blk, c: c or blk.start > 500, False,
                  should_stop=lambda c: c)
print("by_blocks early stop:", stats)

# --- 3. simulating a policy (paper §4) --------------------------------------
# One discrete-event engine (Runtime), one ~50-line policy object per
# scheduler.  The policy is a value: swap it, wrap work in adaptors, or
# compose policies — same engine, comparable numbers.
cost = CostModel(per_item=1.0)
res = simulate(WorkRange(0, 99_999), AdaptivePolicy(), 8, cost, seed=0)
print(f"adaptive sim: tasks={res.tasks_created} = steals+1="
      f"{res.steals_successful + 1}, speedup={res.speedup_vs_serial:.2f}")

# join vs depjoin is one hook's difference (who runs the reduction)
dep = simulate(thief_splitting(WorkRange(0, 50_000), p=8), DepJoinPolicy(),
               8, CostModel(per_item=1.0, reduce_cost=10.0), seed=0)
print(f"depjoin sim: reductions={dep.reductions} == divisions="
      f"{dep.divisions}")

# compositions the old per-scheduler engines could not express: an
# interruptible by_blocks outer loop whose blocks run under the *adaptive*
# policy, stopping as soon as an item-level predicate fires
found = simulate(WorkRange(0, 99_999),
                 ByBlocksPolicy(inner=AdaptivePolicy(), first=8), 8, cost,
                 stop_predicate=lambda i: i if i == 777 else None)
print(f"by_blocks(adaptive) early exit: items={found.items_processed} "
      f"wasted={found.wasted_items} of {found.items_total}")

# --- 4. the paper's showcase: the stable sort, merge tree killed ------------
# New default (PR 6): for bounded keys (num_key_bits ≤ 16) argsort runs a
# MULTI-TILE LSD radix — per digit pass: per-tile stable rank + histogram,
# a one-launch carry scan of the (num_tiles × R) histogram matrix
# (kernels/tile_scan.py), and a global scatter.  3·ceil(num_key_bits/4)
# launches, INDEPENDENT of n (SortSchedule(mode="multi_tile")).  The PR 2/4
# level-batched merge tree — one launch per merge level, log2(n/tile) of
# them, radix tile phase with fused pack/unpack — remains the wide-key
# fallback and is selectable with strategy="merge"; both are stable, so
# their outputs are bit-identical.
import numpy as np
from repro.kernels.merge_sort import argsort, trace_launches

keys = np.random.RandomState(0).randint(0, 16, 4096).astype(np.int32)
with trace_launches() as tr:
    order = argsort(jnp.asarray(keys), tile=512, interpret=True)
assert (np.asarray(order) == np.argsort(keys, kind="stable")).all()
with trace_launches() as tr_mt_big:
    argsort(jnp.asarray(np.tile(keys, 16)), tile=512, interpret=True)
with trace_launches() as tr_merge:
    order_m = argsort(jnp.asarray(keys), tile=512, interpret=True,
                      strategy="merge")
assert (np.asarray(order_m) == np.asarray(order)).all()
print(f"multi-tile radix argsort: n=4096 -> {len(tr)} launches, "
      f"n=65536 -> {len(tr_mt_big)} (independent of n; merge tree takes "
      f"{len(tr_merge)} and grows log2(n/tile)), stable order ok")

# --- 5. the policy driving a JAX training computation ----------------------
# The same plan machinery decides distribution: microbatch counts come from
# a thief_splitting plan, the pipeline tick order is a division tree's leaf
# walk, and every sharding decision is one row of the repro.dist rule table.
from repro.train.step import TrainState, make_train_step, microbatch_plan

from repro.configs.registry import get_config, get_smoke_config
from repro.dist.pipeline import bubble_fraction, schedule_ticks
from repro.dist.sharding import param_pspec
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, init_state

cfg = get_smoke_config("llama3-8b")
model = Model(cfg)
opt = AdamWConfig(warmup_steps=1)
n_mb = microbatch_plan(global_batch=8, dp=1, tokens_per_seq=32,
                       target_tokens_per_replica=64)
print(f"microbatch plan from thief_splitting: {n_mb} microbatches")
step = jax.jit(make_train_step(model, opt, num_microbatches=n_mb))
params = model.init(jax.random.PRNGKey(0))
state = TrainState(params=params, opt=init_state(opt, params))
batch = {"tokens": jnp.ones((8, 32), jnp.int32),
         "labels": jnp.ones((8, 32), jnp.int32)}
state, metrics = step(state, batch)
print("train step:", {k: float(v) for k, v in metrics.items()})

# the sharding rule table: pure (config, path, rank) → PartitionSpec rows
full = get_config("jamba-1.5-large-398b")
print("param_pspec rules:",
      "ffn/gate →", param_pspec(full, "stage/0/ffn/gate", 3), "|",
      "moe/gate →", param_pspec(full, "stage/1/moe/gate", 4))

# the pipeline schedule is a plan artifact too: its microbatch order is the
# division tree's left-to-right leaf walk (repro.dist.pipeline)
ticks = schedule_ticks(4, 8)
print(f"pipeline fill-drain, 4 stages x 8 microbatches: {len(ticks)} ticks, "
      f"bubble = {bubble_fraction(4, 8):.1%}")
print("  tick 3:", " ".join(ticks[3]))

# --- 6. surviving failures -------------------------------------------------
# Faults are scheduling events.  A FaultPlan is pure data; injected into the
# same virtual-time Runtime, a worker death orphans its queue back into the
# steal pool and the run is bit-replayable from (plan, seed).  Static
# partitioning fails over whole chunks; adaptive re-spreads via steals.
from repro.core import AdaptivePolicy as _AP, FaultPlan, WorkerDeath
from repro.core import StaticPartitionPolicy as _SP

plan = FaultPlan(deaths=(WorkerDeath(0, 12_500.0),))
dead_static = simulate(WorkRange(0, 200_000), _SP(), 8,
                       CostModel(per_item=1.0), seed=0, faults=plan)
dead_adapt = simulate(WorkRange(0, 200_000), _AP(preempt=True), 8,
                      CostModel(per_item=1.0), seed=0, faults=plan)
assert dead_static.items_processed == dead_adapt.items_processed == 200_000
print(f"worker death at t=12500: static failover {dead_static.makespan:.0f} "
      f"(lost {dead_static.lost_items}), adaptive re-spread "
      f"{dead_adapt.makespan:.0f} (lost {dead_adapt.lost_items}) -> "
      f"{dead_static.makespan / dead_adapt.makespan:.2f}x faster recovery")

# wall-clock faults: checkpoints are atomic, hashed per leaf, and fail
# loudly when the bytes on disk are not the bytes that were saved
import tempfile
from repro.chaos import corrupt_checkpoint
from repro.train.checkpoint import CheckpointManager

with tempfile.TemporaryDirectory() as ckdir:
    mgr = CheckpointManager(ckdir)
    mgr.save(1, state, blocking=True)
    corrupt_checkpoint(ckdir, 1, target="leaf", leaf_index=0)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    try:
        mgr.restore(abstract)
        raise AssertionError("corruption went undetected")
    except ValueError as e:
        print(f"corrupted checkpoint rejected: {str(e)[:60]}...")

# elastic recovery: lose a host, re-mesh over the survivors, restore
# reshards through host memory (tests/test_chaos.py runs this end to end)
from repro.train.elastic import choose_mesh

devs = (jax.devices() * 8)[:8]          # pretend 2 hosts x 4 devices
before = choose_mesh(8, prefer_model=4, devices=devs)
after = choose_mesh(4, prefer_model=4, devices=devs[:4])   # host 1 died
print(f"elastic re-mesh: {dict(before.shape)} -> {dict(after.shape)} "
      f"over the surviving host")

# --- 7. continuous-batching serving -----------------------------------------
# The serving loop is the scheduling policies under real traffic: a
# persistent decode batch (slots retire at their own EOS / max_new and are
# backfilled), chunked prefill interleaved between decode ticks at the
# by_blocks preemption point, and admission = the cap adaptor driven by
# live telemetry (measured decode cost, page headroom).  Mixed-length
# batches decode exactly the tokens each request would get alone —
# src/repro/serve/DESIGN.md has the invariants.
import numpy as np
from repro.serve import ContinuousEngine, EngineConfig, Request

scfg = EngineConfig(max_batch=2, eos_id=7, max_seq=128, decode_tick=4,
                    prefill_block_budget=2)
serve_model = Model(cfg)                 # reuse the tiny §5 config
serve_params = serve_model.init(jax.random.PRNGKey(1))
engine = ContinuousEngine(serve_model, serve_params, scfg)
rng = np.random.RandomState(0)
for rid, (plen, mnew) in enumerate([(9, 6), (33, 4), (17, 8)]):
    engine.submit(Request(rid=rid, max_new=mnew, prompt=rng.randint(
        3, cfg.vocab_size, size=plen).astype(np.int32)))
served = {}
while engine.pending:
    for r in engine.step():
        served[r.rid] = r
snap = engine.telemetry.snapshot()
print(f"continuous batching: served {len(served)} mixed-length requests in "
      f"{snap['ticks']} decode ticks ({snap['admissions']} admissions, "
      f"{snap['prefill_preemptions']} prefill preemptions, "
      f"cap peak {snap['cap_live_peak']})")
for rid in sorted(served):
    print(f"  req {rid}: {len(served[rid].result)} tokens, "
          f"wasted={served[rid].stats.wasted_tokens}")

# --- 8. two tenants under overload: SLO classes + shedding ------------------
# Requests carry an SLO class, priority, deadline and tenant label.  A
# ServePolicy orders the waiting queue (in-flight work is never touched,
# so tokens stay exact — src/repro/serve/DESIGN.md "SLO classes");
# class_caps reserve lanes for interactive arrivals; queue entries past
# their deadline are shed loudly with per-tenant counters instead of
# dragging every class down uniformly.
from repro.serve import PriorityServePolicy

slo_cfg = EngineConfig(max_batch=2, eos_id=7, max_seq=128, decode_tick=4,
                       prefill_block_budget=2, max_queue=16,
                       class_caps={"batch": 1, "background": 1})
slo_engine = ContinuousEngine(serve_model, serve_params, slo_cfg,
                              policy=PriorityServePolicy())
burst = [  # tenant-a is latency-sensitive; tenant-b floods the queue
    dict(slo="interactive", tenant="tenant-a", priority=2, max_new=4),
    dict(slo="interactive", tenant="tenant-a", priority=2, max_new=4),
    dict(slo="batch", tenant="tenant-b", max_new=6),
    dict(slo="batch", tenant="tenant-b", max_new=6),
    dict(slo="background", tenant="tenant-b", deadline_s=1e-4, max_new=8),
    dict(slo="background", tenant="tenant-b", deadline_s=1e-4, max_new=8),
]
for rid, kw in enumerate(burst):
    slo_engine.submit(Request(rid=rid, prompt=rng.randint(
        3, cfg.vocab_size, size=12).astype(np.int32), **kw))
ok, shed = [], []
while slo_engine.pending:
    for r in slo_engine.step():
        (shed if r.shed else ok).append(r)
slo_snap = slo_engine.telemetry.snapshot()
print(f"SLO overload: served {len(ok)}, shed {len(shed)} "
      f"(by tenant {slo_engine.telemetry.shed_by_tenant}, "
      f"by class {slo_engine.telemetry.shed_by_class}); "
      f"interactive always served, every rid accounted once")
assert sorted(r.rid for r in ok + shed) == list(range(len(burst)))
assert all(r.slo != "interactive" for r in shed)

# --- 9. SSM decode serving: chunked scans + entropy-gated early exit --------
# Recurrent models (mamba/mlstm/slstm) decode from O(1) state instead of a
# growing KV cache.  scan_impl="pallas" runs each layer's prefill
# recurrence as ONE chunked associative-scan launch (kernels/ssm_scan.py —
# same VMEM-carry machinery as the sort's histogram scan); tokens are
# unchanged vs the lax path.  The engine then (a) reserves a fixed
# page_size span per request — recurrent_only models never defer admission
# on sequence length — and (b) can retire *confident* lanes early: a lane
# whose predictive entropy stays under exit_entropy nats for exit_patience
# steps stops decoding, and its slot backfills from the queue.  Gating
# only stops emission, so a gated stream is an exact prefix of the
# ungated one (pinned in tests/test_ssm_scan.py and BENCH_scan_ssm.json).
import dataclasses as _dc

ssm_cfg = _dc.replace(get_smoke_config("xlstm-1.3b"),
                      param_dtype="float32", compute_dtype="float32")
ssm_model = Model(ssm_cfg, scan_impl="pallas")
assert ssm_model.recurrent_only
ssm_params = ssm_model.init(jax.random.PRNGKey(2))
prompts = [rng.randint(3, ssm_cfg.vocab_size, size=n).astype(np.int32)
           for n in (9, 21, 14)]

def serve_ssm(exit_entropy):
    eng = ContinuousEngine(ssm_model, ssm_params, EngineConfig(
        max_batch=2, max_seq=96, eos_id=7, decode_tick=4, page_size=16,
        exit_entropy=exit_entropy))
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new=12))
    out = {}
    while eng.pending:
        for r in eng.step():
            out[r.rid] = np.asarray(r.result)
    return out, eng

plain, plain_eng = serve_ssm(None)
# a random-weight smoke model is near-maximally uncertain (entropy ≈
# ln(vocab) nats), so the demo threshold sits just above that; a trained
# model would use a tight budget like 2–3 nats
gated, gated_eng = serve_ssm(float(np.log(ssm_cfg.vocab_size)) + 0.5)
for rid in plain:                        # exact-prefix property, live
    assert np.array_equal(gated[rid], plain[rid][:len(gated[rid])])
print(f"ssm decode: {plain_eng.telemetry.decode_steps} plain vs "
      f"{gated_eng.telemetry.decode_steps} gated decode steps, "
      f"{gated_eng.telemetry.early_exits} early exits, "
      f"gated streams are exact prefixes")
print("QUICKSTART OK")
