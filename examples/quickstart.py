"""Quickstart: Kvik's composable scheduling policies in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's API surface: a Divisible, adaptors nested over it, three
schedulers executing the same work, and the policy driving a real JAX
computation (microbatched gradient accumulation).
"""

import jax
import jax.numpy as jnp

from repro.core import (AdaptivePolicy, BatchWork, ByBlocksPolicy, CostModel,
                        DepJoinPolicy, JoinPolicy, Runtime, WorkRange,
                        bound_depth, build_plan, by_blocks, demand_split,
                        even_levels, simulate, thief_splitting, wrap_iter)

# --- 1. a Divisible + nested adaptors (paper §3.1/§3.3) --------------------
work = thief_splitting(bound_depth(BatchWork(0, 256), 5), p=16)
plan = build_plan(work)
print("plan:", plan.describe())

# --- 2. the same computation under three schedulers ------------------------
total = wrap_iter(thief_splitting(WorkRange(0, 10_000), p=8)).map_reduce(
    lambda leaf: sum(leaf.indices()), lambda a, b: a + b)
print("wrap_iter map-reduce:", total, "== ", sum(range(10_000)))

adaptive_plan = demand_split(WorkRange(0, 10_000), demand=6)
print("adaptive (demand=6):", adaptive_plan.describe())

bb = by_blocks(first=16)
_, stats = bb.run(WorkRange(0, 10_000),
                  lambda blk, c: c or blk.start > 500, False,
                  should_stop=lambda c: c)
print("by_blocks early stop:", stats)

# --- 3. simulating a policy (paper §4) --------------------------------------
# One discrete-event engine (Runtime), one ~50-line policy object per
# scheduler.  The policy is a value: swap it, wrap work in adaptors, or
# compose policies — same engine, comparable numbers.
cost = CostModel(per_item=1.0)
res = simulate(WorkRange(0, 99_999), AdaptivePolicy(), 8, cost, seed=0)
print(f"adaptive sim: tasks={res.tasks_created} = steals+1="
      f"{res.steals_successful + 1}, speedup={res.speedup_vs_serial:.2f}")

# join vs depjoin is one hook's difference (who runs the reduction)
dep = simulate(thief_splitting(WorkRange(0, 50_000), p=8), DepJoinPolicy(),
               8, CostModel(per_item=1.0, reduce_cost=10.0), seed=0)
print(f"depjoin sim: reductions={dep.reductions} == divisions="
      f"{dep.divisions}")

# compositions the old per-scheduler engines could not express: an
# interruptible by_blocks outer loop whose blocks run under the *adaptive*
# policy, stopping as soon as an item-level predicate fires
found = simulate(WorkRange(0, 99_999),
                 ByBlocksPolicy(inner=AdaptivePolicy(), first=8), 8, cost,
                 stop_predicate=lambda i: i if i == 777 else None)
print(f"by_blocks(adaptive) early exit: items={found.items_processed} "
      f"wasted={found.wasted_items} of {found.items_total}")

# --- 4. the paper's showcase: level-batched stable merge sort ---------------
# The sort's adaptor stack (even_levels ∘ bound_depth) becomes a static plan
# whose sort_schedule() drives ONE Pallas launch per merge level —
# log2(n/tile) launches, fixed ≤2·tile blocks — instead of one per tree
# node.  even_levels parity shows up as the halved tile (3 levels → 4).
# New default (PR 4): the tile phase is an in-kernel LSD radix sort (the
# schedule's digit-pass metadata, ceil(num_key_bits/r) passes) with the
# key<<idx_bits|index pack fused into the tile-sort kernel and the final
# unpack fused into the last merge level — zero standalone elementwise
# launches.  The seed ran pack/unpack as separate elementwise ops outside
# the kernels; fused=False reconstructs that pipeline with them as
# explicit, countable launches (method="bitonic" keeps the seed network).
import numpy as np
from repro.kernels.merge_sort import argsort, trace_launches

keys = np.random.RandomState(0).randint(0, 16, 4096).astype(np.int32)
with trace_launches() as tr:
    order = argsort(jnp.asarray(keys), tile=512, interpret=True)
assert (np.asarray(order) == np.argsort(keys, kind="stable")).all()
with trace_launches() as tr_unfused:
    argsort(jnp.asarray(keys), tile=512, interpret=True, fused=False)
print(f"merge sort: n=4096 tile=512 -> launches={len(tr)} "
      f"(1 radix tile sort + {len(tr) - 1} even merge levels, pack/unpack "
      f"fused; unfused would take {len(tr_unfused)}), stable order ok")

# --- 5. the policy driving a JAX training computation ----------------------
# The same plan machinery decides distribution: microbatch counts come from
# a thief_splitting plan, the pipeline tick order is a division tree's leaf
# walk, and every sharding decision is one row of the repro.dist rule table.
from repro.train.step import TrainState, make_train_step, microbatch_plan

from repro.configs.registry import get_config, get_smoke_config
from repro.dist.pipeline import bubble_fraction, schedule_ticks
from repro.dist.sharding import param_pspec
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, init_state

cfg = get_smoke_config("llama3-8b")
model = Model(cfg)
opt = AdamWConfig(warmup_steps=1)
n_mb = microbatch_plan(global_batch=8, dp=1, tokens_per_seq=32,
                       target_tokens_per_replica=64)
print(f"microbatch plan from thief_splitting: {n_mb} microbatches")
step = jax.jit(make_train_step(model, opt, num_microbatches=n_mb))
params = model.init(jax.random.PRNGKey(0))
state = TrainState(params=params, opt=init_state(opt, params))
batch = {"tokens": jnp.ones((8, 32), jnp.int32),
         "labels": jnp.ones((8, 32), jnp.int32)}
state, metrics = step(state, batch)
print("train step:", {k: float(v) for k, v in metrics.items()})

# the sharding rule table: pure (config, path, rank) → PartitionSpec rows
full = get_config("jamba-1.5-large-398b")
print("param_pspec rules:",
      "ffn/gate →", param_pspec(full, "stage/0/ffn/gate", 3), "|",
      "moe/gate →", param_pspec(full, "stage/1/moe/gate", 4))

# the pipeline schedule is a plan artifact too: its microbatch order is the
# division tree's left-to-right leaf walk (repro.dist.pipeline)
ticks = schedule_ticks(4, 8)
print(f"pipeline fill-drain, 4 stages x 8 microbatches: {len(ticks)} ticks, "
      f"bubble = {bubble_fraction(4, 8):.1%}")
print("  tick 3:", " ".join(ticks[3]))
print("QUICKSTART OK")
