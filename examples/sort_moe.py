"""The paper's stable merge sort, deployed: MoE token dispatch.

    PYTHONPATH=src python examples/sort_moe.py

Routes a batch of tokens through a small MoE layer twice — once with GShard
einsum dispatch, once with sort-based dispatch where the stable order comes
from the Pallas merge-sort kernel (interpret mode on CPU) — and shows the
outputs agree while the sort path processes every token (dropless).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.kernels.merge_sort import argsort as pallas_argsort
from repro.kernels.ref import stable_argsort_reference
from repro.models.moe import moe_einsum, moe_init, moe_sort_dispatch, \
    route_topk

cfg = get_smoke_config("deepseek-v2-lite-16b")
params = moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model)
                      ).astype(jnp.float32)

# 1. the routing decisions
probs, experts, aux = route_topk(params["router"], x.reshape(-1, cfg.d_model),
                                 cfg.top_k)
flat = experts.reshape(-1)
print(f"[sort_moe] {flat.shape[0]} (token,expert) assignments over "
      f"{cfg.num_experts} experts; aux load-balance loss = {float(aux):.3f}")

# 2. the paper's stable sort (Pallas kernel) vs the library oracle — the
# fused radix path: raw expert ids in, order out, pack/unpack in-kernel
order_kernel = pallas_argsort(flat, tile=512, interpret=True, jit=True)
order_ref = stable_argsort_reference(flat)
assert bool(jnp.all(order_kernel == order_ref))
print("[sort_moe] Pallas fused radix merge-sort order == stable oracle ✓")

# 3. end-to-end dispatch equivalence (einsum with generous capacity vs sort)
import dataclasses
cfg_nodrop = dataclasses.replace(cfg, capacity_factor=8.0)
out_sorted, _ = moe_sort_dispatch(params, cfg, x, sort_fn="pallas")
out_einsum, _ = moe_einsum(params, cfg_nodrop, x, group_size=128)
err = float(jnp.max(jnp.abs(out_einsum - out_sorted)))
print(f"[sort_moe] einsum(no-drop) vs sort dispatch max err = {err:.2e}")
assert err < 1e-2
print("[sort_moe] OK")
