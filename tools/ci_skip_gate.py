"""CI gate: summarize a pytest junit XML and fail on excess skips.

Import-level regressions of ``repro.dist`` (or any other package) surface
as waves of skipped/errored tests; this gate makes them loud.  Usage:

    python tools/ci_skip_gate.py results/tier1.xml --max-skips 5

Writes a pass/fail/skip line to ``$GITHUB_STEP_SUMMARY`` when set, always
prints it, and exits non-zero if skips exceed the budget (or anything
failed/errored — pytest already fails the step, this is belt-and-braces).
"""

from __future__ import annotations

import argparse
import os
import sys
import xml.etree.ElementTree as ET


def summarize(path: str):
    root = ET.parse(path).getroot()
    suites = root.iter("testsuite") if root.tag == "testsuites" else [root]
    tests = failures = errors = skipped = 0
    reasons = {}
    for s in suites:
        tests += int(s.get("tests", 0))
        failures += int(s.get("failures", 0))
        errors += int(s.get("errors", 0))
        skipped += int(s.get("skipped", 0))
        for case in s.iter("testcase"):
            sk = case.find("skipped")
            if sk is not None:
                msg = sk.get("message", "")[:100]
                reasons[msg] = reasons.get(msg, 0) + 1
    passed = tests - failures - errors - skipped
    return passed, failures, errors, skipped, reasons


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("junit_xml")
    ap.add_argument("--max-skips", type=int, default=5)
    ap.add_argument("--label", default="tier-1")
    args = ap.parse_args()

    passed, failures, errors, skipped, reasons = summarize(args.junit_xml)
    line = (f"{args.label}: {passed} passed, {failures} failed, "
            f"{errors} errored, {skipped} skipped "
            f"(budget {args.max_skips})")
    print(line)
    for msg, n in sorted(reasons.items(), key=lambda kv: -kv[1]):
        print(f"  skip x{n}: {msg}")

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(f"**{line}**\n")
            for msg, n in sorted(reasons.items(), key=lambda kv: -kv[1]):
                f.write(f"- skip x{n}: `{msg}`\n")

    if failures or errors:
        return 1
    if skipped > args.max_skips:
        print(f"FAIL: {skipped} skips > budget {args.max_skips} — "
              "an import-level regression can hide here", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
