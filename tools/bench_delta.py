"""Compare freshly-run benchmark JSON against the committed baseline.

    python tools/bench_delta.py results/bench/BENCH_sort.json \
        [--baseline git:HEAD] [--max-regress 0.25] [--no-normalize]

Rows are matched by ``name``.  Rows whose *baseline* meta carries
``"pinned": true`` are guarded: a wall-clock regression beyond
``--max-regress`` (default 25%) fails the run (exit 1).

Rows whose baseline meta carries ``"pinned_ints": ["key", ...]`` are
guarded *structurally*: each named meta key must match the baseline
EXACTLY (integer equality, no tolerance, no hardware normalization) —
the mechanism that pins launch counts and block counts, e.g. the
multi-tile radix property "argsort launches are independent of n" and the
one-launch MoE dispatch.  Such rows may have ``us_per_call == 0``; they
are reported in their own launch-count table.

CI runners and the machine that committed the baseline differ in absolute
speed, so raw us_per_call ratios conflate hardware with regressions.  By
default the per-row ratio is therefore normalized by the **median ratio
across the calibration rows** (baseline meta ``"calibration": true``).
Tag only wall-clock rows of the *same kind* as the pinned rows (here:
interpret-mode pallas runs — C-speed library sorts scale differently from
Python-tracing-bound rows, and deterministic rows like virtual-time
makespans or launch counts would drag the scale toward 1.0).  A uniform
hardware delta then cancels, while a
single pinned row regressing against its peers is exactly what survives.
Falls back to the median over all matched rows when nothing is tagged;
``--no-normalize`` compares raw wall clock (same-machine trajectories).

The delta table is printed to stdout and appended to
``$GITHUB_STEP_SUMMARY`` when set (the CI step summary).
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
from pathlib import Path


def load_rows(payload: dict) -> dict:
    return {r["name"]: r for r in payload.get("rows", [])}


def load_baseline(spec: str, fresh_path: str) -> dict:
    """``git:REF`` reads the committed copy of ``fresh_path`` at REF;
    anything else is a filesystem path."""
    if spec.startswith("git:"):
        ref = spec[4:]
        rel = os.path.relpath(fresh_path)
        out = subprocess.run(
            ["git", "show", f"{ref}:{rel}"], capture_output=True, text=True)
        if out.returncode != 0:
            raise SystemExit(f"bench_delta: cannot read {rel} at {ref}: "
                             f"{out.stderr.strip()}")
        return json.loads(out.stdout)
    return json.loads(Path(spec).read_text())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly-written BENCH_*.json")
    ap.add_argument("--baseline", default="git:HEAD",
                    help="baseline: 'git:REF' or a file path (default "
                         "git:HEAD — the committed trajectory)")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed fractional wall-clock regression of a "
                         "pinned row (default 0.25)")
    ap.add_argument("--no-normalize", action="store_true",
                    help="compare raw wall clock instead of hardware-"
                         "normalized ratios")
    args = ap.parse_args(argv)

    fresh = load_rows(json.loads(Path(args.fresh).read_text()))
    base = load_rows(load_baseline(args.baseline, args.fresh))

    # --- pinned integer metrics (launch counts etc.): exact equality,
    # independent of the wall-clock machinery below
    int_lines = []
    int_failures = []
    int_rows = [(name, row) for name, row in base.items()
                if row.get("meta", {}).get("pinned_ints")]
    for name, b in sorted(int_rows):
        keys = b["meta"]["pinned_ints"]
        f = fresh.get(name)
        if f is None:
            int_failures.append((name, "row MISSING from fresh results"))
            int_lines.append(f"| {name} | — | — | — | MISSING |")
            continue
        for key in keys:
            bv = b["meta"].get(key)
            fv = f.get("meta", {}).get(key)
            status = "ok" if bv == fv and fv is not None else "CHANGED"
            if status != "ok":
                int_failures.append((name, f"{key}: {bv} -> {fv}"))
            int_lines.append(f"| {name} | {key} | {bv} | {fv} | {status} |")
    if int_lines:
        int_lines = ["", "#### pinned integer metrics (exact)", "",
                     "| row | metric | base | fresh | status |",
                     "|---|---|---:|---:|:-:|"] + int_lines

    matched = [(name, base[name], fresh[name])
               for name in base if name in fresh
               and base[name]["us_per_call"] > 0]
    if not matched and not int_rows:
        print("bench_delta: no matching rows — nothing to compare")
        return 0

    failures = []
    lines = []
    if matched:
        ratios = {name: f["us_per_call"] / b["us_per_call"]
                  for name, b, f in matched}
        cal = [ratios[name] for name, b, _ in matched
               if b.get("meta", {}).get("calibration")]
        scale = 1.0 if args.no_normalize else \
            statistics.median(cal if cal else list(ratios.values()))

        lines = [f"### bench delta: `{args.fresh}` vs `{args.baseline}` "
                 f"(scale {scale:.2f}× over "
                 f"{len(cal) if cal else len(ratios)} "
                 f"{'calibration' if cal else 'matched'} rows)",
                 "",
                 "| row | base us | fresh us | delta | pinned | status |",
                 "|---|---:|---:|---:|:-:|:-:|"]
        for name, b, f in matched:
            delta = ratios[name] / scale - 1
            pinned = bool(b.get("meta", {}).get("pinned"))
            status = "ok"
            if pinned and delta > args.max_regress:
                status = "REGRESSED"
                failures.append((name, delta))
            lines.append(f"| {name} | {b['us_per_call']:.0f} "
                         f"| {f['us_per_call']:.0f} | {delta:+.1%} "
                         f"| {'📌' if pinned else ''} | {status} |")
    else:
        lines = [f"### bench delta: `{args.fresh}` vs `{args.baseline}` "
                 f"(no wall-clock rows matched)"]
    # a pinned baseline row that vanished from the fresh results is a gate
    # bypass (renamed bench, partial emission, deleted emit), not a pass
    missing_pinned = sorted(
        name for name, row in base.items()
        if row.get("meta", {}).get("pinned") and name not in fresh)
    for name in missing_pinned:
        failures.append((name, float("nan")))
        lines.append(f"| {name} | {base[name]['us_per_call']:.0f} | — | — "
                     f"| 📌 | MISSING |")
    new_rows = sorted(set(fresh) - set(base))
    if new_rows:
        lines += ["", f"new rows (no baseline): {', '.join(new_rows)}"]
    lines += int_lines

    table = "\n".join(lines)
    print(table)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write(table + "\n")

    if failures or int_failures:
        if failures:
            print(f"\nbench_delta: {len(failures)} pinned row(s) regressed "
                  f"> {args.max_regress:.0%}: "
                  + ", ".join(f"{n} ({d:+.1%})" for n, d in failures),
                  file=sys.stderr)
        if int_failures:
            print(f"\nbench_delta: {len(int_failures)} pinned integer "
                  "metric(s) changed: "
                  + "; ".join(f"{n} ({msg})" for n, msg in int_failures),
                  file=sys.stderr)
        return 1
    print(f"\nbench_delta: all pinned rows within {args.max_regress:.0%}"
          + (" and all pinned integer metrics exact" if int_rows else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
