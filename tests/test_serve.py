"""Serving-stack tests: chunked prefill exactness, early-exit waste bounds,
engine end-to-end."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.model import Model
from repro.serve.early_exit import decode_until_eos
from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.prefill import ChunkedPrefill

KEY = jax.random.PRNGKey(0)


def fp32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


@pytest.mark.parametrize("arch", ["llama3-8b", "chatglm3-6b",
                                  "deepseek-v2-lite-16b", "xlstm-1.3b",
                                  "jamba-1.5-large-398b"])
def test_chunked_prefill_matches_full(arch):
    cfg = fp32(get_smoke_config(arch))
    model = Model(cfg, moe_strategy="sort")
    params = model.init(KEY)
    B, S = 2, 96
    toks = jax.random.randint(KEY, (B, S), 1, cfg.vocab_size)
    full_logits, _ = model.prefill(params, {"tokens": toks}, max_seq=S)
    cp = ChunkedPrefill(model, first_block=16, align=16, max_block=64)
    chunk_logits, _, stats = cp.run(params, toks, model.init_cache(B, S))
    assert stats.tokens == S
    assert stats.blocks >= 3          # geometric: 16, 32, 48
    np.testing.assert_allclose(np.asarray(chunk_logits),
                               np.asarray(full_logits), atol=1e-3, rtol=1e-3)


def test_chunked_prefill_vlm_cross_attention():
    cfg = fp32(get_smoke_config("llama-3.2-vision-11b"))
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 2, 64
    toks = jax.random.randint(KEY, (B, S), 1, cfg.vocab_size)
    img = jax.random.normal(KEY, (B, cfg.num_image_tokens, cfg.d_model)
                            ).astype(cfg.dtype())
    batch = {"tokens": toks, "image_embeds": img}
    full_logits, _ = model.prefill(params, batch, max_seq=S)
    cp = ChunkedPrefill(model, first_block=16, align=16, max_block=32)
    chunk_logits, _, _ = cp.run(params, toks, model.init_cache(
        B, S, cross_len=cfg.num_image_tokens), batch=batch)
    np.testing.assert_allclose(np.asarray(chunk_logits),
                               np.asarray(full_logits), atol=1e-3, rtol=1e-3)


def test_chunked_prefill_cancellation_bounded_waste():
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 1, 256
    toks = jax.random.randint(KEY, (B, S), 1, cfg.vocab_size)
    cp = ChunkedPrefill(model, first_block=16, align=16, max_block=None)
    calls = [0]

    def cancel_after_two():
        calls[0] += 1
        return calls[0] >= 2

    logits, _, stats = cp.run(params, toks, model.init_cache(B, S),
                              should_cancel=cancel_after_two)
    assert logits is None and stats.cancelled
    assert stats.tokens < S           # stopped early, bounded work


def test_early_exit_blocks_vs_naive_waste():
    """by_blocks decode wastes bounded work vs the naive full-length run —
    the paper's find_first claim on the decoding path."""
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(KEY)
    B, S, MAXNEW = 4, 16, 128
    toks = jax.random.randint(KEY, (B, S), 3, cfg.vocab_size)
    logits, cache = model.prefill(params, {"tokens": toks},
                                  max_seq=S + MAXNEW)
    first = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
    lengths = jnp.full((B,), S, jnp.int32)
    eos = int(first[0])               # guaranteed to fire at step ~1

    cache2 = jax.tree.map(jnp.copy, cache)
    _, _, with_blocks = decode_until_eos(
        model, params, first, cache, lengths, eos_id=eos, max_new=MAXNEW,
        use_blocks=True, first_block=4)
    _, _, naive = decode_until_eos(
        model, params, first, cache2, lengths, eos_id=eos, max_new=MAXNEW,
        use_blocks=False)
    assert naive.steps_run == MAXNEW
    if with_blocks.all_finished:
        assert with_blocks.steps_run < naive.steps_run
        assert with_blocks.wasted_tokens <= naive.wasted_tokens


def test_engine_end_to_end():
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, EngineConfig(max_batch=3, eos_id=7))
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(8 + i, dtype=np.int32) + 3,
                           max_new=12))
    done = eng.step()
    assert len(done) == 3             # cap admission
    for r in done:
        assert r.result is not None and 1 <= len(r.result) <= 13
    done2 = eng.step()
    assert len(done2) == 2
