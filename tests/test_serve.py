"""Serving-stack tests: chunked prefill exactness, early-exit waste bounds,
engine end-to-end."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.model import Model
from repro.serve.early_exit import decode_until_eos
from repro.serve.engine import (ContinuousEngine, Engine, EngineConfig,
                                Request)
from repro.serve.prefill import ChunkedPrefill

KEY = jax.random.PRNGKey(0)


def fp32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = fp32(get_smoke_config("llama3-8b"))
    model = Model(cfg)
    params = model.init(KEY)
    return model, params


def _mixed_requests(vocab, lens=(9, 33, 17, 26), max_news=(6, 9, 7, 8)):
    rng = np.random.RandomState(0)
    return [Request(rid=i,
                    prompt=rng.randint(3, vocab, size=n).astype(np.int32),
                    max_new=mn)
            for i, (n, mn) in enumerate(zip(lens, max_news))]


def _serve_one_at_a_time(model, params, reqs, **cfg_kw):
    out = []
    for r in reqs:
        eng = Engine(model, params,
                     EngineConfig(max_batch=1, **cfg_kw))
        eng.submit(Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new))
        (done,) = eng.step()
        out.append(np.asarray(done.result))
    return out


@pytest.mark.parametrize("arch", ["llama3-8b", "chatglm3-6b",
                                  "deepseek-v2-lite-16b", "xlstm-1.3b",
                                  "jamba-1.5-large-398b"])
def test_chunked_prefill_matches_full(arch):
    cfg = fp32(get_smoke_config(arch))
    model = Model(cfg, moe_strategy="sort")
    params = model.init(KEY)
    B, S = 2, 96
    toks = jax.random.randint(KEY, (B, S), 1, cfg.vocab_size)
    full_logits, _ = model.prefill(params, {"tokens": toks}, max_seq=S)
    cp = ChunkedPrefill(model, first_block=16, align=16, max_block=64)
    chunk_logits, _, stats = cp.run(params, toks, model.init_cache(B, S))
    assert stats.tokens == S
    assert stats.blocks >= 3          # geometric: 16, 32, 48
    np.testing.assert_allclose(np.asarray(chunk_logits),
                               np.asarray(full_logits), atol=1e-3, rtol=1e-3)


def test_chunked_prefill_vlm_cross_attention():
    cfg = fp32(get_smoke_config("llama-3.2-vision-11b"))
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 2, 64
    toks = jax.random.randint(KEY, (B, S), 1, cfg.vocab_size)
    img = jax.random.normal(KEY, (B, cfg.num_image_tokens, cfg.d_model)
                            ).astype(cfg.dtype())
    batch = {"tokens": toks, "image_embeds": img}
    full_logits, _ = model.prefill(params, batch, max_seq=S)
    cp = ChunkedPrefill(model, first_block=16, align=16, max_block=32)
    chunk_logits, _, _ = cp.run(params, toks, model.init_cache(
        B, S, cross_len=cfg.num_image_tokens), batch=batch)
    np.testing.assert_allclose(np.asarray(chunk_logits),
                               np.asarray(full_logits), atol=1e-3, rtol=1e-3)


def test_chunked_prefill_cancellation_bounded_waste():
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 1, 256
    toks = jax.random.randint(KEY, (B, S), 1, cfg.vocab_size)
    cp = ChunkedPrefill(model, first_block=16, align=16, max_block=None)
    calls = [0]

    def cancel_after_two():
        calls[0] += 1
        return calls[0] >= 2

    logits, _, stats = cp.run(params, toks, model.init_cache(B, S),
                              should_cancel=cancel_after_two)
    assert logits is None and stats.cancelled
    assert stats.tokens < S           # stopped early, bounded work


def test_early_exit_blocks_vs_naive_waste():
    """by_blocks decode wastes bounded work vs the naive full-length run —
    the paper's find_first claim on the decoding path."""
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(KEY)
    B, S, MAXNEW = 4, 16, 128
    toks = jax.random.randint(KEY, (B, S), 3, cfg.vocab_size)
    logits, cache = model.prefill(params, {"tokens": toks},
                                  max_seq=S + MAXNEW)
    first = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
    lengths = jnp.full((B,), S, jnp.int32)
    eos = int(first[0])               # guaranteed to fire at step ~1

    cache2 = jax.tree.map(jnp.copy, cache)
    _, _, with_blocks = decode_until_eos(
        model, params, first, cache, lengths, eos_id=eos, max_new=MAXNEW,
        use_blocks=True, first_block=4)
    _, _, naive = decode_until_eos(
        model, params, first, cache2, lengths, eos_id=eos, max_new=MAXNEW,
        use_blocks=False)
    assert naive.steps_run == MAXNEW
    if with_blocks.all_finished:
        assert with_blocks.steps_run < naive.steps_run
        assert with_blocks.wasted_tokens <= naive.wasted_tokens


def test_engine_end_to_end():
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, EngineConfig(max_batch=3, eos_id=7))
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(8 + i, dtype=np.int32) + 3,
                           max_new=12))
    done = eng.step()
    assert len(done) == 3             # cap admission
    for r in done:
        assert r.result is not None and 1 <= len(r.result) <= 12
    done2 = eng.step()
    assert len(done2) == 2


def test_engine_mixed_lengths_match_one_at_a_time(smoke_model):
    """Golden: a mixed-length batch decodes the same tokens as serving each
    request alone — the padded-position bug would condition short rows on
    pad tokens and diverge."""
    model, params = smoke_model
    reqs = _mixed_requests(model.cfg.vocab_size)
    ref = _serve_one_at_a_time(model, params, reqs, eos_id=7, max_seq=256)
    eng = Engine(model, params,
                 EngineConfig(max_batch=4, eos_id=7, max_seq=256))
    for r in reqs:
        eng.submit(r)
    done = {r.rid: r for r in eng.step()}
    assert len(done) == len(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(done[i].result), ref[i])


def test_engine_per_request_max_new_and_stats(smoke_model):
    """Results are capped at each request's own max_new (not max_new+1, not
    the batch max), and every request gets its own stats object."""
    model, params = smoke_model
    reqs = _mixed_requests(model.cfg.vocab_size,
                           lens=(9, 20, 14), max_news=(3, 11, 1))
    eng = Engine(model, params,
                 EngineConfig(max_batch=3, eos_id=7, max_seq=256))
    for r in reqs:
        eng.submit(r)
    done = {r.rid: r for r in eng.step()}
    stats_ids = {id(done[i].stats) for i in range(3)}
    assert len(stats_ids) == 3        # per-request, not shared
    for i, r in enumerate(reqs):
        assert 1 <= len(done[i].result) <= r.max_new
        st = done[i].stats
        assert st.useful_tokens == len(done[i].result)
        assert st.wasted_tokens == st.steps_run - (st.useful_tokens - 1)
    assert len(done[2].result) == 1   # max_new=1 → the first token only


def test_prefill_compiles_once_per_chunk_size(smoke_model):
    """The jit cache is keyed on chunk *length*, never position: re-runs,
    resumes, and different start offsets reuse the same traces."""
    model, params = smoke_model
    cp = ChunkedPrefill(model, first_block=16, align=16, max_block=64)
    toks = jax.random.randint(KEY, (1, 96), 1, model.cfg.vocab_size)
    cp.run(params, toks, model.init_cache(1, 96))
    n0 = cp.trace_count
    assert n0 == 3                    # geometric blocks: 16, 32, 48

    # same sizes at different positions: resume after preemption + a run
    # starting mid-prompt — no new traces
    _, cache, st = cp.run(params, toks, model.init_cache(1, 96),
                          max_blocks=1)
    assert st.preempted
    cp.run(params, toks, cache, start=st.next_start)
    cp.run(params, toks, model.init_cache(1, 96), start=16)
    assert cp.trace_count == n0

    # the all-logits (mixed-length gather) variant traces separately, and
    # again only once per chunk size
    cp.run(params, toks, model.init_cache(1, 96), row_lengths=[77])
    n1 = cp.trace_count
    assert n1 == n0 + 3
    cp.run(params, toks, model.init_cache(1, 96), row_lengths=[50])
    assert cp.trace_count == n1
    assert len(cp._jits) == n1


def test_decode_wasted_reconciliation(smoke_model):
    """The kernel's per-block waste counter and the steps·B − useful formula
    agree (decode_until_eos asserts it; exercise a mixed-finish batch with
    EOS firing at a block boundary)."""
    model, params = smoke_model
    B, S = 4, 16
    toks = jax.random.randint(KEY, (B, S), 3, model.cfg.vocab_size)
    logits, cache = model.prefill(params, {"tokens": toks}, max_seq=S + 64)
    first = jnp.argmax(logits[:, :model.cfg.vocab_size],
                       -1).astype(jnp.int32)
    lengths = jnp.full((B,), S, jnp.int32)
    eos = int(first[0])               # row 0 finishes immediately
    gen, _, stats = decode_until_eos(
        model, params, first, cache, lengths, eos_id=eos, max_new=64,
        use_blocks=True, first_block=4)
    useful = int((np.asarray(gen) >= 0).sum())
    assert stats.useful_tokens == useful
    assert stats.wasted_tokens == stats.steps_run * B - useful
    assert stats.wasted_tokens > 0    # row 0 idled while others decoded


def test_engine_max_seq_loud_error(smoke_model):
    """Requests that cannot fit the configured cache fail loudly instead of
    silently allocating past max_seq."""
    model, params = smoke_model
    eng = Engine(model, params,
                 EngineConfig(max_batch=1, eos_id=7, max_seq=64))
    eng.submit(Request(rid=0, prompt=np.arange(50, dtype=np.int32) + 3,
                       max_new=32))
    with pytest.raises(ValueError, match="max_seq"):
        eng.step()
    cont = ContinuousEngine(model, params,
                            EngineConfig(max_batch=1, eos_id=7, max_seq=64))
    with pytest.raises(ValueError, match="max_seq"):
        cont.submit(Request(rid=1, prompt=np.arange(50, dtype=np.int32) + 3,
                            max_new=32))


def _drain(engine, max_steps=500):
    out = {}
    steps = 0
    while engine.pending:
        for r in engine.step():
            out[r.rid] = r
        steps += 1
        assert steps < max_steps, "engine made no progress"
    return out


def test_continuous_engine_matches_one_at_a_time(smoke_model):
    """Backfill correctness: 6 mixed-length requests through 3 slots emit
    exactly the tokens each request gets when served alone."""
    model, params = smoke_model
    reqs = _mixed_requests(model.cfg.vocab_size,
                           lens=(9, 33, 17, 51, 12, 40),
                           max_news=(10, 6, 14, 8, 12, 5))
    ref = _serve_one_at_a_time(model, params, reqs, eos_id=7, max_seq=256)
    eng = ContinuousEngine(model, params,
                           EngineConfig(max_batch=3, eos_id=7, max_seq=256,
                                        decode_tick=4))
    for r in reqs:
        eng.submit(Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new))
    done = _drain(eng)
    assert len(done) == len(reqs)
    for i, r in enumerate(reqs):
        res = np.asarray(done[i].result)
        assert 1 <= len(res) <= r.max_new
        np.testing.assert_array_equal(res, ref[i])
        st = done[i].stats
        assert st.useful_tokens == len(res)
        assert st.wasted_tokens == st.steps_run - (st.useful_tokens - 1)
    # slots, pages, and cap leases all return to empty
    assert eng.telemetry.retired == len(reqs)
    assert len(eng.pages.free) == eng.pages.num_pages
    assert eng._admission.counter.value == 1


def test_continuous_preempt_resume_under_backfill(smoke_model):
    """A long prompt's chunked prefill is preempted every step
    (budget=1 block) while decode keeps ticking; short requests admitted
    behind it still finish first, and every result stays exact."""
    model, params = smoke_model
    rng = np.random.RandomState(1)
    long_req = Request(rid=0, prompt=rng.randint(
        3, model.cfg.vocab_size, size=130).astype(np.int32), max_new=12)
    shorts = [Request(rid=i, prompt=rng.randint(
        3, model.cfg.vocab_size, size=10 + i).astype(np.int32), max_new=4)
        for i in (1, 2)]
    reqs = [long_req] + shorts
    # the sync reference pads prompts to a power of two (256 for 130),
    # so it needs a wider cache; extra masked width cannot change tokens
    ref = _serve_one_at_a_time(model, params, reqs, eos_id=7, max_seq=320)
    eng = ContinuousEngine(
        model, params,
        EngineConfig(max_batch=2, eos_id=7, max_seq=192, decode_tick=2,
                     prefill_block_budget=1))
    order = []
    for r in reqs:
        eng.submit(Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new))
    done = {}
    steps = 0
    while eng.pending:
        for r in eng.step():
            done[r.rid] = r
            order.append(r.rid)
        steps += 1
        assert steps < 500
    assert eng.telemetry.prefill_preemptions >= 2   # 130 → ≥3 blocks
    assert order[-1] == 0             # the long request retires last
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(done[i].result), ref[i])


def test_continuous_page_exhaustion_defers_admission(smoke_model):
    """When the page table cannot hold a request's worst-case span the
    admission is deferred — and granted once a retirement frees pages."""
    model, params = smoke_model
    rng = np.random.RandomState(2)
    small = Request(rid=0, prompt=rng.randint(
        3, model.cfg.vocab_size, size=9).astype(np.int32), max_new=20)
    big = Request(rid=1, prompt=rng.randint(
        3, model.cfg.vocab_size, size=70).astype(np.int32), max_new=20)
    eng = ContinuousEngine(
        model, params,
        EngineConfig(max_batch=2, eos_id=7, max_seq=128, decode_tick=4,
                     page_size=32, num_pages=3))
    eng.submit(small)                 # span 32 → 1 page
    eng.submit(big)                   # span 96 → 3 pages: must wait
    done = _drain(eng)
    assert len(done) == 2
    assert eng.telemetry.deferred_pages > 0
    assert len(eng.pages.free) == 3   # all released
    ref = _serve_one_at_a_time(model, params, [small, big],
                               eos_id=7, max_seq=192)
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(done[i].result), ref[i])


def test_cap_live_threshold_and_events():
    """The cap adaptor's serving hooks: threshold_fn shrinks the effective
    cap without rebuilding the stack; on_event observes every counter
    change across clones."""
    from repro.core import Cap, WorkRange
    events = []
    limit = [10]
    c = Cap(WorkRange(0, 100), 4, threshold_fn=lambda: limit[0],
            on_event=lambda kind, live: events.append((kind, live)))
    assert c.should_be_divided()
    lease, rest = c.divide_at(10)     # counter 1 → 2
    assert events == [("divide", 2)]
    limit[0] = 2                      # telemetry tightens below the ceiling
    assert not rest.should_be_divided()
    lease.on_finish()                 # counter 2 → 1
    assert events[-1] == ("finish", 1)
    assert rest.should_be_divided()
