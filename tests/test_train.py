"""Training-stack tests: checkpoint atomicity/restore, resume determinism,
straggler rebalancing, elastic re-meshing, data-pipeline reproducibility."""

import dataclasses
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, DataPipeline
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, init_state
from repro.train.checkpoint import CheckpointManager, config_fingerprint
from repro.train.elastic import choose_mesh
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import TrainState, make_train_step, microbatch_plan
from repro.train.straggler import (AdaptiveRebalancer, StragglerDetector,
                                   TelemetryBuffer)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=7)
    p1 = DataPipeline(cfg)
    batches1 = [p1.next_batch() for _ in range(5)]
    # resume from step 3
    p2 = DataPipeline(cfg)
    p2.state.step = 3
    b3 = p2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], batches1[3]["tokens"])


def test_pipeline_shard_slices_consistent():
    """Any shard [lo,hi) equals those rows of the full batch — replicas can
    regenerate any other replica's data (elastic recovery property)."""
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=32, seed=3)
    p = DataPipeline(cfg)
    full = p.batch_slice(11, 0, 32)
    part = p.batch_slice(11, 8, 20)
    np.testing.assert_array_equal(part["tokens"], full["tokens"][8:20])


def test_pipeline_shard_plan_shares():
    cfg = DataConfig(vocab_size=100, seq_len=4, global_batch=64)
    p = DataPipeline(cfg)
    eq = p.shard_plan(4)
    assert [hi - lo for lo, hi in eq] == [16, 16, 16, 16]
    weighted = p.shard_plan(4, shares=[0.4, 0.3, 0.2, 0.1])
    sizes = [hi - lo for lo, hi in weighted]
    assert sum(sizes) == 64 and sizes[0] > sizes[-1]
    # coverage without overlap
    pos = 0
    for lo, hi in weighted:
        assert lo == pos
        pos = hi
    assert pos == 64


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tiny_state():
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    opt_cfg = AdamWConfig()
    params = model.init(KEY)
    return cfg, model, opt_cfg, TrainState(params=params,
                                           opt=init_state(opt_cfg, params))


def test_checkpoint_roundtrip(tmp_path):
    cfg, model, opt_cfg, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), fingerprint="abc")
    mgr.save(7, state, extra={"data_step": 3}, blocking=True)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, extra = mgr.restore(abstract)
    assert extra["data_step"] == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_incomplete_dirs_ignored(tmp_path):
    cfg, model, opt_cfg, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=True)
    # simulate a crash mid-save: stray tmp dir
    bad = tmp_path / "step_00000002.tmp-999"
    bad.mkdir()
    (bad / "arr_00000.npy").write_bytes(b"garbage")
    mgr2 = CheckpointManager(str(tmp_path))
    assert mgr2.latest_step() == 1
    assert not bad.exists()          # gc'd on restart


def test_checkpoint_keep_k(tmp_path):
    cfg, model, opt_cfg, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.steps() == [3, 4]


def test_checkpoint_fingerprint_mismatch(tmp_path):
    cfg, model, opt_cfg, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), fingerprint="aaa")
    mgr.save(1, state, blocking=True)
    mgr2 = CheckpointManager(str(tmp_path), fingerprint="bbb")
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    with pytest.raises(ValueError):
        mgr2.restore(abstract)


def test_checkpoint_async(tmp_path):
    cfg, model, opt_cfg, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


# ---------------------------------------------------------------------------
# trainer: resume == uninterrupted (bitwise loss trajectory)
# ---------------------------------------------------------------------------

def test_train_resume_matches_uninterrupted(tmp_path):
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, seed=5)

    def run(total, ckpt_dir, resume=False):
        t = Trainer(model, opt_cfg, data_cfg,
                    LoopConfig(total_steps=total, ckpt_every=3,
                               ckpt_dir=str(ckpt_dir), log_every=100))
        state = t.run()
        return t, state

    # uninterrupted 6 steps
    t_a, state_a = run(6, tmp_path / "a")
    # interrupted at 3 then resumed to 6
    t_b1, _ = run(3, tmp_path / "b")
    t_b2, state_b = run(6, tmp_path / "b")
    la = jax.tree.leaves(state_a.params)
    lb = jax.tree.leaves(state_b.params)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_trainer_preemption_checkpoints(tmp_path):
    cfg = get_smoke_config("minitron-4b")
    model = Model(cfg)
    opt_cfg = AdamWConfig()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                          global_batch=2)
    t = Trainer(model, opt_cfg, data_cfg,
                LoopConfig(total_steps=50, ckpt_every=100,
                           ckpt_dir=str(tmp_path), log_every=100))
    state = t.init_or_restore()
    t._preempted = True              # simulate SIGTERM
    t.run(state)
    assert t.ckpt.latest_step() is not None


# ---------------------------------------------------------------------------
# straggler mitigation (the adaptive scheduler at cluster level)
# ---------------------------------------------------------------------------

def test_rebalancer_moves_share_from_straggler():
    tel = TelemetryBuffer(4)
    reb = AdaptiveRebalancer(4, first_window=1)
    shares = None
    for step in range(8):
        tel.record_all([1.0, 1.0, 1.0, 2.5])   # replica 3 is slow
        s = reb.maybe_rebalance(tel)
        shares = s if s is not None else shares
    assert shares is not None
    assert shares[3] < 0.25 < max(shares[:3])
    assert abs(sum(shares) - 1.0) < 1e-9
    assert reb.steals >= 1


def test_rebalancer_window_grows_when_balanced():
    tel = TelemetryBuffer(4)
    reb = AdaptiveRebalancer(4, first_window=2)
    for _ in range(32):
        tel.record_all([1.0, 1.0, 1.0, 1.0])
        assert reb.maybe_rebalance(tel) is None
    assert reb.window > 2            # geometric growth, no steals
    assert reb.steals == 0


def test_straggler_detector_eviction():
    tel = TelemetryBuffer(4)
    det = StragglerDetector(threshold=1.5, patience=3)
    evicted = None
    for _ in range(5):
        tel.record_all([1.0, 1.0, 1.0, 5.0])
        evicted = det.check(tel) or evicted
    assert evicted == 3


# ---------------------------------------------------------------------------
# elastic re-meshing
# ---------------------------------------------------------------------------

def test_choose_mesh_factorization():
    import numpy as _np
    devs = (jax.devices() * 8)[:8]
    m = choose_mesh(8, prefer_model=4, devices=devs)
    assert m.shape["model"] == 4 and m.size == 8
    m2 = choose_mesh(6, prefer_model=4, devices=devs[:6])
    assert m2.shape["model"] == 3 and m2.size == 6


def test_elastic_restore_across_mesh_change(tmp_path):
    """Save under one 'mesh', restore under another device layout."""
    cfg, model, opt_cfg, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=True)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, _ = mgr.restore(abstract, shardings=None)  # host → new devices
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# microbatch planning (the Kvik hook)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gb,dp,tokens", [(256, 16, 4096), (32, 16, 32768),
                                          (64, 4, 1024), (8, 8, 4096)])
def test_microbatch_plan_divides(gb, dp, tokens):
    n = microbatch_plan(gb, dp, tokens_per_seq=tokens)
    assert gb % n == 0
    assert (gb // n) % dp == 0
