"""Sharding-rule tests on small host meshes (the dry-run covers 512)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import get_config, get_smoke_config
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 mesh_context, moments_shardings,
                                 param_pspec, params_shardings,
                                 sanitize_spec, zero1_spec)
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model

# Mesh-materializing tests need ≥4 real host devices.  Run them with
#   XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest tests/test_sharding.py
# (the default suite sees 1 device by design — dry-run owns the 512 flag).
needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs XLA_FLAGS device_count>=4")


def mesh2x2():
    return make_host_mesh(2, 2)


def test_param_pspec_rules():
    cfg = get_config("llama3-8b")
    assert param_pspec(cfg, "embed/table", 2) == P("model", None)
    assert param_pspec(cfg, "stage/0/mixer/wq", 3) == P(None, None, "model")
    assert param_pspec(cfg, "stage/0/mixer/wo", 3) == P(None, "model", None)
    assert param_pspec(cfg, "stage/0/ffn/gate", 3) == P(None, None, "model")
    assert param_pspec(cfg, "stage/0/ffn/down", 3) == P(None, "model", None)


def test_param_pspec_moe_2d():
    cfg = get_config("jamba-1.5-large-398b")
    assert param_pspec(cfg, "stage/0/moe/gate", 4) == \
        P(None, "model", None, "data")
    assert param_pspec(cfg, "stage/0/moe/down", 4) == \
        P(None, "model", "data", None)


from conftest import ShapeOnlyMesh  # sanitize/zero1 only read axis sizes


def test_sanitize_drops_nondividing():
    mesh = ShapeOnlyMesh(data=2, model=2)
    s = sanitize_spec(mesh, P("model", None), (3, 8))
    assert s == P(None, None)
    s2 = sanitize_spec(mesh, P("model", "data"), (4, 6))
    assert s2 == P("model", "data")


def test_zero1_adds_data_axis():
    mesh = ShapeOnlyMesh(data=2, model=2)
    s = zero1_spec(mesh, P(None, "model"), (8, 4))
    assert s == P("data", "model")
    # already data-sharded → unchanged
    s2 = zero1_spec(mesh, P("data", "model"), (8, 4))
    assert s2 == P("data", "model")


@needs_mesh
def test_params_shardings_cover_tree():
    cfg = get_smoke_config("jamba-1.5-large-398b")
    model = Model(cfg)
    aparams = model.abstract_params()
    mesh = mesh2x2()
    sh = params_shardings(cfg, aparams, mesh)
    n_leaves = len(jax.tree.leaves(aparams))
    assert len(jax.tree.leaves(sh)) == n_leaves
    ms = moments_shardings(cfg, aparams, mesh)
    assert len(jax.tree.leaves(ms)) == n_leaves


@needs_mesh
def test_cache_shardings_layouts():
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    mesh = mesh2x2()
    acache = model.abstract_cache(batch=4, max_seq=32)
    sh = cache_shardings(cfg, mesh, acache, batch=4)
    k_shard = sh["stage"][0]["k"]
    # (R, B, S, KV, hd): batch over data, seq over model
    assert k_shard.spec == P(None, "data", "model", None, None)
    # batch=1 (long-context): seq takes every axis
    acache1 = model.abstract_cache(batch=1, max_seq=32)
    sh1 = cache_shardings(cfg, mesh, acache1, batch=1)
    assert sh1["stage"][0]["k"].spec == P(None, None, ("data", "model"),
                                          None, None)


@needs_mesh
def test_sharded_train_equals_unsharded():
    """Numerical equivalence: the same train step, sharded vs single-device."""
    import dataclasses
    from repro.optim.adamw import AdamWConfig, init_state
    from repro.train.step import TrainState, make_train_step

    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              param_dtype="float32",
                              compute_dtype="float32")
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=init_state(opt_cfg, params))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 1,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                     cfg.vocab_size),
    }
    step = make_train_step(model, opt_cfg, num_microbatches=2)
    ref_state, ref_metrics = jax.jit(step)(state, batch)

    mesh = mesh2x2()
    with mesh_context(mesh):
        sh_state, sh_metrics = jax.jit(step)(state, batch)
    assert abs(float(ref_metrics["loss"]) - float(sh_metrics["loss"])) < 1e-4
    # fp32 reduction order differs under sharded psums; 5e-5 abs is the
    # observed single-element drift ceiling on the 2x2 host mesh
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(sh_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5,
                                   rtol=2e-4)
