"""Cross-tile carry scan (kernels/tile_scan.py) — the PR 6 machinery that
turns the (num_tiles × R) digit-histogram matrix into global base offsets
in ONE launch, and the end-to-end multi-tile stability it underwrites.

With real ``hypothesis`` the properties run as ``@given`` tests; under the
conftest stub they degrade to a seeded sweep instead of skipping (the
tests/test_dist_properties.py pattern), so tier-1 keeps the coverage.
"""

import random

import hypothesis
import pytest

import jax.numpy as jnp
import numpy as np

from repro.kernels.merge_sort import argsort, trace_launches
from repro.kernels.tile_scan import histogram_offsets, tile_scan

HAVE_HYPOTHESIS = hasattr(hypothesis, "__version__")


# ---------------------------------------------------------------------------
# check bodies (shared between the hypothesis and the seeded paths)
# ---------------------------------------------------------------------------

def check_scan(vals, block, inclusive):
    vals = np.asarray(vals, np.int32)
    out = np.asarray(tile_scan(jnp.asarray(vals), block=block,
                               inclusive=inclusive))
    ref = np.cumsum(vals, dtype=np.int32)
    if not inclusive:
        ref = ref - vals
    np.testing.assert_array_equal(out, ref)


def check_offsets(hist):
    """offsets[t, d] = #(smaller digit anywhere) + #(same digit, earlier
    tile) — the exclusive scan of the histogram flattened digit-major."""
    hist = np.asarray(hist, np.int32)
    nt, r = hist.shape
    offs = np.asarray(histogram_offsets(jnp.asarray(hist), block=64))
    ref = np.empty_like(hist)
    for t in range(nt):
        for d in range(r):
            ref[t, d] = hist[:, :d].sum() + hist[:t, d].sum()
    np.testing.assert_array_equal(offs, ref)


def check_multi_tile_stable(keys, tile=256, num_key_bits=8):
    """End-to-end: the multi-tile argsort must equal numpy's stable argsort
    — equal keys straddling tile boundaries keep their original order only
    if the carry scan assigns disjoint, correctly-ordered destination
    windows to every (tile, digit) segment."""
    keys = np.asarray(keys, np.int32)
    got = np.asarray(argsort(jnp.asarray(keys), num_key_bits=num_key_bits,
                             tile=tile, strategy="multi_tile"))
    np.testing.assert_array_equal(got, np.argsort(keys, kind="stable"))


# ---------------------------------------------------------------------------
# deterministic adversarial cases (always run)
# ---------------------------------------------------------------------------

def test_scan_single_launch_any_n():
    for n in (1, 5, 256, 1000, 4096):
        with trace_launches() as tr:
            tile_scan(jnp.ones((n,), jnp.int32), block=64)
        assert [r.kind for r in tr] == ["tile_scan"]


def test_scan_max_monoid():
    rng = np.random.default_rng(0)
    vals = rng.integers(-1000, 1000, 777).astype(np.int32)
    out = np.asarray(tile_scan(jnp.asarray(vals), block=64,
                               combine=jnp.maximum, unit=-(2 ** 31),
                               inclusive=True))
    np.testing.assert_array_equal(out, np.maximum.accumulate(vals))


def test_all_equal_digit():
    """One digit owns everything: offsets collapse to pure tile prefix
    sums and the sort must still be the identity permutation."""
    check_offsets(np.array([[0, 7, 0], [0, 5, 0], [0, 3, 0]]))
    check_multi_tile_stable(np.full(1500, 9, np.int32))


def test_one_hot_tile():
    """All the mass of every digit sits in a single tile; every other
    tile's histogram row is zero — the carry must pass through unchanged."""
    nt, r = 6, 8
    hist = np.zeros((nt, r), np.int32)
    hist[3] = np.arange(1, r + 1)
    check_offsets(hist)
    keys = np.zeros(8 * 256, np.int32)
    keys[3 * 256:4 * 256] = np.arange(256) % 7 + 1      # the one hot tile
    check_multi_tile_stable(keys)


@pytest.mark.parametrize("n", [1, 2, 3, 17, 100, 255, 257, 1000, 1025,
                               2047, 3000])
def test_non_power_of_two_n_sweep(n):
    rng = np.random.default_rng(n)
    check_multi_tile_stable(rng.integers(0, 50, n).astype(np.int32))
    check_scan(rng.integers(0, 100, n).astype(np.int32), 64, False)


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import given, strategies as st

    @given(st.lists(st.integers(0, 1000), min_size=1, max_size=600),
           st.sampled_from([16, 64, 256]), st.booleans())
    def test_scan_matches_cumsum(vals, block, inclusive):
        check_scan(vals, block, inclusive)

    @given(st.integers(1, 8), st.integers(1, 16), st.data())
    def test_offsets_match_bruteforce(nt, r, draw):
        hist = draw.draw(st.lists(
            st.lists(st.integers(0, 50), min_size=r, max_size=r),
            min_size=nt, max_size=nt))
        check_offsets(hist)

    @given(st.lists(st.integers(0, 255), min_size=1, max_size=2000))
    def test_multi_tile_stable_across_boundaries(keys):
        check_multi_tile_stable(keys)
else:
    _RNG = random.Random(0)
    _SCAN_CASES = [( [_RNG.randint(0, 1000) for _ in range(_RNG.randint(1, 600))],
                     _RNG.choice([16, 64, 256]), _RNG.random() < 0.5)
                   for _ in range(20)]
    _HIST_CASES = []
    for _ in range(20):
        nt, r = _RNG.randint(1, 8), _RNG.randint(1, 16)
        _HIST_CASES.append([[_RNG.randint(0, 50) for _ in range(r)]
                            for _ in range(nt)])
    _KEY_CASES = [[_RNG.randint(0, 255)
                   for _ in range(_RNG.randint(1, 2000))]
                  for _ in range(10)]

    @pytest.mark.parametrize("vals,block,inclusive", _SCAN_CASES)
    def test_scan_matches_cumsum(vals, block, inclusive):
        check_scan(vals, block, inclusive)

    @pytest.mark.parametrize("hist", _HIST_CASES)
    def test_offsets_match_bruteforce(hist):
        check_offsets(hist)

    @pytest.mark.parametrize("keys", _KEY_CASES)
    def test_multi_tile_stable_across_boundaries(keys):
        check_multi_tile_stable(keys)
