"""Chunked SSM scans (kernels/ssm_scan.py + the models/ssm.py switch).

The contract under test: for any monoid, ``tree_scan``/``batched_scan``
equal ``jax.lax.associative_scan`` seeded with ``carry0`` — in ONE launch —
and flipping ``scan_impl="lax" → "pallas"`` on a model changes launch
structure, never tokens.  With real ``hypothesis`` the properties run as
``@given`` tests; under the conftest stub they degrade to a seeded sweep
(the tests/test_tile_scan.py pattern), so tier-1 keeps the coverage.
"""

import dataclasses
import random

import hypothesis
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.launch_trace import trace_launches
from repro.kernels.ssm_scan import (AFFINE_UNITS, LOGSPACE_UNITS,
                                    affine_combine, logspace_affine_combine,
                                    mamba_assoc_scan, mamba_assoc_scan_ref,
                                    mamba_seq_scan_ref, mlstm_carry_scan,
                                    mlstm_carry_scan_ref)
from repro.kernels.tile_scan import batched_scan, tree_scan

HAVE_HYPOTHESIS = hasattr(hypothesis, "__version__")

EOS = 2


def _fp32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


def _affine_inputs(seed, B, L, Di, N, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    dA = jnp.exp(-jax.nn.softplus(
        jax.random.normal(k1, (B, L, Di, N)))).astype(dtype)
    dBx = (0.1 * jax.random.normal(k2, (B, L, Di, N))).astype(dtype)
    h0 = jax.random.normal(k3, (B, Di, N)).astype(dtype)
    return dA, dBx, h0


# ---------------------------------------------------------------------------
# check bodies (shared between the hypothesis and the seeded paths)
# ---------------------------------------------------------------------------

def check_mamba_equiv(seed, L, block, dtype=jnp.float32, atol=1e-5):
    dA, dBx, h0 = _affine_inputs(seed, 2, L, 4, 4, dtype)
    got = mamba_assoc_scan(dA, dBx, h0, block=block, fblock=64)
    want = mamba_assoc_scan_ref(dA.astype(jnp.float32),
                                dBx.astype(jnp.float32),
                                h0.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=atol, rtol=atol)
    seq = mamba_seq_scan_ref(dA.astype(jnp.float32),
                             dBx.astype(jnp.float32),
                             h0.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(want), np.asarray(seq),
                               atol=atol, rtol=atol)


def check_logspace_equiv(la, mS, seed=0, block=4):
    """Exclusive mlstm carry scan vs the sequential-fold oracle.  ``la``
    and ``mS`` come from the caller (the adversarial axis — gate log-sums
    of arbitrary magnitude); C/n are well-scaled randoms."""
    la = jnp.asarray(la, jnp.float32).reshape(-1, 1, 1)
    mS = jnp.asarray(mS, jnp.float32).reshape(-1, 1, 1)
    nc, B, H, dh = la.shape[0], 1, 1, 4
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    Chat = jax.random.normal(k1, (nc, B, H, dh, dh))
    nhat = jax.random.normal(k2, (nc, B, H, dh))
    carry0 = (jax.random.normal(k3, (B, H)),
              jax.random.normal(k4, (B, H, dh, dh)),
              jnp.zeros((B, H, dh)))
    got = mlstm_carry_scan(la, mS, Chat, nhat, carry0, block=block)
    want = mlstm_carry_scan_ref(la, mS, Chat, nhat, carry0)
    for g, w in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        assert np.all(np.isfinite(g)), "stabilized scan went non-finite"
        # m entries are log-scale and can be huge; compare with rtol on
        # the magnitude so ±1e30-ish log-zeros still match exactly.
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel-level: equivalence, padding, carries, dtypes, launch count
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L", [1, 2, 16, 63, 64, 65, 300, 1024])
def test_mamba_matches_assoc_scan(L):
    # block=16 forces cross-chunk carries from L=17 up; non-pow2 lengths
    # exercise the identity-padding path.
    check_mamba_equiv(L, L, block=16)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 5e-2)])
def test_mamba_dtypes(dtype, atol):
    check_mamba_equiv(7, 130, block=32, dtype=dtype, atol=atol)


def test_int_sum_monoid():
    """batched_scan is monoid-generic: int32 cumsum as a 1-leaf tree."""
    rng = np.random.default_rng(0)
    vals = rng.integers(-50, 50, (2, 257, 3)).astype(np.int32)
    (out,) = batched_scan((jnp.asarray(vals),),
                          combine=lambda a, b: (a[0] + b[0],),
                          units=(0,), block=32, kind="ssm_scan")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.cumsum(vals, axis=1, dtype=np.int32))


def test_exclusive_and_carry0():
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.integers(0, 9, (1, 77, 2)).astype(np.int32))
    c0 = jnp.asarray([[100, 200]], jnp.int32)
    (out,) = batched_scan((vals,), combine=lambda a, b: (a[0] + b[0],),
                          units=(0,), carry0=(c0,), inclusive=False,
                          block=16, kind="ssm_scan")
    ref = np.cumsum(np.asarray(vals), axis=1) - np.asarray(vals) \
        + np.asarray(c0)[:, None]
    np.testing.assert_array_equal(np.asarray(out), ref)


@pytest.mark.parametrize("L", [1, 5, 64, 257, 1000])
def test_single_launch_any_length(L):
    dA, dBx, h0 = _affine_inputs(L, 1, L, 2, 2)
    with trace_launches() as tr:
        batched_scan((dA, dBx), combine=affine_combine, units=AFFINE_UNITS,
                     carry0=(jnp.ones_like(h0), h0), kind="ssm_scan",
                     block=64)
    assert [r.kind for r in tr] == ["ssm_scan"]


def test_tree_scan_single_launch():
    la = jnp.zeros((20, 1, 1))
    with trace_launches() as tr:
        tree_scan((la, la - 5.0,
                   jnp.ones((20, 1, 1, 2, 2)), jnp.ones((20, 1, 1, 2))),
                  combine=logspace_affine_combine, units=LOGSPACE_UNITS,
                  inclusive=False, block=8, kind="ssm_scan")
    assert [r.kind for r in tr] == ["ssm_scan"]


def test_logspace_monoid_extreme_magnitudes():
    """Gate log-sums at ±1e3 (raw exp would overflow at ~88): the max-
    rebased combine must stay finite and still match the fold oracle."""
    check_logspace_equiv([1e3, -1e3, 500.0, 0.0, -700.0, 300.0, 88.0],
                         [-1e3, 1e3, -500.0, 700.0, 0.0, -88.0, 2.0])


# ---------------------------------------------------------------------------
# model-level: scan_impl="pallas" == "lax" per layer
# ---------------------------------------------------------------------------

def _smoke(arch):
    from repro.configs.registry import get_smoke_config
    from repro.models.model import Model
    cfg = _fp32(get_smoke_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _layer_params(model, params, kind):
    for spec, lp in zip(model.period_specs, params["stage"]):
        if spec.kind == kind:
            return jax.tree.map(lambda x: x[0], lp)["mixer"]
    raise AssertionError(f"no {kind} layer in smoke config")


def test_mamba_forward_scan_impl_equiv():
    from repro.models.ssm import mamba_forward
    model, params = _smoke("jamba-1.5-large-398b")
    lp = _layer_params(model, params, "mamba")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, model.cfg.d_model))
    y_lax, st_lax = mamba_forward(lp, model.cfg, x, scan_impl="lax")
    with trace_launches() as tr:
        y_pal, st_pal = mamba_forward(lp, model.cfg, x, scan_impl="pallas")
    assert sum(1 for r in tr if r.kind == "ssm_scan") >= 1
    np.testing.assert_allclose(np.asarray(y_lax), np.asarray(y_pal),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st_lax["ssm"]),
                               np.asarray(st_pal["ssm"]),
                               atol=1e-5, rtol=1e-5)


def test_mlstm_forward_scan_impl_equiv():
    from repro.models.ssm import mlstm_forward
    model, params = _smoke("xlstm-1.3b")
    lp = _layer_params(model, params, "mlstm")
    # S = 4 chunks of 16 → the chunked carry-scan path on both impls
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, model.cfg.d_model))
    y_lax, st_lax = mlstm_forward(lp, model.cfg, x, scan_impl="lax")
    with trace_launches() as tr:
        y_pal, st_pal = mlstm_forward(lp, model.cfg, x, scan_impl="pallas")
    assert sum(1 for r in tr if r.kind == "ssm_scan") == 1
    np.testing.assert_allclose(np.asarray(y_lax), np.asarray(y_pal),
                               atol=1e-4, rtol=1e-4)
    for k in st_lax:
        np.testing.assert_allclose(
            np.asarray(st_lax[k]).astype(np.float32),
            np.asarray(st_pal[k]).astype(np.float32),
            atol=1e-4, rtol=1e-4, err_msg=k)


def test_scan_impl_validated():
    from repro.models.model import Model
    from repro.configs.registry import get_smoke_config
    with pytest.raises(ValueError):
        Model(_fp32(get_smoke_config("xlstm-1.3b")), scan_impl="nope")


# ---------------------------------------------------------------------------
# serving: SSM state slots + entropy-gated early exit
# ---------------------------------------------------------------------------

def _serve(model, params, prompts, exit_entropy, scan_impl=None):
    from repro.serve.engine import ContinuousEngine, EngineConfig, Request
    eng = ContinuousEngine(model, params, EngineConfig(
        max_batch=2, max_seq=96, eos_id=EOS, decode_tick=4, page_size=16,
        exit_entropy=exit_entropy))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=12))
    done = []
    while eng.pending:
        done += eng.step()
    return {r.rid: np.asarray(r.result) for r in done}, eng


def test_ssm_decode_serving():
    """One xlstm smoke model served three ways: pallas ungated (reference),
    lax ungated (tokens must match exactly — scan_impl never changes
    tokens), and pallas gated (exact prefix, fewer steps, gate fired)."""
    from repro.configs.registry import get_smoke_config
    from repro.models.model import Model
    from repro.serve.engine import Request

    cfg = _fp32(get_smoke_config("xlstm-1.3b"))
    pal = Model(cfg, scan_impl="pallas")
    params = pal.init(jax.random.PRNGKey(0))
    lax_m = Model(cfg, scan_impl="lax")

    rng = np.random.RandomState(0)
    prompts = [rng.randint(3, cfg.vocab_size,
                           size=rng.randint(5, 30)).astype(np.int32)
               for _ in range(4)]

    # recurrent-only model → O(1) state slots, independent of prompt length
    assert pal.recurrent_only
    from repro.serve.engine import ContinuousEngine, EngineConfig
    eng = ContinuousEngine(pal, params, EngineConfig(
        max_batch=2, max_seq=96, eos_id=EOS, page_size=16))
    for p in prompts:
        assert eng._slot_span(Request(rid=0, prompt=p, max_new=12)) == 16

    base, eng0 = _serve(pal, params, prompts, None)
    lax_res, _ = _serve(lax_m, params, prompts, None)
    assert set(base) == set(lax_res)
    for k in base:
        np.testing.assert_array_equal(base[k], lax_res[k])

    gated, eng1 = _serve(pal, params, prompts, 8.0)
    assert eng1.telemetry.early_exits > 0
    assert eng1.telemetry.decode_steps < eng0.telemetry.decode_steps
    for k in base:
        np.testing.assert_array_equal(gated[k], base[k][:len(gated[k])])


def test_attention_model_not_recurrent_only():
    from repro.configs.registry import get_smoke_config
    from repro.models.model import Model
    assert not Model(_fp32(get_smoke_config("jamba-1.5-large-398b"))
                     ).recurrent_only


def test_gated_tick_matches_ungated_until_gate():
    """The gated tick's per-step token choice is the ungated argmax —
    gating only stops emission (the exactness property the benchmark
    pins), checked at the tick level with an impossible-to-fire gate."""
    from repro.configs.registry import get_smoke_config
    from repro.models.model import Model

    cfg = _fp32(get_smoke_config("xlstm-1.3b"))
    model = Model(cfg, scan_impl="pallas")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [rng.randint(3, cfg.vocab_size, size=9).astype(np.int32)
               for _ in range(2)]
    # tau=0: entropy is never < 0, the gate can never fire — the gated
    # engine must reproduce the ungated run token-for-token.
    base, eng0 = _serve(model, params, prompts, None)
    never, eng1 = _serve(model, params, prompts, 1e-9)
    assert eng1.telemetry.early_exits == 0
    for k in base:
        np.testing.assert_array_equal(base[k], never[k])


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 200), st.sampled_from([8, 16, 64]),
           st.integers(0, 10 ** 6))
    def test_affine_scan_property(L, block, seed):
        check_mamba_equiv(seed, L, block)

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.floats(-1e3, 1e3), min_size=1, max_size=24),
           st.data())
    def test_logspace_scan_property(la, draw):
        mS = draw.draw(st.lists(st.floats(-1e3, 1e3), min_size=len(la),
                                max_size=len(la)))
        check_logspace_equiv(la, mS)
else:
    _RNG = random.Random(0)
    _AFFINE_CASES = [(_RNG.randint(0, 10 ** 6), _RNG.randint(1, 200),
                      _RNG.choice([8, 16, 64])) for _ in range(12)]
    _LOG_CASES = []
    for _ in range(12):
        n = _RNG.randint(1, 24)
        _LOG_CASES.append(([_RNG.uniform(-1e3, 1e3) for _ in range(n)],
                           [_RNG.uniform(-1e3, 1e3) for _ in range(n)]))

    @pytest.mark.parametrize("seed,L,block", _AFFINE_CASES)
    def test_affine_scan_property(seed, L, block):
        check_mamba_equiv(seed, L, block)

    @pytest.mark.parametrize("la,mS", _LOG_CASES)
    def test_logspace_scan_property(la, mS):
        check_logspace_equiv(la, mS)
