"""Shared test config.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py forces 512 host devices."""

from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro",
    deadline=None,
    max_examples=50,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("repro")
