"""Shared test config.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py forces 512 host devices.

``hypothesis`` is an optional test dependency: when it is not installed we
register a minimal stub into ``sys.modules`` so test modules that do
``from hypothesis import given`` still *collect*, and every ``@given``
property test individually skips instead of killing the whole run at
collection time.  (Property tests that must run regardless detect the stub
via the missing ``__version__`` and fall back to seeded parametrization —
see tests/test_dist_properties.py.)
"""

import sys
import types

import pytest


class ShapeOnlyMesh:
    """Stand-in for a Mesh wherever only axis *sizes* matter (the
    sanitize/zero1 spec algebra) — lets those tests run on a 1-device
    host.  Shared by test_sharding.py and test_dist_properties.py."""

    def __init__(self, **axes):
        self.shape = dict(axes)


try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=50,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("repro")
except ModuleNotFoundError:
    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement: pytest must not see the strategy
            # parameters of the original function as fixtures
            def skipper():
                pytest.skip("hypothesis not installed; property test skipped")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    class _Settings:
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    class _HealthCheck:
        too_slow = "too_slow"
        filter_too_much = "filter_too_much"
        data_too_large = "data_too_large"

    class _Strategy:
        """Inert placeholder accepting the whole strategies combinator API."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _Settings
    hyp.HealthCheck = _HealthCheck
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.example = lambda *a, **k: (lambda fn: fn)

    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: _Strategy()

    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
