"""Shared test config.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py forces 512 host devices.

``hypothesis`` is an optional test dependency: when it is not installed we
register a minimal stub into ``sys.modules`` so test modules that do
``from hypothesis import given`` still *collect*, and every ``@given``
property test individually skips instead of killing the whole run at
collection time.

``repro.dist`` is missing from the seed tree (see ROADMAP open items): the
test modules and tests that need it are skipped — not errored — while the
gap persists, so the rest of the suite stays runnable under ``-x``.  Both
guards are keyed on module availability and vanish once the dependency
exists.
"""

import importlib.util
import sys
import types

import pytest

_HAVE_DIST = importlib.util.find_spec("repro.dist") is not None

if not _HAVE_DIST:
    # these import repro.dist (directly or via repro.train.step /
    # repro.launch) at module level and cannot collect without it
    collect_ignore = ["test_analysis.py", "test_dist.py", "test_models.py",
                      "test_sharding.py", "test_train.py"]

    @pytest.hookimpl(wrapper=True)
    def pytest_runtest_call(item):
        # model-stack tests import repro.dist lazily inside the call;
        # translate exactly that known seed gap into a skip
        try:
            return (yield)
        except ModuleNotFoundError as e:
            if e.name is not None and e.name.startswith("repro.dist"):
                raise pytest.skip.Exception(
                    f"seed gap, see ROADMAP: {e}") from e
            raise

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=50,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("repro")
except ModuleNotFoundError:
    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement: pytest must not see the strategy
            # parameters of the original function as fixtures
            def skipper():
                pytest.skip("hypothesis not installed; property test skipped")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    class _Settings:
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    class _HealthCheck:
        too_slow = "too_slow"
        filter_too_much = "filter_too_much"
        data_too_large = "data_too_large"

    class _Strategy:
        """Inert placeholder accepting the whole strategies combinator API."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _Settings
    hyp.HealthCheck = _HealthCheck
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.example = lambda *a, **k: (lambda fn: fn)

    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: _Strategy()

    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
