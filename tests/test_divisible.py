"""Property tests for the Divisible trait and adaptors (paper §3.1/§3.3)."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (BatchWork, Cap, PermRange, SeqWork, TileGrid2D,
                        WorkRange, ZipDivisible, bound_depth, build_plan, cap,
                        even_levels, force_depth, join_context, size_limit,
                        thief_splitting, total_permutations)


# ---------------------------------------------------------------------------
# Divisible invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(1, 10_000), st.integers(0, 10_000))
def test_divide_at_partitions(start, size, idx):
    w = WorkRange(start, start + size)
    l, r = w.divide_at(idx)
    assert l.size() + r.size() == w.size()
    assert l.start == w.start and r.stop == w.stop and l.stop == r.start


@given(st.integers(0, 10_000), st.integers(1, 10_000))
def test_divide_balanced(start, size):
    w = WorkRange(start, start + size)
    l, r = w.divide()
    assert abs(l.size() - r.size()) <= 1
    assert l.size() + r.size() == size


@given(st.integers(1, 4096), st.integers(1, 64))
def test_seqwork_alignment(size, align):
    w = SeqWork(0, size, align=align)
    if w.should_be_divided():
        l, r = w.divide()
        assert l.size() % align == 0 or r.size() == 0 or l.size() == size


@given(st.integers(1, 500), st.integers(1, 500))
def test_tilegrid_divides_longest(rows, cols):
    g = TileGrid2D(WorkRange(0, rows), WorkRange(0, cols))
    if g.should_be_divided():
        l, r = g.divide()
        assert l.size() + r.size() == g.size()


@given(st.integers(2, 1000))
def test_zip_lockstep(n):
    z = ZipDivisible((WorkRange(0, n), WorkRange(100, 100 + n)))
    l, r = z.divide()
    assert l.parts[0].size() == l.parts[1].size()
    assert l.parts[0].size() + r.parts[0].size() == n


# ---------------------------------------------------------------------------
# Plans cover the work exactly (no loss, no overlap)
# ---------------------------------------------------------------------------

def leaves_cover(plan, start, stop):
    leaves = sorted(plan.leaves(), key=lambda w: w.start)
    pos = start
    for w in leaves:
        assert w.start == pos, "gap or overlap"
        pos = w.stop
    assert pos == stop


@given(st.integers(1, 100_000), st.integers(0, 8))
@settings(max_examples=60)
def test_bound_depth_coverage_and_count(n, d):
    plan = build_plan(bound_depth(WorkRange(0, n), d))
    leaves_cover(plan, 0, n)
    assert plan.num_tasks() <= 2 ** d
    assert plan.depth() <= d


@given(st.integers(1, 20_000), st.integers(4, 1000))
def test_size_limit(n, lim):
    plan = build_plan(size_limit(WorkRange(0, n), lim))
    leaves_cover(plan, 0, n)
    # every leaf obeys the limit unless it was indivisible
    for w in plan.leaves():
        assert w.size() <= max(lim, 1) or w.size() == 1


@given(st.integers(2, 10_000), st.integers(1, 6))
def test_force_depth_complete_tree(n, d):
    if n < 2 ** d:
        return
    plan = build_plan(force_depth(WorkRange(0, n, min_size=n), d))
    # base refuses division (min_size=n) but force_depth insists
    assert plan.num_tasks() == 2 ** d
    leaves_cover(plan, 0, n)


@given(st.integers(4, 10_000))
def test_even_levels_parity(n):
    plan = build_plan(even_levels(bound_depth(WorkRange(0, n), 3)))
    for node in plan.root.leaves():
        assert node.depth % 2 == 0
    leaves_cover(plan, 0, n)


@given(st.integers(1, 10_000), st.integers(1, 64))
def test_cap_bounds_tasks(n, threshold):
    plan = build_plan(cap(WorkRange(0, n), threshold))
    assert plan.num_tasks() <= max(threshold, 1)
    leaves_cover(plan, 0, n)


@given(st.integers(1, 100_000), st.integers(1, 64))
def test_thief_splitting_static_task_count(n, p):
    """Without steals: 2^init tasks (counter halving), the TBB bound."""
    w = thief_splitting(WorkRange(0, n), p=p)
    plan = build_plan(w)
    leaves_cover(plan, 0, n)
    import math
    init = int(math.log2(max(2, p))) + 1
    assert plan.num_tasks() <= 2 ** init


@given(st.integers(2, 10_000), st.integers(1, 8))
def test_join_context_left_spine(n, d):
    """Right children don't divide unless stolen → leaf count = depth+1."""
    plan = build_plan(join_context(WorkRange(0, n), d))
    leaves_cover(plan, 0, n)
    assert plan.num_tasks() <= d + 1


# ---------------------------------------------------------------------------
# PermRange (fannkuch structure, paper §4.3)
# ---------------------------------------------------------------------------

@given(st.integers(3, 7))
def test_perm_range_iterates_all(n):
    total = total_permutations(n)
    pr = PermRange(n, 0, total)
    seen = []
    pr.partial_fold(None, lambda s, p: seen.append(tuple(p)), total)
    assert len(seen) == total
    assert len(set(seen)) == total          # all distinct
    assert seen == sorted(seen)             # lexicographic


@given(st.integers(3, 7), st.integers(0, 100))
def test_perm_range_divide_consistency(n, cut):
    total = total_permutations(n)
    cut = cut % max(1, total)
    l, r = PermRange(n, 0, total).divide_at(cut)
    out_l, out_r = [], []
    l.partial_fold(None, lambda s, p: out_l.append(tuple(p)), total)
    r.partial_fold(None, lambda s, p: out_r.append(tuple(p)), total)
    full = []
    PermRange(n, 0, total).partial_fold(
        None, lambda s, p: full.append(tuple(p)), total)
    assert out_l + out_r == full
