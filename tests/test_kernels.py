"""Per-kernel allclose tests: shape/dtype sweeps against the jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import combine_partials, flash_decode
from repro.kernels.merge_sort import argsort, merge_pair, sort_u32, tile_sort


def rnd(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) \
        .astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA 2:1
    (1, 512, 8, 2, 32),      # GQA 4:1
    (2, 128, 6, 1, 128),     # MQA-ish, hd=128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal):
    q = rnd(0, (B, S, H, hd), dtype)
    k = rnd(1, (B, S, KV, hd), dtype)
    v = rnd(2, (B, S, KV, hd), dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                        interpret=True)
    o_ref = ref.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_invariance(bq, bk):
    q = rnd(0, (1, 256, 2, 64), jnp.float32)
    k = rnd(1, (1, 256, 2, 64), jnp.float32)
    v = rnd(2, (1, 256, 2, 64), jnp.float32)
    o = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                        interpret=True)
    o_ref = ref.attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,bk", [
    (2, 512, 4, 2, 64, 128),
    (1, 1024, 8, 8, 64, 256),
    (3, 256, 4, 1, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, S, H, KV, hd, bk, dtype):
    q = rnd(3, (B, H, hd), dtype)
    kc = rnd(4, (B, S, KV, hd), dtype)
    vc = rnd(5, (B, S, KV, hd), dtype)
    lengths = jnp.asarray(
        np.random.RandomState(0).randint(1, S + 1, B), jnp.int32)
    o = flash_decode(q, kc, vc, lengths, block_k=bk, interpret=True)
    o_ref = ref.decode_attention_reference(q, kc, vc, lengths)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


def test_flash_decode_demand_split_invariance():
    """The reduction-tree shape must not change the result (associativity)."""
    q = rnd(6, (2, 4, 64), jnp.float32)
    kc = rnd(7, (2, 1024, 2, 64), jnp.float32)
    vc = rnd(8, (2, 1024, 2, 64), jnp.float32)
    lengths = jnp.asarray([700, 1024], jnp.int32)
    outs = [flash_decode(q, kc, vc, lengths, block_k=128, demand=d,
                         interpret=True) for d in (1, 2, 4, 8)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)


def test_combine_partials_associative():
    k1, k2, k3 = (rnd(i, (2, 4), jnp.float32) for i in (10, 11, 12))
    a1, a2, a3 = (rnd(i, (2, 4, 8), jnp.float32) for i in (13, 14, 15))
    l1, l2, l3 = (jnp.abs(rnd(i, (2, 4), jnp.float32)) for i in (16, 17, 18))
    p1, p2, p3 = (k1, l1, a1), (k2, l2, a2), (k3, l3, a3)
    left = combine_partials(combine_partials(p1, p2), p3)
    right = combine_partials(p1, combine_partials(p2, p3))
    for a, b in zip(left, right):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# merge sort
# ---------------------------------------------------------------------------

@given(st.integers(1, 4096), st.integers(1, 12), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_argsort_matches_stable_oracle(n, key_bits, seed):
    keys = np.random.RandomState(seed).randint(
        0, 1 << key_bits, n).astype(np.int32)
    order = argsort(jnp.asarray(keys), tile=256, interpret=True)
    expect = ref.stable_argsort_reference(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(order), np.asarray(expect))


@pytest.mark.parametrize("n,tile", [(256, 64), (1024, 256), (4096, 512),
                                    (4096, 1024)])
def test_sort_u32_sorted(n, tile):
    x = jnp.asarray(np.random.RandomState(0).randint(
        0, 2 ** 31, n).astype(np.uint32))
    out = sort_u32(x, tile=tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


def test_tile_sort_sorts_each_tile():
    x = jnp.asarray(np.random.RandomState(1).randint(
        0, 1000, 512).astype(np.uint32))
    out = np.asarray(tile_sort(x, tile=128, interpret=True))
    for t in range(4):
        tile = out[t * 128:(t + 1) * 128]
        assert (np.diff(tile) >= 0).all()


def test_merge_pair_merges():
    a = np.sort(np.random.RandomState(2).randint(0, 1 << 20, 256)) \
        .astype(np.uint32)
    b = np.sort(np.random.RandomState(3).randint(0, 1 << 20, 256)) \
        .astype(np.uint32)
    out = merge_pair(jnp.asarray(a), jnp.asarray(b), interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.sort(np.concatenate([a, b])))


def test_argsort_stability_heavy_duplicates():
    keys = np.zeros(1000, np.int32)          # all equal → order == identity
    order = argsort(jnp.asarray(keys), tile=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(order), np.arange(1000))
