"""Per-kernel allclose tests: shape/dtype sweeps against the jnp oracles."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.kernels import ref
from repro.kernels import merge_sort
from repro.kernels import radix_sort
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import combine_partials, flash_decode
from repro.kernels.merge_sort import argsort, merge_pair, sort_u32, tile_sort


def rnd(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) \
        .astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA 2:1
    (1, 512, 8, 2, 32),      # GQA 4:1
    (2, 128, 6, 1, 128),     # MQA-ish, hd=128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal):
    q = rnd(0, (B, S, H, hd), dtype)
    k = rnd(1, (B, S, KV, hd), dtype)
    v = rnd(2, (B, S, KV, hd), dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                        interpret=True)
    o_ref = ref.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_invariance(bq, bk):
    q = rnd(0, (1, 256, 2, 64), jnp.float32)
    k = rnd(1, (1, 256, 2, 64), jnp.float32)
    v = rnd(2, (1, 256, 2, 64), jnp.float32)
    o = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                        interpret=True)
    o_ref = ref.attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,bk", [
    (2, 512, 4, 2, 64, 128),
    (1, 1024, 8, 8, 64, 256),
    (3, 256, 4, 1, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, S, H, KV, hd, bk, dtype):
    q = rnd(3, (B, H, hd), dtype)
    kc = rnd(4, (B, S, KV, hd), dtype)
    vc = rnd(5, (B, S, KV, hd), dtype)
    lengths = jnp.asarray(
        np.random.RandomState(0).randint(1, S + 1, B), jnp.int32)
    o = flash_decode(q, kc, vc, lengths, block_k=bk, interpret=True)
    o_ref = ref.decode_attention_reference(q, kc, vc, lengths)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


def test_flash_decode_demand_split_invariance():
    """The reduction-tree shape must not change the result (associativity)."""
    q = rnd(6, (2, 4, 64), jnp.float32)
    kc = rnd(7, (2, 1024, 2, 64), jnp.float32)
    vc = rnd(8, (2, 1024, 2, 64), jnp.float32)
    lengths = jnp.asarray([700, 1024], jnp.int32)
    outs = [flash_decode(q, kc, vc, lengths, block_k=128, demand=d,
                         interpret=True) for d in (1, 2, 4, 8)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)


def test_combine_partials_associative():
    k1, k2, k3 = (rnd(i, (2, 4), jnp.float32) for i in (10, 11, 12))
    a1, a2, a3 = (rnd(i, (2, 4, 8), jnp.float32) for i in (13, 14, 15))
    l1, l2, l3 = (jnp.abs(rnd(i, (2, 4), jnp.float32)) for i in (16, 17, 18))
    p1, p2, p3 = (k1, l1, a1), (k2, l2, a2), (k3, l3, a3)
    left = combine_partials(combine_partials(p1, p2), p3)
    right = combine_partials(p1, combine_partials(p2, p3))
    for a, b in zip(left, right):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# merge sort
# ---------------------------------------------------------------------------

@given(st.integers(1, 4096), st.integers(1, 12), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_argsort_matches_stable_oracle(n, key_bits, seed):
    keys = np.random.RandomState(seed).randint(
        0, 1 << key_bits, n).astype(np.int32)
    order = argsort(jnp.asarray(keys), tile=256, interpret=True)
    expect = ref.stable_argsort_reference(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(order), np.asarray(expect))


@pytest.mark.parametrize("n,tile", [(256, 64), (1024, 256), (4096, 512),
                                    (4096, 1024)])
def test_sort_u32_sorted(n, tile):
    x = jnp.asarray(np.random.RandomState(0).randint(
        0, 2 ** 31, n).astype(np.uint32))
    out = sort_u32(x, tile=tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


def test_tile_sort_sorts_each_tile():
    x = jnp.asarray(np.random.RandomState(1).randint(
        0, 1000, 512).astype(np.uint32))
    out = np.asarray(tile_sort(x, tile=128, interpret=True))
    for t in range(4):
        tile = out[t * 128:(t + 1) * 128]
        assert (np.diff(tile) >= 0).all()


def test_merge_pair_merges():
    a = np.sort(np.random.RandomState(2).randint(0, 1 << 20, 256)) \
        .astype(np.uint32)
    b = np.sort(np.random.RandomState(3).randint(0, 1 << 20, 256)) \
        .astype(np.uint32)
    out = merge_pair(jnp.asarray(a), jnp.asarray(b), interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.sort(np.concatenate([a, b])))


def test_argsort_stability_heavy_duplicates():
    keys = np.zeros(1000, np.int32)          # all equal → order == identity
    order = argsort(jnp.asarray(keys), tile=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(order), np.arange(1000))


# ---------------------------------------------------------------------------
# level-batched merge-path sort (PR 2 tentpole)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,tile", [(1 << 12, 256), (1 << 14, 1024),
                                    (1 << 16, 1024)])
def test_merge_tree_launch_count_pinned(n, tile):
    """The merge tree must run in exactly log2(n/tile) pallas_call launches
    (plus the single tile-sort launch) with every merge block ≤ 2·tile
    elements, independent of n — the level-batched structure, pinned."""
    x = jnp.asarray(np.random.RandomState(0).randint(
        0, 2 ** 31, n).astype(np.uint32))
    with merge_sort.trace_launches() as tr:
        out = sort_u32(x, tile=tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))
    kinds = [r.kind for r in tr]
    assert kinds.count("tile_sort") == 1
    assert kinds.count("merge_level") == int(math.log2(n // tile))
    assert len(tr) == 1 + int(math.log2(n // tile))
    for rec in tr:
        if rec.kind == "merge_level":
            assert rec.max_block_elems <= 2 * tile
        else:       # radix tile sort groups ≤ 8 tiles per grid cell
            assert rec.max_block_elems <= 8 * tile
    # level L merges 2^L-tile runs: grid=(num_pairs, blocks_per_pair)
    for L, rec in enumerate(r for r in tr if r.kind == "merge_level"):
        run = tile << L
        assert rec.grid == (n // (2 * run), (2 * run) // tile)


def test_merge_level_matches_reference_merge():
    """One level kernel call over several pairs == per-pair np.merge."""
    rng = np.random.RandomState(7)
    tile, run, num_pairs = 64, 256, 4
    runs = np.sort(rng.randint(0, 1 << 30, (num_pairs, 2, run)).astype(
        np.uint32), axis=-1)
    x = jnp.asarray(runs.reshape(-1))
    out = np.asarray(merge_sort._merge_level(
        x, run=run, tile=tile, interpret=True)).reshape(num_pairs, 2 * run)
    for p in range(num_pairs):
        expect = np.sort(np.concatenate([runs[p, 0], runs[p, 1]]))
        np.testing.assert_array_equal(out[p], expect)


def test_merge_path_starts_corank_invariants():
    """Co-rank splits: monotone, diagonal-consistent, and exact on a known
    stable merge (ties go to A)."""
    rng = np.random.RandomState(3)
    run, tile = 128, 32
    a = np.sort(rng.randint(0, 16, run).astype(np.uint32))
    b = np.sort(rng.randint(0, 16, run).astype(np.uint32))
    ab = jnp.asarray(np.stack([a, b])[None])
    a_start, b_start, la = (np.asarray(v) for v in
                            merge_sort._merge_path_starts(ab, run, tile))
    assert a_start.shape == (1, 2 * run // tile)
    # every diagonal splits exactly: a_start + b_start == d, lengths sum tile
    d = np.arange(2 * run // tile) * tile
    np.testing.assert_array_equal(a_start[0] + b_start[0], d)
    assert (la >= 0).all() and (la <= tile).all()
    # exact co-rank: count of A elements among first d of the stable merge
    packed = np.concatenate([a.astype(np.uint64) * 2,       # A before equal B
                             b.astype(np.uint64) * 2 + 1])
    order = np.argsort(packed, kind="stable")
    for i, dd in enumerate(d):
        expect_ia = int((order[:dd] < run).sum())
        assert a_start[0, i] == expect_ia


@pytest.mark.parametrize("n,tile", [(16, 2), (8, 1), (32, 2), (64, 1)])
def test_sort_u32_tiny_tiles_odd_depth(n, tile):
    """Odd merge depth with tiles too small to halve must still sort (the
    parity adjustment falls back to an odd schedule, regression test)."""
    x = np.random.RandomState(n).randint(0, 2 ** 31, n).astype(np.uint32)
    out = np.asarray(sort_u32(jnp.asarray(x), tile=tile, interpret=True))
    np.testing.assert_array_equal(out, np.sort(x))


@pytest.mark.parametrize("n", [1, 2, 3, 5, 255, 257, 1000, 1023, 4097])
@pytest.mark.parametrize("key_bits", [1, 3, 11])
def test_argsort_property_sweep_vs_stable_oracle(n, key_bits):
    """Non-power-of-two sizes × duplicate-heavy keys vs np stable argsort
    (explicit sweep — runs even when hypothesis is stubbed out)."""
    keys = np.random.RandomState(n * 31 + key_bits).randint(
        0, 1 << key_bits, n).astype(np.int32)
    order = argsort(jnp.asarray(keys), tile=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(order),
                                  np.argsort(keys, kind="stable"))


def test_argsort_jit_end_to_end():
    keys = np.random.RandomState(5).randint(0, 64, 777).astype(np.int32)
    order = argsort(jnp.asarray(keys), tile=256, interpret=True, jit=True)
    np.testing.assert_array_equal(np.asarray(order),
                                  np.argsort(keys, kind="stable"))


# ---------------------------------------------------------------------------
# radix tile sort + fused pack/unpack (PR 4 tentpole)
# ---------------------------------------------------------------------------

def _tile_cases(tile, seed=0):
    """Random, all-equal, and reverse-sorted tiles (the radix-vs-bitonic
    equivalence sweep the satellite asks for)."""
    rng = np.random.RandomState(seed)
    rev = np.arange(4 * tile, 0, -1, dtype=np.uint32)
    return {
        "random": rng.randint(0, 2 ** 31, 4 * tile).astype(np.uint32),
        "dup_heavy": rng.randint(0, 7, 4 * tile).astype(np.uint32),
        "all_equal": np.full(4 * tile, 123456, np.uint32),
        "reverse": rev,
    }


@pytest.mark.parametrize("tile", [64, 256, 1024])
@pytest.mark.parametrize("digit_bits", [2, 4, 8])
def test_radix_tile_sort_matches_bitonic(tile, digit_bits):
    """Generic radix tile sort ≡ the bitonic network, bit for bit, on the
    sweep including all-equal and reverse-sorted tiles."""
    for name, x in _tile_cases(tile).items():
        xj = jnp.asarray(x)
        bit = np.asarray(tile_sort(xj, tile=tile, interpret=True))
        rad = np.asarray(radix_sort.radix_tile_sort(
            xj, tile=tile, digit_bits=digit_bits, interpret=True))
        np.testing.assert_array_equal(rad, bit, err_msg=f"case {name}")


def test_radix_tile_sort_packed_rejects_malformed_schedules():
    """The kernel strides uniformly by the first pass width — schedules it
    cannot execute must raise, not silently mis-sort."""
    from repro.core import DigitPass
    keys = jnp.zeros(16, jnp.int32)
    kw = dict(n=16, tile=16, num_key_bits=6, idx_bits=4, interpret=True)
    with pytest.raises(ValueError, match="key_shift"):
        radix_sort.radix_tile_sort_packed(
            keys, passes=(DigitPass(0, 4),), **kw)
    with pytest.raises(ValueError, match="uniform stride"):
        radix_sort.radix_tile_sort_packed(
            keys, passes=(DigitPass(4, 2), DigitPass(6, 4)), **kw)
    with pytest.raises(ValueError, match="uniform stride"):
        radix_sort.radix_tile_sort_packed(
            keys, passes=(DigitPass(4, 4), DigitPass(12, 2)), **kw)
    # the well-formed schedule (narrowed last pass) is accepted
    out = radix_sort.radix_tile_sort_packed(
        keys, passes=(DigitPass(4, 4), DigitPass(8, 2)), **kw)
    assert out.shape == (16,)


def test_radix_tile_sort_respects_bit_window():
    """Bits outside [key_shift, key_shift+total_bits) must not participate
    in the ordering — the final pass narrows to the leftover bits
    (regression: a full-width last-pass digit read them)."""
    # equal low-4-bit digits, differing bit 4: order must be preserved
    x = jnp.asarray(np.asarray([0x10, 0x00], np.uint32))
    out = radix_sort.radix_tile_sort(x, tile=2, total_bits=4, digit_bits=8,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(out), [0x10, 0x00])
    # a shifted window: sort by bits [4, 8) only, low bits are tie order
    vals = np.asarray([0x23, 0x12, 0x21, 0x15], np.uint32)
    out2 = radix_sort.radix_tile_sort(jnp.asarray(vals), tile=4,
                                      total_bits=4, key_shift=4,
                                      digit_bits=3, interpret=True)
    np.testing.assert_array_equal(np.asarray(out2),
                                  [0x12, 0x15, 0x23, 0x21])


@pytest.mark.parametrize("n,tile", [(1024, 256), (4096, 1024)])
def test_fused_radix_tile_sort_matches_pack_plus_bitonic(n, tile):
    """Fused pack+radix tile sort ≡ separate pack followed by the bitonic
    tile sort (bit-identical packed words, sentinel padding included)."""
    idx_bits = max(1, (n - 1).bit_length())
    for name, keys in _tile_cases(tile, seed=3).items():
        keys = (keys[:n] & 0xFFF).astype(np.int32)
        packed = (keys.astype(np.uint32) << idx_bits) | \
            np.arange(n, dtype=np.uint32)
        bit = np.asarray(tile_sort(jnp.asarray(packed), tile=tile,
                                   interpret=True))
        fused = np.asarray(radix_sort.radix_tile_sort_packed(
            jnp.asarray(keys), n=n, tile=tile, num_key_bits=12,
            idx_bits=idx_bits, interpret=True))
        np.testing.assert_array_equal(fused, bit, err_msg=f"case {name}")


def test_argsort_fused_drops_two_elementwise_launches():
    """The fused path must run zero standalone pack/unpack launches — the
    end-to-end launch count drops by exactly those two vs fused=False."""
    keys = jnp.asarray(np.random.RandomState(0).randint(
        0, 16, 4096).astype(np.int32))
    with merge_sort.trace_launches() as tr_fused:
        a = argsort(keys, tile=512, interpret=True, strategy="merge")
    with merge_sort.trace_launches() as tr_unfused:
        b = argsort(keys, tile=512, interpret=True, fused=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    kinds_f = [r.kind for r in tr_fused]
    kinds_u = [r.kind for r in tr_unfused]
    assert "pack" not in kinds_f and "unpack" not in kinds_f
    assert kinds_u.count("pack") == 1 and kinds_u.count("unpack") == 1
    assert len(tr_unfused) - len(tr_fused) == 2
    # and the jitted fused path traces the same zero-elementwise pipeline
    jax.clear_caches()
    with merge_sort.trace_launches() as tr_jit:
        c = argsort(keys, tile=512, interpret=True, jit=True,
                    strategy="merge")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    assert [r.kind for r in tr_jit] == kinds_f


def test_argsort_methods_agree():
    """radix-fused, radix-unfused, and bitonic argsort agree with the
    stable oracle on a non-power-of-two, duplicate-heavy input."""
    keys = np.random.RandomState(9).randint(0, 5, 3000).astype(np.int32)
    expect = np.argsort(keys, kind="stable")
    for kw in [dict(), dict(fused=False), dict(method="bitonic")]:
        order = argsort(jnp.asarray(keys), tile=256, interpret=True, **kw)
        np.testing.assert_array_equal(np.asarray(order), expect,
                                      err_msg=str(kw))


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4),
       st.sampled_from([37, 256, 1000, 2048]))
@settings(max_examples=20, deadline=None)
def test_argsort_stability_property(seed, key_bits, n):
    """Property: equal keys preserve input order (dup-heavy distributions:
    at most 16 distinct keys over up to 2048 elements)."""
    keys = np.random.RandomState(seed).randint(
        0, 1 << key_bits, n).astype(np.int32)
    order = np.asarray(argsort(jnp.asarray(keys), num_key_bits=key_bits,
                               tile=256, interpret=True))
    assert (np.sort(order) == np.arange(n)).all()          # a permutation
    sorted_keys = keys[order]
    assert (np.diff(sorted_keys) >= 0).all()               # sorted
    for k in np.unique(keys):                              # stable
        pos = order[sorted_keys == k]
        assert (np.diff(pos) > 0).all(), f"key {k} broke input order"


@pytest.mark.parametrize("dist", ["two_vals", "all_equal", "reverse_blocks"])
def test_argsort_stability_adversarial_distributions(dist):
    n = 2000
    if dist == "two_vals":
        keys = (np.arange(n) % 2).astype(np.int32)
    elif dist == "all_equal":
        keys = np.full(n, 7, np.int32)
    else:
        keys = np.repeat(np.arange(7, -1, -1), 250).astype(np.int32)
    order = np.asarray(argsort(jnp.asarray(keys), num_key_bits=4,
                               tile=256, interpret=True))
    np.testing.assert_array_equal(order, np.argsort(keys, kind="stable"))


def test_argsort_guard_too_many_elements():
    """The hard error fires only when packing is genuinely impossible:
    num_key_bits + ceil(log2(n)) > 32.  At the default num_key_bits=12
    that is exactly n > 2^IDX_BITS = 2^20 (the documented default cap)."""
    n = (1 << merge_sort.IDX_BITS) + 1
    with pytest.raises(ValueError, match="cannot pack"):
        argsort(jnp.zeros(n, jnp.int32))


def test_argsort_guard_key_overflow():
    with pytest.raises(ValueError, match="collide with the index"):
        argsort(jnp.asarray([1, 1 << 4, 3], dtype=jnp.int32), num_key_bits=4)
    # boundary passes: max legal key value sorts fine
    keys = np.asarray([(1 << 4) - 1, 0, (1 << 4) - 1], np.int32)
    order = argsort(jnp.asarray(keys), num_key_bits=4, tile=256,
                    interpret=True)
    np.testing.assert_array_equal(np.asarray(order),
                                  np.argsort(keys, kind="stable"))


def test_argsort_idx_bits_derived_per_call():
    """idx_bits = ceil(log2(n)): small batches admit keys up to
    2^(32 − ceil(log2(n))) — both sides of the boundary pinned."""
    # n=1024 → idx_bits=10 → keys up to 2^22 admissible (would have been
    # rejected under the fixed IDX_BITS=20 packing)
    keys = np.random.RandomState(0).randint(0, 1 << 22, 1024).astype(np.int32)
    order = argsort(jnp.asarray(keys), num_key_bits=22, tile=256,
                    interpret=True)
    np.testing.assert_array_equal(np.asarray(order),
                                  np.argsort(keys, kind="stable"))
    # one element more → idx_bits=11 → 22+11 > 32 → genuinely impossible
    with pytest.raises(ValueError, match="cannot pack"):
        argsort(jnp.zeros(1025, jnp.int32), num_key_bits=22)
    # extreme small-n boundary: two elements admit 31-bit keys…
    keys2 = np.asarray([(1 << 31) - 1, 0], np.int32)
    order2 = argsort(jnp.asarray(keys2), num_key_bits=31, interpret=True)
    np.testing.assert_array_equal(np.asarray(order2), [1, 0])
    # …but three do not (idx_bits=2)
    with pytest.raises(ValueError, match="cannot pack"):
        argsort(jnp.zeros(3, jnp.int32), num_key_bits=31)


# ---------------------------------------------------------------------------
# multi-tile LSD radix (PR 6 tentpole): merge-tree-free global argsort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1 << 13, 1 << 15, 1 << 16])
def test_multi_tile_launch_count_independent_of_n(n):
    """The multi-tile argsort must run exactly 3 launches per digit pass
    (local sort+histogram, carry scan, scatter) at ANY n — launch count a
    function of num_key_bits only, never of n.  Pinned per kind."""
    keys = jnp.asarray(np.random.RandomState(0).randint(
        0, 1 << 12, n).astype(np.int32))
    with merge_sort.trace_launches() as tr:
        out = argsort(keys, tile=1024, interpret=True,
                      strategy="multi_tile")
    np.testing.assert_array_equal(
        np.asarray(out), np.argsort(np.asarray(keys), kind="stable"))
    kinds = [r.kind for r in tr]
    num_passes = 3                       # ceil(12 key bits / 4 digit bits)
    assert kinds == ["radix_mt_local", "tile_scan",
                     "radix_mt_scatter"] * num_passes
    assert len(tr) == 3 * num_passes     # == SortSchedule.num_launches
    for rec in tr:
        if rec.kind in ("radix_mt_local", "radix_mt_scatter"):
            # grouped tile blocks, never whole-array inputs
            assert rec.grid[0] >= max(1, (n // 1024) // 8)


@pytest.mark.parametrize("n", [1 << 12, 3 * 1024, 5000, 1 << 16, 77, 1000])
def test_multi_tile_bit_identical_to_merge_tree(n):
    """Both strategies are stable sorts of the same keys, so the orders
    must be bit-identical — across random / dup-heavy / all-equal /
    reverse inputs including non-power-of-two n."""
    rng = np.random.RandomState(n)
    cases = {
        "random": rng.randint(0, 1 << 12, n).astype(np.int32),
        "dup_heavy": rng.randint(0, 7, n).astype(np.int32),
        "all_equal": np.full(n, (1 << 12) - 1, np.int32),
        "reverse": (np.arange(n, 0, -1) % (1 << 12)).astype(np.int32),
    }
    for name, keys in cases.items():
        jk = jnp.asarray(keys)
        mt = np.asarray(argsort(jk, interpret=True, strategy="multi_tile"))
        mg = np.asarray(argsort(jk, interpret=True, strategy="merge"))
        np.testing.assert_array_equal(mt, mg, err_msg=f"case {name} n={n}")
        np.testing.assert_array_equal(
            mt, np.argsort(keys, kind="stable"), err_msg=f"case {name}")


def test_argsort_strategy_auto_selection():
    """Small keys default to multi_tile; wide keys (> 16 bits) fall back
    to the merge tree; incompatible pipelines are rejected."""
    keys = jnp.asarray(np.random.RandomState(1).randint(
        0, 16, 4096).astype(np.int32))
    with merge_sort.trace_launches() as tr_small:
        argsort(keys, interpret=True)
    assert "radix_mt_local" in {r.kind for r in tr_small}
    wide = jnp.asarray(np.random.RandomState(1).randint(
        0, 1 << 17, 2048).astype(np.int32))
    with merge_sort.trace_launches() as tr_wide:
        argsort(wide, num_key_bits=17, interpret=True)
    kinds = {r.kind for r in tr_wide}
    assert "merge_level" in kinds and "radix_mt_local" not in kinds
    with pytest.raises(ValueError, match="multi_tile"):
        argsort(keys, strategy="multi_tile", fused=False)
    with pytest.raises(ValueError, match="multi_tile"):
        argsort(keys, strategy="multi_tile", method="bitonic")
    with pytest.raises(ValueError, match="strategy"):
        argsort(keys, strategy="quantum")


def test_moe_dispatch_sort_single_launch_and_exact():
    """The fused dispatch kernel: one pallas_call, and every output —
    permuted activation rows included — bit-identical to stable argsort +
    gather."""
    from repro.kernels.radix_sort import moe_dispatch_sort
    rng = np.random.RandomState(7)
    T, K, E, D = 100, 2, 16, 32
    x = rng.randn(T, D).astype(np.float32)
    e = rng.randint(0, E, (T, K)).astype(np.int32)
    p = rng.rand(T, K).astype(np.float32)
    with merge_sort.trace_launches() as tr:
        xd, se, st, sp = moe_dispatch_sort(
            jnp.asarray(x), jnp.asarray(e), jnp.asarray(p),
            num_experts=E, tile=64, jit=False)
    assert [r.kind for r in tr] == ["moe_dispatch"]
    fe, fp = e.reshape(-1), p.reshape(-1)
    tok = np.repeat(np.arange(T), K)
    order = np.argsort(fe, kind="stable")
    np.testing.assert_array_equal(np.asarray(se), fe[order])
    np.testing.assert_array_equal(np.asarray(st), tok[order])
    np.testing.assert_array_equal(np.asarray(sp), fp[order])
    np.testing.assert_array_equal(np.asarray(xd), x[tok[order]])
    with pytest.raises(ValueError, match="256"):
        moe_dispatch_sort(jnp.asarray(x), jnp.asarray(e), jnp.asarray(p),
                          num_experts=300)
