"""Per-kernel allclose tests: shape/dtype sweeps against the jnp oracles."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.kernels import ref
from repro.kernels import merge_sort
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import combine_partials, flash_decode
from repro.kernels.merge_sort import argsort, merge_pair, sort_u32, tile_sort


def rnd(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) \
        .astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA 2:1
    (1, 512, 8, 2, 32),      # GQA 4:1
    (2, 128, 6, 1, 128),     # MQA-ish, hd=128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal):
    q = rnd(0, (B, S, H, hd), dtype)
    k = rnd(1, (B, S, KV, hd), dtype)
    v = rnd(2, (B, S, KV, hd), dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                        interpret=True)
    o_ref = ref.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_invariance(bq, bk):
    q = rnd(0, (1, 256, 2, 64), jnp.float32)
    k = rnd(1, (1, 256, 2, 64), jnp.float32)
    v = rnd(2, (1, 256, 2, 64), jnp.float32)
    o = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                        interpret=True)
    o_ref = ref.attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,bk", [
    (2, 512, 4, 2, 64, 128),
    (1, 1024, 8, 8, 64, 256),
    (3, 256, 4, 1, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, S, H, KV, hd, bk, dtype):
    q = rnd(3, (B, H, hd), dtype)
    kc = rnd(4, (B, S, KV, hd), dtype)
    vc = rnd(5, (B, S, KV, hd), dtype)
    lengths = jnp.asarray(
        np.random.RandomState(0).randint(1, S + 1, B), jnp.int32)
    o = flash_decode(q, kc, vc, lengths, block_k=bk, interpret=True)
    o_ref = ref.decode_attention_reference(q, kc, vc, lengths)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


def test_flash_decode_demand_split_invariance():
    """The reduction-tree shape must not change the result (associativity)."""
    q = rnd(6, (2, 4, 64), jnp.float32)
    kc = rnd(7, (2, 1024, 2, 64), jnp.float32)
    vc = rnd(8, (2, 1024, 2, 64), jnp.float32)
    lengths = jnp.asarray([700, 1024], jnp.int32)
    outs = [flash_decode(q, kc, vc, lengths, block_k=128, demand=d,
                         interpret=True) for d in (1, 2, 4, 8)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)


def test_combine_partials_associative():
    k1, k2, k3 = (rnd(i, (2, 4), jnp.float32) for i in (10, 11, 12))
    a1, a2, a3 = (rnd(i, (2, 4, 8), jnp.float32) for i in (13, 14, 15))
    l1, l2, l3 = (jnp.abs(rnd(i, (2, 4), jnp.float32)) for i in (16, 17, 18))
    p1, p2, p3 = (k1, l1, a1), (k2, l2, a2), (k3, l3, a3)
    left = combine_partials(combine_partials(p1, p2), p3)
    right = combine_partials(p1, combine_partials(p2, p3))
    for a, b in zip(left, right):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# merge sort
# ---------------------------------------------------------------------------

@given(st.integers(1, 4096), st.integers(1, 12), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_argsort_matches_stable_oracle(n, key_bits, seed):
    keys = np.random.RandomState(seed).randint(
        0, 1 << key_bits, n).astype(np.int32)
    order = argsort(jnp.asarray(keys), tile=256, interpret=True)
    expect = ref.stable_argsort_reference(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(order), np.asarray(expect))


@pytest.mark.parametrize("n,tile", [(256, 64), (1024, 256), (4096, 512),
                                    (4096, 1024)])
def test_sort_u32_sorted(n, tile):
    x = jnp.asarray(np.random.RandomState(0).randint(
        0, 2 ** 31, n).astype(np.uint32))
    out = sort_u32(x, tile=tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


def test_tile_sort_sorts_each_tile():
    x = jnp.asarray(np.random.RandomState(1).randint(
        0, 1000, 512).astype(np.uint32))
    out = np.asarray(tile_sort(x, tile=128, interpret=True))
    for t in range(4):
        tile = out[t * 128:(t + 1) * 128]
        assert (np.diff(tile) >= 0).all()


def test_merge_pair_merges():
    a = np.sort(np.random.RandomState(2).randint(0, 1 << 20, 256)) \
        .astype(np.uint32)
    b = np.sort(np.random.RandomState(3).randint(0, 1 << 20, 256)) \
        .astype(np.uint32)
    out = merge_pair(jnp.asarray(a), jnp.asarray(b), interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.sort(np.concatenate([a, b])))


def test_argsort_stability_heavy_duplicates():
    keys = np.zeros(1000, np.int32)          # all equal → order == identity
    order = argsort(jnp.asarray(keys), tile=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(order), np.arange(1000))


# ---------------------------------------------------------------------------
# level-batched merge-path sort (PR 2 tentpole)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,tile", [(1 << 12, 256), (1 << 14, 1024),
                                    (1 << 16, 1024)])
def test_merge_tree_launch_count_pinned(n, tile):
    """The merge tree must run in exactly log2(n/tile) pallas_call launches
    (plus the single tile-sort launch) with every block ≤ 2·tile elements,
    independent of n — the level-batched structure, pinned."""
    x = jnp.asarray(np.random.RandomState(0).randint(
        0, 2 ** 31, n).astype(np.uint32))
    with merge_sort.trace_launches() as tr:
        out = sort_u32(x, tile=tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))
    kinds = [r.kind for r in tr]
    assert kinds.count("tile_sort") == 1
    assert kinds.count("merge_level") == int(math.log2(n // tile))
    assert len(tr) == 1 + int(math.log2(n // tile))
    assert max(r.max_block_elems for r in tr) <= 2 * tile
    # level L merges 2^L-tile runs: grid=(num_pairs, blocks_per_pair)
    for L, rec in enumerate(r for r in tr if r.kind == "merge_level"):
        run = tile << L
        assert rec.grid == (n // (2 * run), (2 * run) // tile)


def test_merge_level_matches_reference_merge():
    """One level kernel call over several pairs == per-pair np.merge."""
    rng = np.random.RandomState(7)
    tile, run, num_pairs = 64, 256, 4
    runs = np.sort(rng.randint(0, 1 << 30, (num_pairs, 2, run)).astype(
        np.uint32), axis=-1)
    x = jnp.asarray(runs.reshape(-1))
    out = np.asarray(merge_sort._merge_level(
        x, run=run, tile=tile, interpret=True)).reshape(num_pairs, 2 * run)
    for p in range(num_pairs):
        expect = np.sort(np.concatenate([runs[p, 0], runs[p, 1]]))
        np.testing.assert_array_equal(out[p], expect)


def test_merge_path_starts_corank_invariants():
    """Co-rank splits: monotone, diagonal-consistent, and exact on a known
    stable merge (ties go to A)."""
    rng = np.random.RandomState(3)
    run, tile = 128, 32
    a = np.sort(rng.randint(0, 16, run).astype(np.uint32))
    b = np.sort(rng.randint(0, 16, run).astype(np.uint32))
    ab = jnp.asarray(np.stack([a, b])[None])
    a_start, b_start, la = (np.asarray(v) for v in
                            merge_sort._merge_path_starts(ab, run, tile))
    assert a_start.shape == (1, 2 * run // tile)
    # every diagonal splits exactly: a_start + b_start == d, lengths sum tile
    d = np.arange(2 * run // tile) * tile
    np.testing.assert_array_equal(a_start[0] + b_start[0], d)
    assert (la >= 0).all() and (la <= tile).all()
    # exact co-rank: count of A elements among first d of the stable merge
    packed = np.concatenate([a.astype(np.uint64) * 2,       # A before equal B
                             b.astype(np.uint64) * 2 + 1])
    order = np.argsort(packed, kind="stable")
    for i, dd in enumerate(d):
        expect_ia = int((order[:dd] < run).sum())
        assert a_start[0, i] == expect_ia


@pytest.mark.parametrize("n,tile", [(16, 2), (8, 1), (32, 2), (64, 1)])
def test_sort_u32_tiny_tiles_odd_depth(n, tile):
    """Odd merge depth with tiles too small to halve must still sort (the
    parity adjustment falls back to an odd schedule, regression test)."""
    x = np.random.RandomState(n).randint(0, 2 ** 31, n).astype(np.uint32)
    out = np.asarray(sort_u32(jnp.asarray(x), tile=tile, interpret=True))
    np.testing.assert_array_equal(out, np.sort(x))


@pytest.mark.parametrize("n", [1, 2, 3, 5, 255, 257, 1000, 1023, 4097])
@pytest.mark.parametrize("key_bits", [1, 3, 11])
def test_argsort_property_sweep_vs_stable_oracle(n, key_bits):
    """Non-power-of-two sizes × duplicate-heavy keys vs np stable argsort
    (explicit sweep — runs even when hypothesis is stubbed out)."""
    keys = np.random.RandomState(n * 31 + key_bits).randint(
        0, 1 << key_bits, n).astype(np.int32)
    order = argsort(jnp.asarray(keys), tile=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(order),
                                  np.argsort(keys, kind="stable"))


def test_argsort_jit_end_to_end():
    keys = np.random.RandomState(5).randint(0, 64, 777).astype(np.int32)
    order = argsort(jnp.asarray(keys), tile=256, interpret=True, jit=True)
    np.testing.assert_array_equal(np.asarray(order),
                                  np.argsort(keys, kind="stable"))


def test_argsort_guard_too_many_elements():
    n = (1 << merge_sort.IDX_BITS) + 1
    with pytest.raises(ValueError, match="at most"):
        argsort(jnp.zeros(n, jnp.int32))


def test_argsort_guard_key_overflow():
    with pytest.raises(ValueError, match="collide with the index"):
        argsort(jnp.asarray([1, 1 << 4, 3], dtype=jnp.int32), num_key_bits=4)
    with pytest.raises(ValueError, match="pack into 32 bits"):
        argsort(jnp.asarray([0, 1], dtype=jnp.int32), num_key_bits=13)
    # boundary passes: max legal key value sorts fine
    keys = np.asarray([(1 << 4) - 1, 0, (1 << 4) - 1], np.int32)
    order = argsort(jnp.asarray(keys), num_key_bits=4, tile=256,
                    interpret=True)
    np.testing.assert_array_equal(np.asarray(order),
                                  np.argsort(keys, kind="stable"))
