"""Scheduler + simulated-runtime tests: the paper's quantitative claims."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (AdaptiveSim, BatchWork, CostModel, SeqWork,
                        WorkStealingSim, WorkRange, adaptive, by_blocks,
                        build_plan, demand_split, geometric_blocks,
                        static_partition_sim, thief_splitting, wrap_iter,
                        work_loop)


# ---------------------------------------------------------------------------
# by_blocks: geometric sizes + the wasted-work bound (paper §3.5)
# ---------------------------------------------------------------------------

@given(st.integers(1, 10_000_000), st.integers(1, 64))
@settings(max_examples=60)
def test_geometric_blocks_cover(total, first):
    blocks = geometric_blocks(total, first=first)
    pos = 0
    for lo, hi in blocks:
        assert lo == pos and hi > lo
        pos = hi
    assert pos == total
    import math
    assert len(blocks) <= math.ceil(math.log2(total / first + 1)) + 2


@given(st.integers(10, 1_000_000), st.integers(1, 32),
       st.integers(0, 1_000_000))
@settings(max_examples=60)
def test_by_blocks_wasted_work_bound(total, first, target):
    """Items processed ≤ 2×(target+1) + first: wasted ≤ ~half (growth 2)."""
    target = target % total
    bb = by_blocks(first=first)

    def block_fn(blk, carry):
        return carry or (blk.start <= target < blk.stop)

    carry, stats = bb.run(WorkRange(0, total), block_fn, False,
                          should_stop=lambda c: c)
    assert stats.stopped_early
    assert stats.items_run <= 2 * (target + 1) + 2 * first


def test_by_blocks_no_stop_runs_all():
    bb = by_blocks(first=7)
    _, stats = bb.run(WorkRange(0, 1000), lambda b, c: c, None)
    assert stats.items_run == 1000 and not stats.stopped_early


# ---------------------------------------------------------------------------
# demand_split: the adaptive schedule's "tasks = steals + 1"
# ---------------------------------------------------------------------------

@given(st.integers(1, 100_000), st.integers(1, 300))
def test_demand_split_minimal_divisions(n, demand):
    plan = demand_split(WorkRange(0, n), demand)
    want = min(demand, n)
    assert plan.num_tasks() == want
    assert plan.divisions == want - 1          # minimal: tasks = divisions+1
    leaves = sorted(plan.leaves(), key=lambda w: w.start)
    assert leaves[0].start == 0 and leaves[-1].stop == n
    sizes = plan.leaf_sizes()
    if n >= 4 * demand:
        assert max(sizes) <= 2 * max(1, min(sizes)) + 1  # largest-first halving


# ---------------------------------------------------------------------------
# Simulated work-stealing runtime: paper claims, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [2, 4, 8, 16])
def test_adaptive_tasks_equal_steals_plus_one(p):
    sim = AdaptiveSim(p, CostModel(per_item=1.0), seed=0)
    res = sim.run(WorkRange(0, 400_000))
    assert res.tasks_created == res.steals_successful + 1
    assert res.items_processed == 400_000
    assert res.speedup_vs_serial > 0.7 * p


@pytest.mark.parametrize("p", [2, 4, 8])
def test_thief_splitting_near_linear_speedup(p):
    sim = WorkStealingSim(p, CostModel(per_item=1.0, split_overhead=1.0),
                          seed=1)
    res = sim.run(thief_splitting(WorkRange(0, 400_000), p=p))
    assert res.items_processed == 400_000
    assert res.speedup_vs_serial > 0.7 * p
    # far fewer tasks than items (the whole point vs naive Ω(n)); tail
    # fragmentation inflates the count (the paper's "might be higher" case)
    assert res.tasks_created < res.items_total // 100


def test_adaptive_fewer_tasks_than_thief():
    """Paper §3.6: 'less tasks creations' vs counter-based splitting."""
    cost = CostModel(per_item=1.0, split_overhead=5.0)
    thief = WorkStealingSim(8, cost, seed=0).run(
        thief_splitting(WorkRange(0, 200_000), p=8))
    adapt = AdaptiveSim(8, cost, seed=0).run(WorkRange(0, 200_000))
    assert adapt.tasks_created < thief.tasks_created


def test_expensive_splits_favor_adaptive():
    """fannkuch structure: divide_at is expensive → adaptive wins makespan."""
    def split_cost(work):
        return 400.0                       # first-permutation generation
    cost = CostModel(per_item=1.0, split_cost_fn=split_cost)
    n = 100_000
    static = static_partition_sim(WorkRange(0, n), 8, cost, num_blocks=64)
    adapt = AdaptiveSim(8, CostModel(per_item=1.0), seed=0).run(
        WorkRange(0, n))
    assert adapt.makespan < static.makespan


def test_heterogeneous_workers_load_balance():
    """Work stealing absorbs a 2× straggler; static partitioning doesn't."""
    speeds = [1.0] * 7 + [0.5]
    cost = CostModel(per_item=1.0)
    ws = WorkStealingSim(8, cost, seed=0, speeds=speeds).run(
        thief_splitting(WorkRange(0, 200_000), p=8))
    static = static_partition_sim(WorkRange(0, 200_000), 8, cost,
                                  speeds=speeds, num_blocks=8)
    assert ws.makespan < 0.8 * static.makespan


def test_depjoin_no_slower_than_join():
    cost = CostModel(per_item=1.0, reduce_cost=50.0)
    join = WorkStealingSim(4, cost, depjoin=False, seed=2).run(
        thief_splitting(WorkRange(0, 50_000), p=4))
    dep = WorkStealingSim(4, cost, depjoin=True, seed=2).run(
        thief_splitting(WorkRange(0, 50_000), p=4))
    assert dep.makespan <= join.makespan * 1.3
    assert dep.items_processed == join.items_processed == 50_000


# ---------------------------------------------------------------------------
# wrap_iter / work_loop (paper §3.4, §3.6.1)
# ---------------------------------------------------------------------------

def test_wrap_iter_map_reduce_sum():
    import math
    w = thief_splitting(WorkRange(0, 1000), p=4)
    total = wrap_iter(w).map_reduce(
        lambda leaf: sum(leaf.indices()), lambda a, b: a + b)
    assert total == sum(range(1000))


def test_work_loop_geometric_grants():
    import jax.numpy as jnp

    def advance(state, n):
        import jax
        return jax.lax.fori_loop(0, n, lambda i, s: s + 1, state)

    out = work_loop(jnp.int32(0), advance, total=1000, first_grant=1)
    assert int(out) == 1000


def test_work_loop_early_stop():
    import jax
    import jax.numpy as jnp

    def advance(state, n):
        return jax.lax.fori_loop(0, n, lambda i, s: s + 1, state)

    out = work_loop(jnp.int32(0), advance, total=1 << 20,
                    should_stop=lambda s: s >= 100, first_grant=1)
    # stops at a grant boundary after crossing 100 → ≤ next power of two
    assert 100 <= int(out) <= 256
