"""Multi-tenant SLO serving: admission ordering, per-class caps, deadline
shedding, hot policy swap, slot death, bounded queues, config validation and
the telemetry EWMA cold-start fix."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.model import Model
from repro.serve.engine import (ContinuousEngine, Engine, EngineConfig,
                                EngineTelemetry, QueueFull, Request)
from repro.serve.slo import (DeadlineServePolicy, FifoServePolicy,
                             PriorityServePolicy)

KEY = jax.random.PRNGKey(0)
EOS = 7
MAX_SEQ = 224


def fp32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = fp32(get_smoke_config("llama3-8b"))
    model = Model(cfg)
    params = model.init(KEY)
    return model, params


def _req(rid, vocab, *, n=12, max_new=6, **kw):
    rng = np.random.RandomState(100 + rid)
    return Request(rid=rid, prompt=rng.randint(8, vocab, size=n)
                   .astype(np.int32), max_new=max_new, **kw)


def _drain(eng, max_steps=400):
    out = []
    for _ in range(max_steps):
        if not eng.pending:
            return out
        out.extend(eng.step())
    raise AssertionError(f"engine did not drain in {max_steps} steps")


def _cont(model, params, policy=None, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("eos_id", EOS)
    kw.setdefault("max_seq", MAX_SEQ)
    return ContinuousEngine(model, params, EngineConfig(**kw), policy=policy)


def _one_at_a_time(model, params, reqs):
    refs = {}
    for r in reqs:
        eng = Engine(model, params,
                     EngineConfig(max_batch=1, eos_id=EOS, max_seq=MAX_SEQ))
        eng.submit(Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new))
        (done,) = eng.step()
        refs[r.rid] = np.asarray(done.result)
    return refs


# ---------------------------------------------------------------------------
# EngineConfig validation (loud, at construction)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(max_batch=0), "max_batch"),
    (dict(max_batch=2, prefill_block_budget=0), "prefill_block_budget"),
    (dict(max_batch=2, decode_tick=0), "decode_tick"),
    (dict(max_batch=4, max_queue=2), "max_queue"),
    (dict(max_batch=2, class_caps={"streaming": 1}), "class_caps"),
    (dict(max_batch=2, class_caps={"batch": 0}), "class_caps"),
])
def test_engine_config_validation(kw, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(eos_id=EOS, **kw)


# ---------------------------------------------------------------------------
# Telemetry EWMA cold start
# ---------------------------------------------------------------------------

def test_ewma_first_observation_seeds_directly():
    t = EngineTelemetry()
    t.observe_decode(useful=4, seconds=0.4, steps=1)
    assert t.decode_s_per_token == 0.1          # seeded, NOT 0.25 * 0.1
    t.observe_decode(useful=4, seconds=0.8, steps=1)
    assert t.decode_s_per_token == (1 - t.ewma) * 0.1 + t.ewma * 0.2
    t2 = EngineTelemetry()
    t2.observe_admission(pages=6)
    assert t2.pages_per_request == 6.0
    t2.observe_prefill(blocks=0, tokens=0, seconds=0.5)   # no-op: no work
    assert t2.prefill_s_per_block == 0.0 and "prefill_s_per_block" \
        not in t2._seeded


def test_ewma_zero_first_sample_is_still_seeded():
    """A genuine ~0.0 first sample must count as seeded (the old
    ``old == 0.0`` sentinel would re-seed forever)."""
    t = EngineTelemetry()
    t.observe_decode(useful=4, seconds=0.0, steps=1)
    assert t.decode_s_per_token == 0.0
    t.observe_decode(useful=4, seconds=0.4, steps=1)
    assert t.decode_s_per_token == t.ewma * 0.1  # mixed with the seeded 0.0


# ---------------------------------------------------------------------------
# Bounded queues
# ---------------------------------------------------------------------------

def test_sync_engine_max_queue_rejects_loudly(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    eng = Engine(model, params, EngineConfig(
        max_batch=2, eos_id=EOS, max_seq=MAX_SEQ, max_queue=2))
    eng.submit(_req(0, vocab))
    eng.submit(_req(1, vocab))
    with pytest.raises(QueueFull, match="max_queue"):
        eng.submit(_req(2, vocab))
    assert eng.telemetry.queue_rejections == 1
    assert [r.rid for r in eng.queue] == [0, 1]   # rejected one never queued


def test_continuous_engine_max_queue_and_unknown_class(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    eng = _cont(model, params, max_batch=2, max_queue=3)
    for i in range(3):
        eng.submit(_req(i, vocab))
    with pytest.raises(QueueFull):
        eng.submit(_req(3, vocab))
    assert eng.telemetry.queue_rejections == 1
    with pytest.raises(ValueError, match="SLO class"):
        eng.submit(_req(9, vocab, slo="streaming"))
    done = _drain(eng)
    assert sorted(r.rid for r in done) == [0, 1, 2]


# ---------------------------------------------------------------------------
# SLO admission ordering + per-class caps
# ---------------------------------------------------------------------------

def _first_admitted(eng, step_results):
    """rid of the request the engine admitted in a just-run step — whether
    it is still prefilling, already decoding, or retired within the step
    (the smoke model can serve a short request inside one step)."""
    if eng._job is not None:
        return eng._job.req.rid
    live = [s.req.rid for s in eng.slots if s is not None]
    if live:
        return live[0]
    return step_results[0].rid


def test_priority_policy_admits_interactive_first(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    eng = _cont(model, params, PriorityServePolicy())
    eng.submit(_req(0, vocab, slo="batch"))
    eng.submit(_req(1, vocab, slo="background"))
    eng.submit(_req(2, vocab, slo="interactive"))
    done = eng.step()
    assert _first_admitted(eng, done) == 2   # interactive jumped the queue
    done += _drain(eng)
    assert sorted(r.rid for r in done) == [0, 1, 2]


def test_deadline_policy_admits_earliest_deadline_first(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    eng = _cont(model, params, DeadlineServePolicy())
    eng.submit(_req(0, vocab, slo="batch", deadline_s=500.0))
    eng.submit(_req(1, vocab, slo="batch", deadline_s=50.0))
    done = eng.step()
    assert _first_admitted(eng, done) == 1
    done += _drain(eng)
    assert sorted(r.rid for r in done) == [0, 1]


def test_class_caps_bound_in_flight_concurrency(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    eng = _cont(model, params, class_caps={"batch": 1})
    for i in range(3):
        eng.submit(_req(i, vocab, slo="batch", max_new=4))
    done = []
    for _ in range(400):
        if not eng.pending:
            break
        in_flight = [j.req.slo for j in (eng._job, eng._parked)
                     if j is not None]
        in_flight += [s.req.slo for s in eng.slots if s is not None]
        assert in_flight.count("batch") <= 1   # the cap, every step
        done.extend(eng.step())
    assert sorted(r.rid for r in done) == [0, 1, 2]


# ---------------------------------------------------------------------------
# Deadline shedding
# ---------------------------------------------------------------------------

def test_expired_queue_entries_shed_with_counters(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    eng = _cont(model, params)
    eng.submit(_req(0, vocab, slo="batch", deadline_s=1e-9,
                    tenant="tenant-a"))
    eng.submit(_req(1, vocab, slo="background", deadline_s=1e-9,
                    tenant="tenant-b"))
    eng.submit(_req(2, vocab, slo="interactive"))
    eng.submit(_req(3, vocab, slo="batch", tenant="tenant-a"))
    done = _drain(eng)
    shed = [r for r in done if r.shed]
    served = [r for r in done if not r.shed]
    assert sorted(r.rid for r in shed) == [0, 1]
    assert sorted(r.rid for r in served) == [2, 3]
    for r in shed:                       # loud, accounted, empty result
        assert r.result.size == 0 and r.t_done is not None
    assert eng.telemetry.shed == 2
    assert eng.telemetry.shed_by_tenant == {"tenant-a": 1, "tenant-b": 1}
    assert eng.telemetry.shed_by_class == {"batch": 1, "background": 1}


def test_in_flight_work_is_never_shed(smoke_model):
    """Deadlines only gate the queue: once admitted, a request runs to
    completion even if its deadline passes mid-decode."""
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    eng = _cont(model, params, max_batch=1)
    eng.submit(_req(0, vocab, deadline_s=30.0, max_new=8))
    (done,) = _drain(eng)
    assert not done.shed and done.result.size > 0


# ---------------------------------------------------------------------------
# Class preemption: batch prefill parks for interactive work
# ---------------------------------------------------------------------------

def test_batch_prefill_parks_for_interactive_and_both_are_exact(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    batch = _req(0, vocab, n=96, max_new=6, slo="batch")
    inter = _req(1, vocab, n=12, max_new=6, slo="interactive")
    eng = _cont(model, params, PriorityServePolicy(), prefill_block_budget=1)
    eng.submit(batch)
    done = eng.step()
    assert eng._job is not None and eng._job.req.rid == 0   # still prefilling
    eng.submit(inter)
    done += eng.step()
    assert eng.telemetry.class_preemptions == 1
    assert eng._parked is not None and eng._parked.req.rid == 0
    done += _drain(eng)
    assert sorted(r.rid for r in done) == [0, 1]
    refs = _one_at_a_time(model, params, [batch, inter])
    for r in done:                       # parking never perturbs tokens
        np.testing.assert_array_equal(refs[r.rid], np.asarray(r.result))


# ---------------------------------------------------------------------------
# Hot policy swap
# ---------------------------------------------------------------------------

def test_set_policy_hot_swap_preserves_exactness(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    reqs = [_req(i, vocab, n=10 + 3 * i, max_new=5 + (i % 3),
                 slo=("interactive" if i % 3 == 0 else "batch"))
            for i in range(6)]
    eng = _cont(model, params, FifoServePolicy(), max_batch=2)
    for r in reqs:
        eng.submit(r)
    done = []
    for step in range(400):
        if not eng.pending:
            break
        done.extend(eng.step())
        if step == 1:
            eng.set_policy(PriorityServePolicy())   # live, mid-flight
    assert eng.telemetry.policy_swaps == 1
    assert isinstance(eng.policy, PriorityServePolicy)
    assert sorted(r.rid for r in done) == list(range(6))
    refs = _one_at_a_time(model, params, reqs)
    for r in done:
        np.testing.assert_array_equal(refs[r.rid], np.asarray(r.result))


# ---------------------------------------------------------------------------
# Slot death: requeue exactly once, tokens exact
# ---------------------------------------------------------------------------

def test_slot_death_requeues_once_with_exact_tokens(smoke_model):
    model, params = smoke_model
    vocab = model.cfg.vocab_size
    reqs = [_req(i, vocab, n=14 + 5 * i, max_new=10) for i in range(2)]

    undisturbed = {}
    eng0 = _cont(model, params, max_batch=2)
    for r in reqs:
        eng0.submit(Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new))
    for r in _drain(eng0):
        undisturbed[r.rid] = np.asarray(r.result)

    eng = _cont(model, params, max_batch=2)
    for r in reqs:
        eng.submit(r)
    killed = False
    done = []
    for _ in range(400):
        if not eng.pending:
            break
        done.extend(eng.step())
        if not killed:
            for i, s in enumerate(eng.slots):
                if s is not None and s.emitted:
                    assert eng.kill_slot(i)
                    killed = True
                    break
    assert killed and eng.kill_slot(0) is False   # empty lane: no-op
    assert eng.telemetry.slot_deaths == 1
    assert sorted(r.rid for r in done) == [0, 1]
    by_rid = {r.rid: r for r in done}
    assert sum(r.requeues for r in done) == 1     # exactly one re-serve
    for rid, ref in undisturbed.items():
        np.testing.assert_array_equal(ref, np.asarray(by_rid[rid].result))
