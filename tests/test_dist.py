"""Distribution-optimization tests: collective matmul, gradient compression,
pipeline schedule.  Mesh tests need ≥4 host devices (see test_sharding.py)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.dist.pipeline import bubble_fraction, schedule_ticks
from repro.optim.compress import (BLOCK, compressed_grad_transform,
                                  compression_ratio, dequantize, init_error,
                                  quantize)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs XLA_FLAGS device_count>=4")


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,), jnp.float32)
    q, s = quantize(x)
    back = dequantize(q, s, x.shape, jnp.float32)
    err = jnp.abs(back - x)
    # per-block absmax/127 is the max quantization step
    assert float(err.max()) <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_error_feedback_accumulates():
    """With error feedback, the *sum* of compressed grads converges to the
    sum of true grads (the EF-SGD property)."""
    key = jax.random.PRNGKey(1)
    grads = {"w": jax.random.normal(key, (512,), jnp.float32) * 1e-3}
    err = init_error(grads)
    total_true = jnp.zeros((512,))
    total_comp = jnp.zeros((512,))
    for i in range(50):
        g = {"w": grads["w"] * (1 + 0.01 * i)}
        out, err = compressed_grad_transform(g, err)
        total_true += g["w"]
        total_comp += out["w"]
    # residual is bounded by one quantization step, not 50 of them
    resid = float(jnp.abs(total_true - total_comp).max())
    step = float(jnp.abs(grads["w"]).max()) / 127.0 * 2
    assert resid < step * 3


def test_compression_ratio():
    params = {"a": jnp.zeros((1024, 1024), jnp.float32)}
    r = compression_ratio(params)
    assert 3.5 < r < 4.0


# ---------------------------------------------------------------------------
# collective matmul (needs a real mesh)
# ---------------------------------------------------------------------------

@needs_mesh
def test_collective_matmuls_match():
    """Both ring decompositions reproduce the exact x @ w (one test: they
    share the setup, and the 1-device skip budget is capped at 5)."""
    from repro.dist.collective import allgather_matmul, matmul_reducescatter
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(2, 2)
    M, K, N = 8, 32, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    with mesh:
        y_ag = allgather_matmul(x, w, mesh, axis="model")
        y_rs = matmul_reducescatter(x, w, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(y_ag), np.asarray(x @ w),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_rs), np.asarray(x @ w),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# pipeline schedule
# ---------------------------------------------------------------------------

def test_schedule_ticks_structure():
    table = schedule_ticks(4, 8)
    assert len(table) == 11                       # n_mb + p - 1
    # stage s starts at tick s and processes n_mb microbatches
    for s in range(4):
        col = [row[s] for row in table]
        work = [c for c in col if c != "-"]
        assert work == [str(i) for i in range(8)]


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(1 - 16 / 28)
    assert bubble_fraction(4, 32) < 0.1           # deep microbatching


@needs_mesh
def test_pipeline_forward_matches_sequential():
    from repro.dist.pipeline import pipeline_forward
    from repro.launch.mesh import make_host_mesh
    import numpy as _np
    from jax.sharding import Mesh
    devs = jax.devices()[:4]
    mesh = Mesh(_np.array(devs).reshape(4,), ("pipe",))
    P_STAGES, D = 4, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (P_STAGES, D, D),
                           jnp.float32) / jnp.sqrt(D)

    def stage(x, w):
        return jnp.tanh(x @ w)

    xs = jax.random.normal(jax.random.PRNGKey(1), (8, 4, D), jnp.float32)
    with mesh:
        out = pipeline_forward(stage, ws, xs, mesh, axis="pipe")
    # sequential reference
    ref = xs
    for s in range(P_STAGES):
        ref = jax.vmap(lambda mb: stage(mb, ws[s]))(ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
