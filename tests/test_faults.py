"""Fault injection in the unified Runtime: determinism, death/recovery
semantics, work conservation, the mid-region preemption hook.

The recovery-ratio claims pinned here are the same quantities emitted to
results/bench/BENCH_recovery.json by benchmarks/recovery.py (and gated in
CI by tools/bench_delta.py).
"""

import pytest

from repro.core import (AdaptivePolicy, ByBlocksPolicy, CostModel,
                        DepJoinPolicy, FaultPlan, JoinPolicy, Runtime,
                        Slowdown, StaticPartitionPolicy, WorkerDeath,
                        WorkRange, simulate)

COST = CostModel(per_item=1.0)
N = 200_000
P = 8
DEATH = FaultPlan(deaths=(WorkerDeath(0, 12_500.0),))


def _tuple(r):
    return (r.makespan, r.tasks_created, r.divisions, r.steals_attempted,
            r.steals_successful, r.reductions, r.items_processed,
            r.deaths, r.lost_items, r.recoveries)


# ---------------------------------------------------------------------------
# determinism + inertness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy_fn", [
    lambda: AdaptivePolicy(preempt=True),
    lambda: StaticPartitionPolicy(),
    lambda: JoinPolicy(),
    lambda: DepJoinPolicy(),
    lambda: ByBlocksPolicy(inner=AdaptivePolicy(preempt=True), first=P),
])
def test_fault_runs_are_deterministic(policy_fn):
    a = simulate(WorkRange(0, N), policy_fn(), P, COST, seed=3, faults=DEATH)
    b = simulate(WorkRange(0, N), policy_fn(), P, COST, seed=3, faults=DEATH)
    assert _tuple(a) == _tuple(b)


def test_plan_without_runtime_events_is_inert():
    """A plan carrying only wall-clock events must not perturb the engine."""
    from repro.core import CheckpointWriteFault, PreemptionFault
    inert = FaultPlan(checkpoint_faults=(CheckpointWriteFault(1),),
                      preemptions=(PreemptionFault(3),))
    base = simulate(WorkRange(0, N), AdaptivePolicy(), P, COST, seed=0)
    same = simulate(WorkRange(0, N), AdaptivePolicy(), P, COST, seed=0,
                    faults=inert)
    assert _tuple(base) == _tuple(same)
    assert same.deaths == 0 and same.lost_items == 0


def test_preempt_flag_alone_is_inert_without_demand():
    """preempt=True only clips grants when another worker is idle; a fully
    loaded faultless run is bit-identical to preempt=False."""
    base = simulate(WorkRange(0, N), AdaptivePolicy(), P, COST, seed=0)
    pre = simulate(WorkRange(0, N), AdaptivePolicy(preempt=True), P, COST,
                   seed=0)
    # steady state equal; transient startup (workers idle before first
    # steals are served) may differ, so compare the load-bearing fields
    assert pre.items_processed == base.items_processed == N
    assert pre.deaths == base.deaths == 0


def test_random_plan_replayable():
    a = FaultPlan.random(7, p=P, horizon=10_000.0, n_deaths=2,
                         n_slowdowns=1)
    b = FaultPlan.random(7, p=P, horizon=10_000.0, n_deaths=2,
                         n_slowdowns=1)
    assert a == b
    c = FaultPlan.random(8, p=P, horizon=10_000.0, n_deaths=2,
                         n_slowdowns=1)
    assert a != c


# ---------------------------------------------------------------------------
# death semantics: loss, orphaning, conservation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy_fn,big_charges", [
    (lambda: AdaptivePolicy(preempt=True), True),
    (lambda: AdaptivePolicy(), True),
    (lambda: StaticPartitionPolicy(), True),
    # join-family leaves are small: the death usually lands on a division
    # charge between leaves, so losing zero items is legitimate there
    (lambda: JoinPolicy(), False),
    (lambda: DepJoinPolicy(), False),
])
def test_death_conserves_work(policy_fn, big_charges):
    """Truncated charges never advance the producer, so every item is
    eventually processed exactly once by a survivor."""
    r = simulate(WorkRange(0, N), policy_fn(), P, COST, seed=0, faults=DEATH)
    assert r.deaths == 1
    assert r.items_processed == r.items_total == N
    assert r.recoveries >= 1          # the orphan(s) were adopted
    assert r.lost_items < N
    if big_charges:                   # partial grant/leaf lost at the cut
        assert r.lost_items > 0
        assert 0.0 < r.lost_work_fraction < 1.0


def test_static_death_loses_whole_partial_chunk():
    """Static partitioning runs whole-chunk leaves: dying mid-chunk loses
    everything executed since the chunk started — here the worker had run
    12.5k of its 25k chunk."""
    r = simulate(WorkRange(0, N), StaticPartitionPolicy(), P, COST, seed=0,
                 faults=DEATH)
    assert r.lost_items == 12_500


def test_adaptive_loses_at_most_one_grant():
    """Adaptive loses only the truncated nano-loop grant, which the cap
    bounds — far less than static's whole chunk."""
    r = simulate(WorkRange(0, N), AdaptivePolicy(preempt=True), P, COST,
                 seed=0, faults=DEATH)
    rs = simulate(WorkRange(0, N), StaticPartitionPolicy(), P, COST,
                  seed=0, faults=DEATH)
    assert r.lost_items < rs.lost_items


def test_death_at_time_zero_reseeds():
    """The seed worker dying immediately must not strand the region."""
    r = simulate(WorkRange(0, 10_000), AdaptivePolicy(preempt=True), 4,
                 COST, seed=0,
                 faults=FaultPlan(deaths=(WorkerDeath(0, 0.0),)))
    assert r.deaths == 1 and r.items_processed == 10_000


def test_multiple_deaths():
    plan = FaultPlan(deaths=(WorkerDeath(0, 2_000.0),
                             WorkerDeath(3, 5_000.0)))
    r = simulate(WorkRange(0, N), AdaptivePolicy(preempt=True), P, COST,
                 seed=0, faults=plan)
    assert r.deaths == 2 and r.items_processed == N


def test_all_workers_dead_raises():
    plan = FaultPlan(deaths=(WorkerDeath(0, 10.0), WorkerDeath(1, 10.0)))
    rt = Runtime(2, COST, AdaptivePolicy(preempt=True), seed=0, faults=plan)
    with pytest.raises(RuntimeError, match="killed every worker"):
        rt.run(WorkRange(0, 100_000))


def test_by_blocks_death_is_absolute_across_regions():
    """by_blocks resets the region clock per block; the death time is
    absolute (abs_offset), and dead workers stay dead in later blocks."""
    plan = FaultPlan(deaths=(WorkerDeath(1, 500.0),))
    r = simulate(WorkRange(0, 100_000),
                 ByBlocksPolicy(inner=AdaptivePolicy(preempt=True), first=P),
                 P, COST, seed=0, faults=plan)
    assert r.deaths == 1              # exactly once, not once per region
    assert r.items_processed == 100_000


# ---------------------------------------------------------------------------
# slowdowns
# ---------------------------------------------------------------------------

def test_slowdown_stretches_makespan():
    slow = FaultPlan(slowdowns=(Slowdown(0, 0.0, 1e12, 0.25),))
    base = simulate(WorkRange(0, N), StaticPartitionPolicy(), P, COST,
                    seed=0)
    r = simulate(WorkRange(0, N), StaticPartitionPolicy(), P, COST, seed=0,
                 faults=slow)
    assert r.makespan > 1.5 * base.makespan   # 4x slower straggler chunk
    assert r.deaths == 0 and r.items_processed == N


def test_speed_factor_window_and_composition():
    plan = FaultPlan(slowdowns=(Slowdown(0, 10.0, 20.0, 0.5),
                                Slowdown(0, 15.0, 30.0, 0.5)))
    assert plan.speed_factor(0, 5.0) == 1.0
    assert plan.speed_factor(0, 12.0) == 0.5
    assert plan.speed_factor(0, 17.0) == 0.25   # overlap multiplies
    assert plan.speed_factor(0, 25.0) == 0.5
    assert plan.speed_factor(0, 30.0) == 1.0    # stop is exclusive
    assert plan.speed_factor(1, 17.0) == 1.0


# ---------------------------------------------------------------------------
# the recovery claim: preemption hook + adoption beat static failover
# ---------------------------------------------------------------------------

def test_preempt_hook_recovers_inside_region():
    """The pinned zero-recovery scenario: a straggler holds work late in a
    region, the grown nano-loop leaves no steal-service boundary, idle
    demand goes unserved.  The preempt hook clips grants under demand, so
    the straggler's remainder re-spreads — strictly more successful steals
    and a shorter makespan."""
    slow = FaultPlan(slowdowns=(Slowdown(0, 0.0, 1e12, 0.25),))
    no_hook = simulate(WorkRange(0, N), AdaptivePolicy(), P, COST, seed=0,
                       faults=slow)
    hook = simulate(WorkRange(0, N), AdaptivePolicy(preempt=True), P, COST,
                    seed=0, faults=slow)
    assert hook.makespan < no_hook.makespan
    assert hook.steals_successful > no_hook.steals_successful
    # death recovery doesn't need the hook (adoption resets nano to 1), but
    # the hook must not break it either
    d = simulate(WorkRange(0, N), AdaptivePolicy(preempt=True), P, COST,
                 seed=0, faults=DEATH)
    assert d.items_processed == N and d.recoveries >= 1


def test_recovery_ratio_meets_bar():
    """The BENCH_recovery.json headline, asserted at test granularity:
    adaptive(preempt) recovers a worker death ≥1.3x faster than static
    failover."""
    adaptive = simulate(WorkRange(0, N), AdaptivePolicy(preempt=True), P,
                        COST, seed=0, faults=DEATH)
    static = simulate(WorkRange(0, N), StaticPartitionPolicy(), P, COST,
                      seed=0, faults=DEATH)
    assert static.makespan / adaptive.makespan >= 1.3
    assert adaptive.lost_work_fraction < static.lost_work_fraction
