"""SLO scheduling policies: PriorityPolicy / DeadlinePolicy over the unified
Runtime, Tagged metadata propagation, WorkSet division, and the composition
claims (by_blocks outer loop, cap-gated eager division).  Golden tuples pin
the faultless strict (k=1) runs bit-exactly — strict pops consume no rng, so
these must never drift."""

import pytest

from repro.core import (ByBlocksPolicy, CostModel, DeadlinePolicy,
                        PriorityPolicy, WorkRange, WorkSet, cap, find_tag,
                        simulate, tagged)

C1 = CostModel(per_item=1.0)


def _priority_work():
    return WorkSet(tuple(
        tagged(WorkRange(1000 * i, 1000 * (i + 1)), priority=i % 3,
               tenant=f"t{i % 2}")
        for i in range(8)))


def _deadline_work(mult=320.0):
    return WorkSet(tuple(
        tagged(WorkRange(500 * i, 500 * (i + 1)), deadline=mult * (i + 1))
        for i in range(6)))


# ---------------------------------------------------------------------------
# Tagged / WorkSet plumbing
# ---------------------------------------------------------------------------

def test_tagged_children_inherit_and_find_tag_through_cap():
    w = cap(tagged(WorkRange(0, 100), priority=3, deadline=9.0,
                   tenant="t-a"), 2)
    tag = find_tag(w)
    assert (tag.priority, tag.deadline, tag.tenant) == (3, 9.0, "t-a")
    l, r = w.divide()
    for child in (l, r):
        t = find_tag(child)
        assert (t.priority, t.deadline, t.tenant) == (3, 9.0, "t-a")
    assert l.size() + r.size() == 100


def test_workset_divide_at_cuts_whole_parts():
    ws = WorkSet((WorkRange(0, 10), WorkRange(10, 30), WorkRange(30, 60)))
    assert ws.size() == 60 and ws.should_be_divided()
    l, r = ws.divide_at(15)          # smallest non-empty prefix >= 15 items
    assert [p.size() for p in l.parts] == [10, 20]
    assert [p.size() for p in r.parts] == [30]
    # a full-size cut must keep every part (empty right half, nothing lost)
    l, r = ws.divide_at(60)
    assert l.size() == 60 and r.size() == 0 and r.parts == ()


def test_workset_single_part_declines_division():
    assert not WorkSet((WorkRange(0, 5),)).should_be_divided()


# ---------------------------------------------------------------------------
# PriorityPolicy: strict golden, ordering, relaxation
# ---------------------------------------------------------------------------

# (makespan, tasks, divisions, items, expired) at seed 0 — strict k=1
GOLDEN_PRIORITY_P4 = (4998.5, 8000, 7992, 8000, 0)
GOLDEN_CAP_PRIORITY = (1009.5, 24, 4000)
GOLDEN_DEADLINE_P2 = (1992.0, 1050, 1950)


def test_priority_strict_golden_bit_identical():
    r = simulate(_priority_work(), PriorityPolicy(), 4, C1, seed=0)
    assert (r.makespan, r.tasks_created, r.divisions, r.items_processed,
            r.expired_items) == GOLDEN_PRIORITY_P4


def test_priority_strict_seed_independent():
    """k=1 pops consume no rng, so the strict schedule cannot depend on
    the seed."""
    a = simulate(_priority_work(), PriorityPolicy(), 4, C1, seed=0)
    b = simulate(_priority_work(), PriorityPolicy(), 4, C1, seed=1234)
    assert (a.makespan, a.tasks_created, a.divisions) == \
        (b.makespan, b.tasks_created, b.divisions)


def test_priority_pops_highest_first():
    class Recording(PriorityPolicy):
        def __init__(self):
            super().__init__(k=1)
            self.keys = []

        def _pop_index(self, rt):
            i = super()._pop_index(rt)
            self.keys.append(self._pool[i][0])
            return i

    pol = Recording()
    simulate(WorkSet(tuple(
        tagged(WorkRange(10 * i, 10 * (i + 1)), priority=p)
        for i, p in enumerate((0, 2, 1, 2, 0)))), pol, 1, C1, seed=0)
    assert pol.keys == sorted(pol.keys)       # key is (-priority,): 2,2,1,0,0
    assert pol.keys[0] == (-2,) and pol.keys[-1] == (0,)


def test_priority_relaxed_k_deterministic_per_seed():
    runs = [simulate(_priority_work(), PriorityPolicy(k=3), 4, C1, seed=s)
            for s in (7, 7, 8)]
    assert (runs[0].makespan, runs[0].tasks_created) == \
        (runs[1].makespan, runs[1].tasks_created)
    for r in runs:
        assert r.items_processed == 8000      # relaxation never loses work


def test_priority_k_validated():
    with pytest.raises(ValueError, match="relaxation k"):
        PriorityPolicy(k=0)


def test_untagged_work_runs_at_default_priority():
    r = simulate(WorkRange(0, 2000), PriorityPolicy(), 2, C1, seed=0)
    assert r.items_processed == 2000 and r.expired_items == 0


# ---------------------------------------------------------------------------
# DeadlinePolicy: EDF order, expiry accounting, conservation
# ---------------------------------------------------------------------------

def test_deadline_golden_and_conservation():
    r = simulate(_deadline_work(), DeadlinePolicy(), 2, C1, seed=0)
    assert (r.makespan, r.items_processed, r.expired_items) == \
        GOLDEN_DEADLINE_P2
    assert r.items_processed + r.expired_items == 3000


def test_deadline_pops_earliest_first():
    class Recording(DeadlinePolicy):
        def __init__(self):
            super().__init__(k=1)
            self.keys = []

        def _pop_index(self, rt):
            i = super()._pop_index(rt)
            self.keys.append(self._pool[i][0])
            return i

    pol = Recording()
    simulate(WorkSet(tuple(
        tagged(WorkRange(10 * i, 10 * (i + 1)), deadline=d)
        for i, d in enumerate((900.0, 100.0, 500.0)))), pol, 1, C1, seed=0)
    assert pol.keys == sorted(pol.keys)       # key is (deadline,)
    assert pol.keys[0] == (100.0,)


def test_deadline_generous_deadlines_expire_nothing():
    r = simulate(_deadline_work(mult=1e9), DeadlinePolicy(), 2, C1, seed=0)
    assert r.expired_items == 0 and r.items_processed == 3000


def test_deadline_expired_work_is_dropped_not_run():
    """All-expired input: every item is counted, none processed, and the
    makespan stays far below the per_item cost of actually running them."""
    work = WorkSet(tuple(
        tagged(WorkRange(1000 * i, 1000 * (i + 1)), deadline=-1.0)
        for i in range(4)))
    r = simulate(work, DeadlinePolicy(), 2, C1, seed=0)
    assert r.items_processed == 0 and r.expired_items == 4000
    assert r.makespan < 4000 * C1.per_item


# ---------------------------------------------------------------------------
# Composition: by_blocks outer loop and cap-gated division
# ---------------------------------------------------------------------------

def test_by_blocks_deadline_composition_conserves_items():
    work = WorkSet(tuple(
        tagged(WorkRange(100 * i, 100 * (i + 1)), deadline=10_000.0)
        for i in range(8)))
    pol = ByBlocksPolicy(DeadlinePolicy(), first=64)
    r = simulate(work, pol, 4, C1, seed=0)
    assert r.items_processed + r.expired_items == 800
    assert r.expired_items == 0
    assert pol.blocks_run >= 3                # geometric outer loop really ran


def test_cap_gates_priority_eager_division():
    r = simulate(cap(tagged(WorkRange(0, 4000), priority=1), 3),
                 PriorityPolicy(), 4, C1, seed=0)
    assert (r.makespan, r.tasks_created, r.items_processed) == \
        GOLDEN_CAP_PRIORITY
    assert r.tasks_created < 4000             # cap stopped singleton blowup
