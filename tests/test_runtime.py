"""Unified-runtime tests: bit-identity with the pre-refactor engines, the
paper's scheduling invariants, seed determinism, and the policy compositions
the old four-engine design could not express."""

import dataclasses

import pytest

from repro.core import (AdaptivePolicy, AdaptiveScheduler, AdaptiveSim,
                        ByBlocks, ByBlocksPolicy, CostModel, DepJoinPolicy,
                        JoinPolicy, JoinScheduler, PermRange, Runtime,
                        StaticPartitionPolicy, WorkRange, WorkStealingSim,
                        cap, simulate, size_limit, static_partition_sim,
                        thief_splitting, total_permutations)

C1 = CostModel(per_item=1.0)


# ---------------------------------------------------------------------------
# Golden bit-identity: the refactor must not change a single simulated number.
# Values recorded from the pre-refactor WorkStealingSim / AdaptiveSim /
# static_partition_sim at the seeds used by tests and benchmarks.
# (makespan, tasks, divisions, steal_try, steal_ok, reductions, items)
# ---------------------------------------------------------------------------

GOLDEN_ADAPTIVE = {
    2: (200002.45, 2, 1, 1, 1, 1, 400000),
    4: (100003.95, 4, 3, 3, 3, 3, 400000),
    8: (50005.45, 8, 7, 7, 7, 7, 400000),
    16: (34837.40000000005, 137, 136, 148, 136, 136, 400000),
}

GOLDEN_THIEF = {
    2: (200007.50000000198, 10, 9, 1, 1, 9, 400000),
    4: (100092.50000000502, 229, 228, 21, 21, 228, 400000),
    8: (50254.500000007974, 1962, 1961, 125, 125, 1961, 400000),
}


def _tuple(r):
    return (r.makespan, r.tasks_created, r.divisions, r.steals_attempted,
            r.steals_successful, r.reductions, r.items_processed)


@pytest.mark.parametrize("p", sorted(GOLDEN_ADAPTIVE))
def test_golden_adaptive_bit_identical(p):
    r = AdaptiveSim(p, C1, seed=0).run(WorkRange(0, 400_000))
    assert _tuple(r) == GOLDEN_ADAPTIVE[p]


@pytest.mark.parametrize("p", sorted(GOLDEN_THIEF))
def test_golden_thief_bit_identical(p):
    r = WorkStealingSim(p, CostModel(per_item=1.0, split_overhead=1.0),
                        seed=1).run(thief_splitting(WorkRange(0, 400_000),
                                                    p=p))
    assert _tuple(r) == GOLDEN_THIEF[p]


def test_golden_join_vs_depjoin_bit_identical():
    cost = CostModel(per_item=1.0, reduce_cost=50.0)
    join = WorkStealingSim(4, cost, depjoin=False, seed=2).run(
        thief_splitting(WorkRange(0, 50_000), p=4))
    dep = WorkStealingSim(4, cost, depjoin=True, seed=2).run(
        thief_splitting(WorkRange(0, 50_000), p=4))
    assert _tuple(join) == (15322.000000003001, 219, 218, 24, 24, 218, 50000)
    assert _tuple(dep) == (16267.0, 256, 255, 27, 27, 255, 50000)


def test_golden_static_and_hetero_bit_identical():
    speeds = [1.0] * 7 + [0.5]
    ws = WorkStealingSim(8, C1, seed=0, speeds=speeds).run(
        thief_splitting(WorkRange(0, 200_000), p=8))
    st = static_partition_sim(WorkRange(0, 200_000), 8, C1, speeds=speeds,
                              num_blocks=8)
    assert _tuple(ws) == (26893.000000008004, 1628, 1627, 122, 122, 1627,
                          200000)
    assert _tuple(st) == (50007.0, 8, 7, 0, 0, 7, 200000)


def test_golden_fannkuch_bit_identical():
    tot = total_permutations(9)
    costf = CostModel(per_item=1.0, split_cost_fn=lambda w: 81.0,
                      steal_latency=2.0)
    st = static_partition_sim(PermRange(9, 0, tot), 16, costf, num_blocks=128)
    ad = AdaptiveSim(16, CostModel(per_item=1.0, steal_latency=2.0),
                     seed=0).run(PermRange(9, 0, tot))
    assert _tuple(st) == (33094.0, 128, 127, 0, 0, 127, 362880)
    assert _tuple(ad) == (35098.15000000007, 177, 176, 190, 176, 176, 362880)


# ---------------------------------------------------------------------------
# Shims are thin: same Runtime underneath
# ---------------------------------------------------------------------------

def test_shims_delegate_to_unified_runtime():
    assert isinstance(WorkStealingSim(2, C1)._rt, Runtime)
    assert isinstance(WorkStealingSim(2, C1, depjoin=True)._rt.policy,
                      DepJoinPolicy)
    assert isinstance(AdaptiveSim(2, C1)._rt.policy, AdaptivePolicy)
    direct = Runtime(4, C1, JoinPolicy(), seed=7).run(
        thief_splitting(WorkRange(0, 10_000), p=4))
    shim = WorkStealingSim(4, C1, seed=7).run(
        thief_splitting(WorkRange(0, 10_000), p=4))
    assert _tuple(direct) == _tuple(shim)


# ---------------------------------------------------------------------------
# Paper invariants on the unified runtime
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [2, 4, 8, 16, 32])
def test_adaptive_tasks_equal_steals_plus_one(p):
    r = simulate(WorkRange(0, 300_000), AdaptivePolicy(), p, C1)
    assert r.tasks_created == r.steals_successful + 1
    assert r.items_processed == 300_000


@pytest.mark.parametrize("policy_name", ["join", "depjoin"])
def test_join_reduction_count_is_division_count(policy_name):
    """Every division creates exactly one reduction, under both reduction
    ownership rules (join: dividing owner; depjoin: last finisher)."""
    cost = CostModel(per_item=1.0, reduce_cost=10.0)
    pol = DepJoinPolicy() if policy_name == "depjoin" else JoinPolicy()
    r = simulate(thief_splitting(WorkRange(0, 40_000), p=8), pol, 8, cost,
                 seed=3)
    assert r.reductions == r.divisions
    assert r.tasks_created == r.divisions + 1
    assert r.items_processed == 40_000


def test_depjoin_reduces_no_later_than_join():
    cost = CostModel(per_item=1.0, reduce_cost=50.0)
    join = simulate(thief_splitting(WorkRange(0, 50_000), p=4),
                    JoinPolicy(), 4, cost, seed=2)
    dep = simulate(thief_splitting(WorkRange(0, 50_000), p=4),
                   DepJoinPolicy(), 4, cost, seed=2)
    assert dep.makespan <= join.makespan * 1.3
    assert dep.items_processed == join.items_processed == 50_000


POLICIES = {
    "join": lambda: (JoinPolicy(), thief_splitting(WorkRange(0, 60_000), p=8)),
    "depjoin": lambda: (DepJoinPolicy(),
                        thief_splitting(WorkRange(0, 60_000), p=8)),
    "adaptive": lambda: (AdaptivePolicy(), WorkRange(0, 60_000)),
    "static": lambda: (StaticPartitionPolicy(num_blocks=16),
                       WorkRange(0, 60_000)),
    "by_blocks": lambda: (ByBlocksPolicy(inner=AdaptivePolicy(), first=8),
                          WorkRange(0, 60_000)),
}


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_seed_determinism_all_policies(name):
    """Same seed → identical SimResult, for every policy on the one engine."""
    runs = []
    for _ in range(2):
        pol, work = POLICIES[name]()
        runs.append(simulate(work, pol, 8,
                             CostModel(per_item=1.0, reduce_cost=2.0),
                             seed=42))
    a, b = runs
    assert dataclasses.asdict(a) == dataclasses.asdict(b)


# ---------------------------------------------------------------------------
# wasted_items is a real computed field now (was a property stuck at 0)
# ---------------------------------------------------------------------------

def test_wasted_items_is_a_real_field():
    r = simulate(WorkRange(0, 1000), AdaptivePolicy(), 2, C1)
    assert dataclasses.replace(r, wasted_items=7).wasted_items == 7


def test_wasted_items_computed_for_interruptible_runs():
    """wasted_items = processed items strictly beyond the stop index,
    cross-checked by counting through the predicate itself."""
    target = 5_000
    seen = []

    def hit_item(item):
        seen.append(item)
        return target if item == target else None

    r = simulate(WorkRange(0, 500_000), AdaptivePolicy(), 8, C1,
                 stop_predicate=hit_item)
    assert r.stopped_early
    assert r.wasted_items == sum(1 for i in seen if i > target)
    assert 0 < r.wasted_items < r.items_total

    leaves = []

    def hit_leaf(w):
        leaves.append((w.start, w.stop))
        return target if (w.start <= target < w.stop) else None

    r = simulate(thief_splitting(WorkRange(0, 500_000), p=8), JoinPolicy(),
                 8, C1, stop_predicate=hit_leaf)
    assert r.stopped_early
    assert r.wasted_items == sum(max(0, hi - max(lo, target + 1))
                                 for (lo, hi) in leaves)
    assert r.wasted_items > 0


# ---------------------------------------------------------------------------
# Compositions impossible under the old four-engine design
# ---------------------------------------------------------------------------

def test_composed_by_blocks_over_adaptive_inner():
    """by_blocks outer loop with *adaptive* inner blocks, interruptible:
    previously by_blocks existed only statically and AdaptiveSim had no
    block structure.  The identity tasks = steals + blocks holds because
    each block seeds one initial task and every steal adds one."""
    target = 600
    seen = []

    def hit_item(item):
        seen.append(item)
        return target if item == target else None

    pol = ByBlocksPolicy(inner=AdaptivePolicy(), first=8)
    r = Runtime(8, C1, pol, seed=0, stop_predicate=hit_item).run(
        WorkRange(0, 100_000))
    assert r.stopped_early
    assert pol.blocks_run >= 2
    assert r.tasks_created == r.steals_successful + pol.blocks_run
    # geometric blocks bound the wasted work
    assert r.items_processed <= 2 * (target + 1) + 2 * 8
    assert r.wasted_items == sum(1 for i in seen if i > target)


def test_composed_adaptor_stack_over_adaptive_policy():
    """Adaptors gate *adaptive* steal-splits now: size_limit refuses splits
    below the threshold and cap bounds live tasks — neither was consulted by
    the old AdaptiveSim."""
    plain = simulate(WorkRange(0, 100_000), AdaptivePolicy(), 8, C1)
    limited = simulate(size_limit(WorkRange(0, 100_000), 50_000),
                       AdaptivePolicy(), 8, C1)
    capped = simulate(cap(WorkRange(0, 100_000), 3), AdaptivePolicy(), 8, C1)
    assert plain.steals_successful == 7
    assert limited.steals_successful == 1        # halves hit the size floor
    assert capped.tasks_created <= 3             # live-task cap honoured
    for r in (plain, limited, capped):
        assert r.items_processed == 100_000      # composition never loses work


def test_composed_depjoin_inner_blocks():
    """depjoin under a by_blocks outer loop (old depjoin flag lived only on
    the monolithic join engine)."""
    pol = ByBlocksPolicy(inner=DepJoinPolicy(), first=16,
                         wrap=lambda b: thief_splitting(b, p=4))
    r = Runtime(4, CostModel(per_item=1.0, reduce_cost=5.0), pol,
                seed=0).run(WorkRange(0, 20_000))
    assert r.items_processed == 20_000
    assert r.reductions == r.divisions           # depjoin semantics intact


def test_serve_admission_simulates_on_unified_runtime():
    """Batch admission picks its k by simulating candidate batches on the
    same Runtime (padding waste vs per-batch overhead)."""
    from repro.serve.engine import AdmissionSimulator
    sim = AdmissionSimulator(lanes=4, batch_overhead=256.0)
    assert sim.choose([100], 8) == 1
    assert sim.choose([64] * 10, 8) == 8        # uniform: amortize overhead
    # one huge request: padding everything to 512 is worse than stopping
    assert sim.choose([16, 16, 16, 512, 16], 8) < 5


def test_train_rebalance_gain_predicted_by_runtime():
    """The straggler rebalancer consults the same Runtime: a 2× straggler
    shows a predicted makespan gain, a balanced pod shows none."""
    from repro.train.straggler import predicted_rebalance_gain
    balanced = predicted_rebalance_gain([1.0] * 8)
    straggler = predicted_rebalance_gain([1.0] * 7 + [2.0])
    assert 0.95 <= balanced <= 1.05
    assert straggler > 1.2


def test_scheduler_simulate_faces():
    """Every scheduler exposes the same dynamic face over the one engine."""
    r1 = JoinScheduler().simulate(thief_splitting(WorkRange(0, 10_000), p=4),
                                  4, C1)
    r2 = JoinScheduler().simulate(thief_splitting(WorkRange(0, 10_000), p=4),
                                  4, C1, depjoin=True)
    r3 = AdaptiveScheduler(demand=8).simulate(WorkRange(0, 10_000), None, C1)
    r4 = ByBlocks(first=8).simulate(WorkRange(0, 10_000), 4, C1,
                                    inner=AdaptivePolicy())
    for r in (r1, r2, r3, r4):
        assert r.items_processed == 10_000
    assert r3.tasks_created == r3.steals_successful + 1
