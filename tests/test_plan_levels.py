"""Plan.levels() / merge_schedule(): the level-order API driving the
level-batched merge kernel (PR 2 tentpole)."""

import numpy as np
import pytest

from repro.core import (SeqWork, WorkRange, bound_depth, build_plan,
                        demand_split, even_levels)


def balanced_plan(n=1024, tile=64):
    import math
    depth = int(math.log2(n // tile))
    return build_plan(even_levels(bound_depth(
        SeqWork(0, n, align=tile, min_size=tile), depth))), depth


def test_levels_groups_nodes_by_depth():
    plan, depth = balanced_plan()
    lv = plan.levels()
    assert len(lv) == depth + 1
    for d, nodes in enumerate(lv):
        assert len(nodes) == 1 << d
        assert all(n.depth == d for n in nodes)
    # leaves all at the deepest level for a complete tree
    assert all(n.is_leaf for n in lv[-1])


def test_node_span_covers_leaves():
    plan, _ = balanced_plan()
    assert plan.root.span() == (0, 1024)
    l, r = plan.root.left.span(), plan.root.right.span()
    assert l == (0, 512) and r == (512, 1024)


def test_merge_schedule_bottom_up_uniform():
    plan, depth = balanced_plan(n=1024, tile=64)
    sched = plan.merge_schedule()
    assert len(sched) == depth
    run = 64
    for level in sched:
        assert level.uniform
        assert level.run_length == run
        assert level.num_pairs == 1024 // (2 * run)
        run *= 2


def test_merge_schedule_even_levels_parity():
    """even_levels work ⇒ an even number of merge levels (the paper's
    right-buffer guarantee, realized on the schedule length)."""
    for n, tile in [(1024, 64), (4096, 256), (1 << 14, 1 << 10)]:
        plan, depth = balanced_plan(n, tile)
        assert depth % 2 == 0
        assert len(plan.merge_schedule()) % 2 == 0


def test_merge_schedule_equivalent_to_map_reduce():
    """Executing the schedule level-by-level reproduces map_reduce's tree
    reduction (on a non-commutative op, so order matters)."""
    plan, _ = balanced_plan(n=256, tile=32)
    expect = plan.map_reduce(lambda w: [(w.start, w.stop)], lambda a, b: a + b)

    spans = {(w.start, w.stop): [(w.start, w.stop)] for w in plan.leaves()}
    for level in plan.merge_schedule():
        for (a, b) in level.pairs:
            spans[(a[0], b[1])] = spans.pop(a) + spans.pop(b)
    assert list(spans) == [(0, 256)]
    assert spans[(0, 256)] == expect


def test_merge_schedule_unbalanced_tree_not_uniform():
    plan = demand_split(WorkRange(0, 100), demand=3)
    sched = plan.merge_schedule()
    # 3 leaves -> 2 merges across (up to) 2 levels, not uniform everywhere
    assert sum(level.num_pairs for level in sched) == 2
    assert not all(level.uniform for level in sched)


def test_merge_schedule_single_leaf_empty():
    plan = build_plan(bound_depth(SeqWork(0, 64, min_size=64), 4))
    assert plan.num_tasks() == 1
    assert plan.merge_schedule() == []
    assert len(plan.levels()) == 1
