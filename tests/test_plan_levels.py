"""Plan.levels() / merge_schedule() / sort_schedule(): the level-order API
driving the level-batched merge kernel (PR 2 tentpole) and the radix
digit-pass metadata of the tile phase (PR 4 tentpole)."""

import numpy as np
import pytest

from repro.core import (DigitPass, SeqWork, WorkRange, bound_depth,
                        build_plan, demand_split, digit_passes, even_levels)


def balanced_plan(n=1024, tile=64):
    import math
    depth = int(math.log2(n // tile))
    return build_plan(even_levels(bound_depth(
        SeqWork(0, n, align=tile, min_size=tile), depth))), depth


def test_levels_groups_nodes_by_depth():
    plan, depth = balanced_plan()
    lv = plan.levels()
    assert len(lv) == depth + 1
    for d, nodes in enumerate(lv):
        assert len(nodes) == 1 << d
        assert all(n.depth == d for n in nodes)
    # leaves all at the deepest level for a complete tree
    assert all(n.is_leaf for n in lv[-1])


def test_node_span_covers_leaves():
    plan, _ = balanced_plan()
    assert plan.root.span() == (0, 1024)
    l, r = plan.root.left.span(), plan.root.right.span()
    assert l == (0, 512) and r == (512, 1024)


def test_merge_schedule_bottom_up_uniform():
    plan, depth = balanced_plan(n=1024, tile=64)
    sched = plan.merge_schedule()
    assert len(sched) == depth
    run = 64
    for level in sched:
        assert level.uniform
        assert level.run_length == run
        assert level.num_pairs == 1024 // (2 * run)
        run *= 2


def test_merge_schedule_even_levels_parity():
    """even_levels work ⇒ an even number of merge levels (the paper's
    right-buffer guarantee, realized on the schedule length)."""
    for n, tile in [(1024, 64), (4096, 256), (1 << 14, 1 << 10)]:
        plan, depth = balanced_plan(n, tile)
        assert depth % 2 == 0
        assert len(plan.merge_schedule()) % 2 == 0


def test_merge_schedule_equivalent_to_map_reduce():
    """Executing the schedule level-by-level reproduces map_reduce's tree
    reduction (on a non-commutative op, so order matters)."""
    plan, _ = balanced_plan(n=256, tile=32)
    expect = plan.map_reduce(lambda w: [(w.start, w.stop)], lambda a, b: a + b)

    spans = {(w.start, w.stop): [(w.start, w.stop)] for w in plan.leaves()}
    for level in plan.merge_schedule():
        for (a, b) in level.pairs:
            spans[(a[0], b[1])] = spans.pop(a) + spans.pop(b)
    assert list(spans) == [(0, 256)]
    assert spans[(0, 256)] == expect


def test_merge_schedule_unbalanced_tree_not_uniform():
    plan = demand_split(WorkRange(0, 100), demand=3)
    sched = plan.merge_schedule()
    # 3 leaves -> 2 merges across (up to) 2 levels, not uniform everywhere
    assert sum(level.num_pairs for level in sched) == 2
    assert not all(level.uniform for level in sched)


def test_merge_schedule_single_leaf_empty():
    plan = build_plan(bound_depth(SeqWork(0, 64, min_size=64), 4))
    assert plan.num_tasks() == 1
    assert plan.merge_schedule() == []
    assert len(plan.levels()) == 1


# ---------------------------------------------------------------------------
# sort_schedule: radix digit-pass metadata (PR 4 tentpole)
# ---------------------------------------------------------------------------

def test_digit_passes_arithmetic():
    """ceil-division pass count; the last pass narrows to the leftover
    bits; shifts start at key_shift and step by digit_bits."""
    assert digit_passes(12, 4) == (DigitPass(0, 4), DigitPass(4, 4),
                                   DigitPass(8, 4))
    assert digit_passes(12, 8) == (DigitPass(0, 8), DigitPass(8, 4))
    # the unfused packed case from the issue: 12 key bits + 20 index bits
    # take ceil(32/8) = 4 eight-bit passes
    assert len(digit_passes(12 + 20, 8)) == 4
    assert digit_passes(12, 8, key_shift=10) == (DigitPass(10, 8),
                                                 DigitPass(18, 4))
    assert digit_passes(0, 4) == ()
    assert digit_passes(1, 4) == (DigitPass(0, 1),)
    with pytest.raises(ValueError, match="digit_bits"):
        digit_passes(12, 0)


def test_sort_schedule_carries_passes_and_levels():
    plan, depth = balanced_plan(n=1024, tile=64)
    sched = plan.sort_schedule(sort_bits=12, digit_bits=4, key_shift=6)
    assert sched.num_passes == 3
    assert all(p.shift == 6 + i * 4 for i, p in
               enumerate(sched.tile_passes))
    assert all(p.radix == 16 for p in sched.tile_passes)
    assert list(sched.levels) == plan.merge_schedule()
    # fused execution cost: one tile-sort launch + one per merge level
    assert sched.num_launches == 1 + depth


def test_sort_schedule_fused_vs_unfused_pass_count():
    """Pack fusion halves the ranked width: in-tile the index bits are the
    already-ordered local positions, so only the key bits need passes."""
    plan, _ = balanced_plan()
    fused = plan.sort_schedule(sort_bits=12, digit_bits=4, key_shift=6)
    unfused = plan.sort_schedule(sort_bits=12 + 20, digit_bits=4)
    assert fused.num_passes == 3
    assert unfused.num_passes == 8


def test_sort_schedule_multi_tile_mode():
    """PR 6: the merge-tree-free schedule — no levels, launch count
    3 launches per digit pass regardless of n."""
    from repro.core import MULTI_TILE_LAUNCHES_PER_PASS, SortSchedule
    for n in (1024, 16384):
        plan, _ = balanced_plan(n=n, tile=64)
        sched = plan.sort_schedule(sort_bits=12, digit_bits=4,
                                   key_shift=10, mode="multi_tile")
        assert sched.mode == "multi_tile"
        assert sched.levels == ()
        assert sched.num_tiles == n // 64
        assert sched.num_passes == 3
        assert sched.num_launches == MULTI_TILE_LAUNCHES_PER_PASS * 3
    # a single tile degenerates to the one-launch fused tile sort
    one = SortSchedule(tile_passes=digit_passes(12, 4), levels=(),
                       mode="multi_tile", num_tiles=1)
    assert one.num_launches == 1
    # schedule invariants are enforced at construction
    with pytest.raises(ValueError, match="merge levels"):
        SortSchedule(tile_passes=digit_passes(12, 4),
                     levels=tuple(balanced_plan()[0].merge_schedule()),
                     mode="multi_tile", num_tiles=16)
    with pytest.raises(ValueError, match="mode"):
        SortSchedule(tile_passes=(), levels=(), mode="bogus")
