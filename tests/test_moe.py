"""MoE routing/dispatch invariants (hypothesis property tests)."""

import dataclasses

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.models.moe import (capacity_per_group, moe_einsum, moe_init,
                              moe_sort_dispatch, route_topk)

KEY = jax.random.PRNGKey(0)


@given(st.integers(2, 64), st.integers(1, 6), st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_route_topk_invariants(e, k, t):
    k = min(k, e)
    w = jax.random.normal(KEY, (8, e), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(t), (t, 8), jnp.float32)
    probs, experts, aux = route_topk(w, x, k)
    assert probs.shape == (t, k) and experts.shape == (t, k)
    # normalized, nonnegative, experts valid and distinct per token
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, atol=1e-5)
    assert (np.asarray(probs) >= 0).all()
    ex = np.asarray(experts)
    assert ((ex >= 0) & (ex < e)).all()
    for row in ex:
        assert len(set(row.tolist())) == k
    assert float(aux) >= 0.99  # E[e·f·p] ≥ 1 with equality at balance


@given(st.integers(8, 4096), st.integers(2, 64), st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_capacity_accommodates_balanced_load(g, e, k):
    k = min(k, e)
    c = capacity_per_group(g, e, k, 1.25)
    assert c * e >= g * k            # total slots ≥ assignments
    assert c % 4 == 0


def test_einsum_vs_sort_dispatch_no_drop():
    cfg = get_smoke_config("llama4-scout-17b-a16e")
    cfg_big = dataclasses.replace(cfg, capacity_factor=8.0)
    params = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32)
    out_e, aux_e = moe_einsum(params, cfg_big, x, group_size=64)
    out_s, aux_s = moe_sort_dispatch(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_s),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-5)


def test_einsum_dispatch_drops_under_capacity_pressure():
    """With capacity_factor ≪ 1 the GShard path drops tokens (residual
    carries them) — outputs differ from dropless by design."""
    cfg = dataclasses.replace(get_smoke_config("llama4-scout-17b-a16e"),
                              capacity_factor=0.1)
    params = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                          jnp.float32)
    out_drop, _ = moe_einsum(params, cfg, x, group_size=64)
    out_full, _ = moe_sort_dispatch(params, cfg, x)
    # dropped rows are exactly zero in the MoE contribution (+ shared expert)
    diff = np.abs(np.asarray(out_drop - out_full)).max()
    assert diff > 1e-3


def test_shared_expert_always_applies():
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    assert cfg.num_shared_experts == 2
    params = moe_init(KEY, cfg)
    x = jnp.zeros((1, 8, cfg.d_model), jnp.float32)
    out, _ = moe_einsum(params, cfg, x)
    # zero input → zero output regardless of routing (sanity)
    assert float(jnp.abs(out).max()) < 1e-5


def test_pallas_dispatch_is_one_launch_and_matches_jnp():
    """PR 6: routing with sort_fn="pallas" — stable sort by expert id plus
    the activation-row gather — runs as a single fused pallas_call, and the
    layer output matches the jnp stable-sort path exactly."""
    from repro.kernels.merge_sort import trace_launches
    from repro.models.moe import sort_route
    cfg = get_smoke_config("llama4-scout-17b-a16e")
    params = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model),
                          jnp.float32)
    jax.clear_caches()
    with trace_launches() as tr:
        xd, se, st, sp, aux = sort_route(params, cfg, x, "pallas")
    assert [r.kind for r in tr] == ["moe_dispatch"]
    xd_j, se_j, st_j, sp_j, aux_j = sort_route(params, cfg, x)
    np.testing.assert_array_equal(np.asarray(se), np.asarray(se_j))
    np.testing.assert_array_equal(np.asarray(st), np.asarray(st_j))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(sp_j))
    np.testing.assert_array_equal(np.asarray(xd), np.asarray(xd_j))
    out_p, _ = moe_sort_dispatch(params, cfg, x, sort_fn="pallas")
    out_j, _ = moe_sort_dispatch(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_j),
                               atol=1e-5, rtol=1e-5)
