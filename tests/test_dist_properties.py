"""Property tests for the repro.dist invariants.

* ``sanitize_spec`` never returns an entry whose mesh-axis product fails to
  divide the dimension, and only ever weakens (drops) entries.
* ``bubble_fraction`` equals the brute-force idle-cell count of
  ``schedule_ticks`` for arbitrary (stages, microbatches).
* ``microbatch_order`` (the plan-driven injection order) is always the
  identity permutation — the division tree's left-to-right leaf walk.
* ``moe_shard_map`` (mesh only) matches the single-shard sort dispatch.

With real ``hypothesis`` these are ``@given`` properties; under the
conftest stub (no hypothesis on the host) they degrade to a seeded random
sweep plus a full small grid instead of skipping, so the tier-1 suite keeps
the coverage either way.
"""

import random

import hypothesis
import pytest

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.pipeline import (bubble_fraction, microbatch_order,
                                 schedule_ticks)
from repro.dist.sharding import sanitize_spec

from conftest import ShapeOnlyMesh

HAVE_HYPOTHESIS = hasattr(hypothesis, "__version__")

_ENTRIES = [None, "data", "model", ("data", "model")]


def _axis_product(mesh, entry):
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape.get(a, 1)
    return n


def check_sanitize(data, model, entry_ids, dims):
    mesh = ShapeOnlyMesh(data=data, model=model)
    entries = [_ENTRIES[i] for i in entry_ids]
    out = sanitize_spec(mesh, P(*entries), tuple(dims))
    got = list(out) + [None] * (len(dims) - len(tuple(out)))
    for dim, before, after in zip(dims, entries, got):
        # invariant 1: every surviving entry divides its dimension
        assert dim % _axis_product(mesh, after) == 0, (dim, after)
        # invariant 2: entries are only kept or dropped, never invented
        assert after in (before, None)
        # invariant 3: dividing entries are preserved verbatim
        if dim % _axis_product(mesh, before) == 0:
            assert after == before


def check_bubble(stages, n_mb):
    table = schedule_ticks(stages, n_mb)
    assert len(table) == n_mb + stages - 1
    idle = sum(cell == "-" for row in table for cell in row)
    total = stages * len(table)
    assert bubble_fraction(stages, n_mb) == pytest.approx(idle / total)
    # every stage processes the full plan order exactly once
    order = [str(i) for i in microbatch_order(n_mb)]
    for s in range(stages):
        assert [row[s] for row in table if row[s] != "-"] == order


def check_order(n_mb):
    order = microbatch_order(n_mb)
    assert order == list(range(n_mb))


def test_degenerate_schedules_rejected():
    with pytest.raises(ValueError):
        schedule_ticks(4, 0)
    with pytest.raises(ValueError):
        bubble_fraction(4, 0)
    with pytest.raises(ValueError):
        schedule_ticks(0, 8)
    with pytest.raises(ValueError):
        bubble_fraction(0, 8)


def test_sanitize_drops_axes_absent_from_mesh():
    # a 'pipe'-only mesh cannot express 'model'; the guard must replicate,
    # not pass the spec through as if the axis had size 1
    mesh = ShapeOnlyMesh(pipe=4)
    assert sanitize_spec(mesh, P("model", None), (4, 4)) == P(None, None)
    assert sanitize_spec(mesh, P(("data", "model"),), (4,)) == P(None)


if HAVE_HYPOTHESIS:
    from hypothesis import given, strategies as st

    @given(st.integers(1, 4), st.integers(1, 4),
           st.lists(st.integers(0, len(_ENTRIES) - 1), min_size=1,
                    max_size=4),
           st.data())
    def test_sanitize_never_nondividing(data, model, entry_ids, draw):
        dims = draw.draw(st.lists(st.integers(1, 24),
                                  min_size=len(entry_ids),
                                  max_size=len(entry_ids)))
        check_sanitize(data, model, entry_ids, dims)

    @given(st.integers(1, 8), st.integers(1, 16))
    def test_bubble_matches_idle_count(stages, n_mb):
        check_bubble(stages, n_mb)

    @given(st.integers(1, 32))
    def test_microbatch_order_is_plan_leaf_walk(n_mb):
        check_order(n_mb)
else:
    _RNG = random.Random(0)
    _SANITIZE_CASES = []
    for _ in range(50):
        rank = _RNG.randint(1, 4)
        _SANITIZE_CASES.append((
            _RNG.randint(1, 4), _RNG.randint(1, 4),
            tuple(_RNG.randrange(len(_ENTRIES)) for _ in range(rank)),
            tuple(_RNG.randint(1, 24) for _ in range(rank))))

    @pytest.mark.parametrize("data,model,entry_ids,dims", _SANITIZE_CASES)
    def test_sanitize_never_nondividing(data, model, entry_ids, dims):
        check_sanitize(data, model, entry_ids, dims)

    @pytest.mark.parametrize("stages", range(1, 9))
    @pytest.mark.parametrize("n_mb", [1, 2, 3, 4, 7, 8, 13, 16])
    def test_bubble_matches_idle_count(stages, n_mb):
        check_bubble(stages, n_mb)

    @pytest.mark.parametrize("n_mb", range(1, 33))
    def test_microbatch_order_is_plan_leaf_walk(n_mb):
        check_order(n_mb)


# ---------------------------------------------------------------------------
# expert-parallel dispatch: degenerate 1x1 mesh everywhere (shard_map path
# still exercised), real 2x2 expert/token partitioning in the mesh8 CI job
# ---------------------------------------------------------------------------

def test_moe_shard_map_matches_single_shard():
    import dataclasses
    from repro.configs.registry import get_smoke_config
    from repro.dist.expert import moe_shard_map
    from repro.launch.mesh import make_host_mesh
    from repro.models.moe import moe_init, moe_sort_dispatch

    cfg = dataclasses.replace(get_smoke_config("deepseek-v2-lite-16b"),
                              param_dtype="float32",
                              compute_dtype="float32")
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    ref, aux_ref = moe_sort_dispatch(params, cfg, x)
    n = 2 if jax.device_count() >= 4 else 1
    mesh = make_host_mesh(n, n)
    with mesh:
        out, aux = moe_shard_map(params, cfg, x, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    assert float(aux) == pytest.approx(float(aux_ref))
