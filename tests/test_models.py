"""Per-architecture smoke tests (reduced configs, real arrays, CPU) +
decode/prefill consistency — the assignment's required smoke coverage."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.train.step import TrainState, make_train_step

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32, key=KEY):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 1, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)).astype(cfg.dtype())
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (B, S, cfg.d_model)).astype(cfg.dtype())
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg, max_decoder_positions=64)
    params = model.init(KEY)
    loss, metrics = model.loss_fn(params, make_batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert bool(jnp.isfinite(metrics["xent"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg, max_decoder_positions=64)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1)
    step = make_train_step(model, opt_cfg, num_microbatches=2)
    params = model.init(KEY)
    state = TrainState(params=params, opt=init_state(opt_cfg, params))
    state, metrics = step(state, make_batch(cfg, B=4))
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert bool(jnp.isfinite(metrics["grad_norm"])), arch
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_two_steps_loss_changes(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg, max_decoder_positions=64)
    opt_cfg = AdamWConfig(lr_peak=1e-2, warmup_steps=1, weight_decay=0.0)
    step = jax.jit(make_train_step(model, opt_cfg))
    params = model.init(KEY)
    state = TrainState(params=params, opt=init_state(opt_cfg, params))
    losses = []
    for i in range(3):
        state, metrics = step(state, make_batch(cfg, key=jax.random.PRNGKey(i)))
        losses.append(float(metrics["loss"]))
    assert losses[0] != losses[-1], f"{arch}: optimizer had no effect"


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_smoke_config(a).is_encdec])
def test_decode_matches_teacher_forcing(arch):
    """prefill + 3 decode steps == full forward (fp32, dropless MoE)."""
    cfg = dataclasses.replace(get_smoke_config(arch),
                              param_dtype="float32",
                              compute_dtype="float32")
    model = Model(cfg, moe_strategy="sort")
    params = model.init(KEY)
    B, S = 2, 17
    toks = jax.random.randint(KEY, (B, S + 3), 1, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_image_tokens, cfg.d_model)).astype(cfg.dtype())
    _, cache = model.prefill(params, batch, max_seq=S + 3)
    lengths = jnp.full((B,), S, jnp.int32)
    for t in range(3):
        lg, cache = model.decode_step(params, toks[:, S + t], cache, lengths)
        lengths = lengths + 1
    full = dict(batch)
    full["tokens"] = toks
    lf, _ = model.prefill(params, full, max_seq=S + 3)
    err = float(jnp.max(jnp.abs(lg - lf)))
    scale = float(jnp.max(jnp.abs(lf))) + 1e-6
    assert err / scale < 1e-3, f"{arch}: decode diverges from forward"


def test_encdec_decode_runs():
    cfg = get_smoke_config("whisper-medium")
    model = Model(cfg, max_decoder_positions=64)
    params = model.init(KEY)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(KEY, (B, S), 1, cfg.vocab_size),
             "frames": jax.random.normal(KEY, (B, S, cfg.d_model)
                                         ).astype(cfg.dtype())}
    logits, cache = model.prefill(params, batch, max_seq=S + 4)
    lengths = jnp.full((B,), S, jnp.int32)
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache, lengths)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
        lengths = lengths + 1
    assert bool(jnp.isfinite(logits[:, :cfg.vocab_size]).all())


def test_vocab_padding_masked():
    cfg = get_smoke_config("whisper-medium")
    assert cfg.vocab_padding > 0
    model = Model(cfg, max_decoder_positions=64)
    params = model.init(KEY)
    B, S = 1, 8
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "frames": jnp.zeros((B, S, cfg.d_model), cfg.dtype())}
    logits, _ = model.prefill(params, batch, max_seq=S)
    pad_logits = logits[:, cfg.vocab_size:]
    assert bool((pad_logits < -1e20).all()), "padded vocab rows must be -inf"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_positive_and_plausible(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 0
    # coarse plausibility vs the names (e.g. llama3-8b within 2x of 8e9)
    expectations = {
        "llama3-8b": 8e9, "yi-9b": 8.8e9, "minitron-4b": 4e9,
        "chatglm3-6b": 6e9, "whisper-medium": 0.76e9,
        "deepseek-v2-lite-16b": 16e9, "xlstm-1.3b": 1.3e9,
        "jamba-1.5-large-398b": 398e9,
    }
    if arch in expectations:
        assert 0.5 * expectations[arch] < n < 2.2 * expectations[arch], \
            f"{arch}: {n/1e9:.2f}B params vs expected {expectations[arch]/1e9}B"
    if cfg.is_moe:
        assert cfg.param_count(active_only=True) < n
