"""End-to-end chaos: the wall-clock fault layer (repro.chaos) against the
production train/serve wiring.

Covers the full fault taxonomy above the virtual-time Runtime (which
tests/test_faults.py owns):

* checkpoint I/O faults absorbed by retry-with-backoff / surfaced on
  exhaustion, with atomicity intact either way,
* corruption: per-leaf sha256 catches flipped bytes, manifest truncation
  fails at parse, pre-sha256 checkpoints stay restorable,
* ``gc_incomplete``: orphaned .tmp dirs are swept on restart and never
  shadow complete checkpoints,
* SIGTERM: real signal → flag at the step boundary → final checkpoint →
  resume with zero lost/repeated samples,
* serve: a straggling prefill is preempted at a by_blocks boundary, the
  bounded residual requeued, and the preempted engine's outputs match the
  unpreempted engine exactly,
* mesh8 tier: kill a host mid-step and survive it — eviction justified by
  telemetry + the simulated policy, ``choose_mesh`` over the survivors,
  restore resharded through host memory, resume matching the uninterrupted
  trajectory.
"""

import dataclasses
import json
import signal

import numpy as np
import pytest

import jax

from repro.chaos import (CheckpointIOFaults, HostDeathInjector, HostLost,
                         SigtermInjector, corrupt_checkpoint)
from repro.configs.registry import get_smoke_config
from repro.core import (CheckpointWriteFault, FaultPlan, HostDeath,
                        PreemptionFault)
from repro.data.pipeline import DataConfig
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, init_state
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import ElasticController, choose_mesh
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import (TrainState, abstract_train_state,
                              train_state_shardings)
from repro.train.straggler import (StragglerDetector, TelemetryBuffer,
                                   predicted_rebalance_gain)

KEY = jax.random.PRNGKey(0)

needs_mesh8 = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs XLA_FLAGS device_count>=8")


def _tiny_state():
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    opt_cfg = AdamWConfig()
    params = model.init(KEY)
    return cfg, model, opt_cfg, TrainState(params=params,
                                           opt=init_state(opt_cfg, params))


def _abstract(state):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)


# ---------------------------------------------------------------------------
# checkpoint I/O faults: retry absorbs, exhaustion surfaces, atomicity holds
# ---------------------------------------------------------------------------

def test_ckpt_io_fault_absorbed_by_retry(tmp_path):
    _, _, _, state = _tiny_state()
    inj = CheckpointIOFaults(FaultPlan(
        checkpoint_faults=(CheckpointWriteFault(1),)))
    mgr = CheckpointManager(str(tmp_path), retries=1, io_check=inj)
    mgr.save(1, state, extra={"data_step": 1}, blocking=True)
    assert inj.attempts == 2          # first attempt failed, retry landed
    assert mgr.latest_step() == 1
    restored, extra = mgr.restore(_abstract(state))
    assert extra["data_step"] == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_io_fault_exhausts_retries_blocking(tmp_path):
    _, _, _, state = _tiny_state()
    inj = CheckpointIOFaults(FaultPlan(checkpoint_faults=(
        CheckpointWriteFault(1), CheckpointWriteFault(2))))
    mgr = CheckpointManager(str(tmp_path), retries=1, io_check=inj)
    with pytest.raises(RuntimeError, match="async checkpoint failed"):
        mgr.save(1, state, blocking=True)
    assert inj.attempts == 2
    # atomicity: a failed save leaves no step dir and no .tmp litter
    assert mgr.steps() == []
    assert list(mgr.dir.glob("*.tmp-*")) == []


def test_ckpt_io_fault_async_surfaces_on_wait(tmp_path):
    _, _, _, state = _tiny_state()
    inj = CheckpointIOFaults(FaultPlan(checkpoint_faults=(
        CheckpointWriteFault(1), CheckpointWriteFault(2),
        CheckpointWriteFault(3))))
    mgr = CheckpointManager(str(tmp_path), retries=2, io_check=inj)
    mgr.save(1, state, blocking=False)
    with pytest.raises(RuntimeError, match="async checkpoint failed"):
        mgr.wait()
    assert inj.attempts == 3 and mgr.steps() == []


def test_trainer_wires_retry_config(tmp_path):
    cfg = get_smoke_config("minitron-4b")
    model = Model(cfg)
    t = Trainer(model, AdamWConfig(),
                DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                           global_batch=2),
                LoopConfig(total_steps=1, ckpt_dir=str(tmp_path),
                           ckpt_retries=3, ckpt_backoff_s=0.0))
    assert t.ckpt.retries == 3


# ---------------------------------------------------------------------------
# corruption: sha256 catches flipped bytes, manifests fail at parse
# ---------------------------------------------------------------------------

def test_corrupt_leaf_fails_loudly(tmp_path):
    _, _, _, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state, blocking=True)
    corrupt_checkpoint(str(tmp_path), 3, target="leaf", leaf_index=2)
    with pytest.raises(ValueError,
                       match=r"checkpoint corruption: leaf 2"):
        mgr.restore(_abstract(state))


def test_corrupt_manifest_fails_at_parse(tmp_path):
    _, _, _, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state, blocking=True)
    corrupt_checkpoint(str(tmp_path), 3, target="manifest")
    with pytest.raises(json.JSONDecodeError):
        mgr.restore(_abstract(state))


def test_manifest_carries_sha256_and_presha_restores(tmp_path):
    """Every leaf is hashed; stripping the hashes (a pre-sha256 checkpoint)
    must still restore — the check is forward-compatible, not a lockout."""
    _, _, _, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=True)
    mf = tmp_path / "step_00000001" / "manifest.json"
    manifest = json.loads(mf.read_text())
    assert all(len(leaf["sha256"]) == 64 for leaf in manifest["leaves"])
    for leaf in manifest["leaves"]:
        del leaf["sha256"]
    mf.write_text(json.dumps(manifest))
    restored, _ = mgr.restore(_abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# gc_incomplete: orphaned .tmp dirs are swept and never shadow completes
# ---------------------------------------------------------------------------

def test_gc_incomplete_sweeps_orphans_on_restart(tmp_path):
    _, _, _, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state, blocking=True)
    # crash mid-save: one tmp for the same step, one for a LATER step
    same = tmp_path / "step_00000003.tmp-111"
    later = tmp_path / "step_00000005.tmp-222"
    for d in (same, later):
        d.mkdir()
        (d / "arr_00000.npy").write_bytes(b"garbage")
    # even before gc, tmp dirs are invisible to step discovery: the
    # half-written step 5 must not shadow the complete step 3
    assert mgr.steps() == [3] and mgr.latest_step() == 3
    mgr2 = CheckpointManager(str(tmp_path))       # restart → gc
    assert not same.exists() and not later.exists()
    assert mgr2.latest_step() == 3
    restored, _ = mgr2.restore(_abstract(state))  # complete dir untouched
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# SIGTERM: real signal → step-boundary flag → final checkpoint → exact resume
# ---------------------------------------------------------------------------

def test_sigterm_preemption_resumes_exactly(tmp_path):
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, seed=5)

    def trainer(ckpt_dir, total=6):
        return Trainer(model, opt_cfg, data_cfg,
                       LoopConfig(total_steps=total, ckpt_every=100,
                                  ckpt_dir=str(ckpt_dir), log_every=100))

    # uninterrupted reference
    t_ref = trainer(tmp_path / "ref")
    state_ref = t_ref.run()

    # deliver a real SIGTERM at step 3; the handler flips the flag, the
    # in-flight step completes, a final blocking checkpoint runs
    inj = SigtermInjector(FaultPlan(preemptions=(PreemptionFault(3),)))
    old_term = signal.getsignal(signal.SIGTERM)
    old_int = signal.getsignal(signal.SIGINT)
    try:
        t1 = trainer(tmp_path / "chaos")
        t1.install_signal_handlers()
        t1.run(on_step=inj)
    finally:
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)
    assert inj.delivered == [3]
    assert t1._preempted
    assert t1.ckpt.latest_step() == 3             # checkpointed at the flag
    assert t1.pipeline.state.step == 3            # 3 batches consumed

    # resume: same step, no lost or repeated samples
    t2 = trainer(tmp_path / "chaos")
    state_res = t2.run()
    assert t2.start_step == 3
    assert t2.pipeline.state.step == 6 == t_ref.pipeline.state.step
    for a, b in zip(jax.tree.leaves(state_ref.params),
                    jax.tree.leaves(state_res.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


# ---------------------------------------------------------------------------
# serve: preempt a straggling prefill at a by_blocks boundary
# ---------------------------------------------------------------------------

def _fp32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


def test_prefill_preemption_residual_bounded():
    """max_blocks stops at a block boundary; the only overshoot is the block
    in flight, bounded by growth/(1+growth) of the processed prefix."""
    from repro.serve.prefill import ChunkedPrefill
    cfg = _fp32(get_smoke_config("llama3-8b"))
    model = Model(cfg)
    params = model.init(KEY)
    S = 512
    toks = (np.arange(S, dtype=np.int32)[None, :] % 50) + 3
    pf = ChunkedPrefill(model, first_block=32, growth=2.0, align=32,
                        max_block=512)
    cache = model.init_cache(1, S)
    logits, cache, st = pf.run(params, toks, cache, max_blocks=3)
    assert st.preempted and st.blocks == 3
    assert st.next_start == st.tokens == 32 + 64 + 128
    assert st.last_block <= (2.0 / 3.0) * st.tokens     # growth/(1+growth)
    # resume from the boundary: the cache already holds the prefix
    logits2, cache, st2 = pf.run(params, toks, cache, start=st.next_start)
    assert not st2.preempted
    assert st.tokens + st2.tokens == S
    # exactness: same logits as an unpreempted prefill
    full_logits, _, full_st = pf.run(params, toks, model.init_cache(1, S))
    assert not full_st.preempted and full_st.tokens == S
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(full_logits),
                               atol=1e-5, rtol=1e-5)


def test_engine_preemption_matches_unpreempted():
    """A block budget makes long prefills yield; the residual resumes with
    priority and the finished outputs are identical to no preemption."""
    from repro.serve.engine import Engine, EngineConfig, Request
    cfg = _fp32(get_smoke_config("llama3-8b"))
    model = Model(cfg)
    params = model.init(KEY)

    def reqs():
        return [Request(rid=i,
                        prompt=(np.arange(120 + i, dtype=np.int32) % 50) + 3,
                        max_new=8) for i in range(2)]

    base = Engine(model, params, EngineConfig(max_batch=2, eos_id=7))
    for r in reqs():
        base.submit(r)
    done_base = base.step()
    assert len(done_base) == 2

    pre = Engine(model, params, EngineConfig(max_batch=2, eos_id=7,
                                             prefill_block_budget=1))
    for r in reqs():
        pre.submit(r)
    empty_steps = 0
    done_pre = []
    for _ in range(12):
        out = pre.step()
        if out:
            done_pre = out
            break
        assert pre._residual is not None      # yielded, residual stashed
        empty_steps += 1
    assert empty_steps >= 1                   # it really was preempted
    assert len(done_pre) == 2
    for a, b in zip(done_base, done_pre):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.result, b.result)


def test_engine_residual_has_priority_over_admissions():
    from repro.serve.engine import Engine, EngineConfig, Request
    cfg = _fp32(get_smoke_config("llama3-8b"))
    model = Model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, EngineConfig(max_batch=2, eos_id=7,
                                             prefill_block_budget=1))
    eng.submit(Request(rid=0,
                       prompt=(np.arange(120, dtype=np.int32) % 50) + 3,
                       max_new=4))
    assert eng.step() == []                   # preempted
    # a new request arrives while the residual is parked
    eng.submit(Request(rid=1, prompt=np.arange(8, dtype=np.int32) + 3,
                       max_new=4))
    finished = []
    for _ in range(12):
        finished.extend(r.rid for r in eng.step())
        if len(finished) == 2:
            break
    assert finished == [0, 1]                 # residual first, then rid 1


# ---------------------------------------------------------------------------
# serve: SIGTERM drain + handoff, and a slot-death storm under replay
# ---------------------------------------------------------------------------

def _continuous(model, params, **kw):
    from repro.serve.engine import ContinuousEngine, EngineConfig
    kw.setdefault("max_batch", 2)
    kw.setdefault("eos_id", 7)
    kw.setdefault("max_seq", 224)
    return ContinuousEngine(model, params, EngineConfig(**kw))


def _slo_reqs(vocab, n=4):
    from repro.serve.engine import Request
    rng = np.random.RandomState(7)
    return [Request(rid=i,
                    prompt=rng.randint(8, vocab, size=24 + 9 * i)
                    .astype(np.int32), max_new=10)
            for i in range(n)]


def test_sigterm_drains_continuous_engine_handoff_resumes_exactly():
    """Real SIGTERM mid-serve: the flag flips at the step boundary,
    in-flight slots drain to completion, the waiting queue survives for
    handoff, and resubmission on a fresh engine yields the exact tokens of
    an undisturbed run — zero requests lost, zero duplicated."""
    import os
    cfg = _fp32(get_smoke_config("llama3-8b"))
    model = Model(cfg)
    params = model.init(KEY)
    vocab = cfg.vocab_size

    refs = {}
    ref_eng = _continuous(model, params)
    for r in _slo_reqs(vocab):
        ref_eng.submit(r)
    for _ in range(200):
        if not ref_eng.pending:
            break
        for r in ref_eng.step():
            refs[r.rid] = np.asarray(r.result)
    assert sorted(refs) == [0, 1, 2, 3]

    eng = _continuous(model, params, prefill_block_budget=1)
    old = signal.getsignal(signal.SIGTERM)
    done = []
    try:
        eng.install_signal_handlers()
        for r in _slo_reqs(vocab):
            eng.submit(r)
        done.extend(eng.step())           # some work in flight
        os.kill(os.getpid(), signal.SIGTERM)
        for _ in range(200):              # drain mode: no new admissions
            if not eng.pending:
                break
            done.extend(eng.step())
    finally:
        signal.signal(signal.SIGTERM, old)
    assert eng.preempted
    waiting = eng.handoff()
    assert waiting and eng.queue == []    # queue froze, then detached
    assert not any(s is not None for s in eng.slots)   # slots fully drained
    assert eng._job is None and eng._parked is None

    resumed = _continuous(model, params)
    for r in waiting:
        resumed.submit(r)
    for _ in range(200):
        if not resumed.pending:
            break
        done.extend(resumed.step())
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]   # conservation
    for r in done:
        np.testing.assert_array_equal(refs[r.rid], np.asarray(r.result))


def test_slot_death_storm_replay_conserves_and_reserves_exactly():
    """Planned decode-lane deaths during a wall-clock replay: every killed
    request is requeued exactly once per death, re-served from scratch,
    and its final tokens match the undisturbed run."""
    from repro.chaos.serving import (ReplayResult, SlotDeathInjector,
                                     TraceItem, make_request, replay)
    from repro.core import SlotDeath
    cfg = _fp32(get_smoke_config("llama3-8b"))
    model = Model(cfg)
    params = model.init(KEY)
    vocab = cfg.vocab_size
    trace = tuple(TraceItem(rid=i, arrival=0.0, prompt_len=16 + 7 * i,
                            max_new=12) for i in range(4))

    calm = replay(_continuous(model, params), trace, vocab=vocab)
    assert isinstance(calm, ReplayResult) and calm.conserved(trace)
    refs = {r.rid: np.asarray(r.result) for r in calm.served}

    inj = SlotDeathInjector(FaultPlan(slot_deaths=(
        SlotDeath(at_step=2, slot=0), SlotDeath(at_step=4, slot=1),
        SlotDeath(at_step=6, slot=9))))     # slot 9 doesn't exist: ignored
    eng = _continuous(model, params)
    stormy = replay(eng, trace, vocab=vocab, on_step=inj)
    assert stormy.conserved(trace) and not stormy.shed
    assert eng.telemetry.slot_deaths == len(inj.killed)
    assert sum(r.requeues for r in stormy.served) == len(inj.killed)
    for r in stormy.served:
        np.testing.assert_array_equal(refs[r.rid], np.asarray(r.result))


# ---------------------------------------------------------------------------
# mesh8 tier: kill a host mid-step and survive it
# ---------------------------------------------------------------------------

@needs_mesh8
def test_mesh8_kill_host_elastic_recovery(tmp_path):
    """The full elastic cycle on 8 host devices (2 hosts x 4):

    uninterrupted 6-step reference on a 2x4 mesh  vs  a run where host 1
    vanishes with step 5 in flight (last checkpoint: step 4).  Straggler
    telemetry + the simulated policy justify eviction; ``choose_mesh``
    re-meshes over the 4 survivors; restore reshards the step-4 checkpoint
    through host memory onto the new mesh; resume replays step 5 and
    finishes — final params match the uninterrupted run and the data
    counter proves zero lost or repeated samples."""
    from repro.dist.sharding import mesh_context

    cfg = _fp32(get_smoke_config("llama3-8b"))
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, seed=11)
    TOTAL = 6

    def trainer(ckpt_dir):
        return Trainer(model, opt_cfg, data_cfg,
                       LoopConfig(total_steps=TOTAL, ckpt_every=2,
                                  ckpt_dir=str(ckpt_dir), log_every=100))

    # --- reference: uninterrupted on the full 2-host mesh -----------------
    mesh8 = choose_mesh(8, prefer_model=4)
    assert mesh8.shape["data"] == 2 and mesh8.shape["model"] == 4
    t_ref = trainer(tmp_path / "ref")
    with mesh_context(mesh8):
        state_ref = t_ref.run()
    assert t_ref.pipeline.state.step == TOTAL

    # --- chaos: host 1 (devices 4..7) dies with step 5 in flight ----------
    plan = FaultPlan(host_deaths=(HostDeath(host=1, at_step=5,
                                            devices_per_host=4),))
    t1 = trainer(tmp_path / "chaos")
    with mesh_context(mesh8):
        with pytest.raises(HostLost) as ei:
            t1.run(on_step=HostDeathInjector(plan))
    assert ei.value.host == 1 and ei.value.step == 5
    t1.ckpt.wait()                    # drain the async step-4 write
    assert t1.ckpt.latest_step() == 4          # step 5 died with the host

    # --- eviction justified: EWMA flags the host, the simulated policy ----
    # says rebalancing onto survivors is worth >=1.3x ----------------------
    telemetry = TelemetryBuffer(num_replicas=2)  # one DP replica per host
    detector = StragglerDetector(threshold=1.4, patience=3)
    evict = None
    for _ in range(3):
        telemetry.record_all([0.1, 0.5])      # host 1 straggled pre-death
        evict = detector.check(telemetry)
    assert evict == 1
    gain = predicted_rebalance_gain(list(telemetry.ewma))
    assert gain >= 1.3

    # --- re-mesh over survivors, reshard through host memory --------------
    survivors = jax.devices()[:4]
    ctl = ElasticController(prefer_model=4)
    new_mesh = ctl.remesh(survivors)
    assert new_mesh.size == 4 and new_mesh.shape["model"] == 4
    t2 = trainer(tmp_path / "chaos")
    sshard = train_state_shardings(cfg, model, opt_cfg, new_mesh)
    state, extra = ctl.reshard_state(t2.ckpt,
                                     abstract_train_state(model, opt_cfg),
                                     sshard)
    leaf = jax.tree.leaves(state.params)[0]
    assert set(leaf.sharding.device_set) <= set(survivors)

    # --- resume: replay the lost step, finish on the small mesh -----------
    t2.pipeline.state.step = int(extra["data_step"])
    t2.start_step = t2.ckpt.latest_step()
    assert t2.start_step == 4 and t2.pipeline.state.step == 4
    with mesh_context(new_mesh):
        state_b = t2.run(state)
    assert t2.pipeline.state.step == TOTAL == t_ref.pipeline.state.step
    for a, b in zip(jax.tree.leaves(state_ref.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=2e-4)
