"""Unit tests for the HLO analyzer and data auditing."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (HloAnalysis, analyze_hlo,
                                       shape_bytes_and_elems, shape_dims)


def test_shape_parsing():
    b, e = shape_bytes_and_elems("bf16[2,4,8]")
    assert e == 64 and b == 128
    b2, e2 = shape_bytes_and_elems("(f32[4]{0}, s32[2,2]{1,0})")
    assert e2 == 8 and b2 == 32
    assert shape_dims("f32[3,5]{1,0}") == [3, 5]
    assert shape_dims("f32[]") == []


def test_scan_trip_count_multiplied():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    r = analyze_hlo(txt)
    ideal = 8 * 2 * 128 ** 3
    assert 0.95 * ideal < r["flops_per_chip"] < 1.1 * ideal
    # XLA's own counter reports ~1/8 of that (the undercount we fix);
    # cost_analysis() returns a per-computation list on some jax versions
    ca = jax.jit(f).lower(x, w).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < 0.2 * r["flops_per_chip"]


def test_dot_flops_single():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    r = analyze_hlo(txt)
    assert abs(r["flops_per_chip"] - 2 * 64 * 256 * 32) / (2*64*256*32) < 0.05


def test_traffic_excludes_elementwise_chains():
    def f(x):
        for _ in range(20):
            x = jnp.tanh(x) + 1.0
        return x
    x = jax.ShapeDtypeStruct((1 << 16,), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    r = analyze_hlo(txt)
    # filtered traffic stays near a couple of passes over x, not 20
    assert r["traffic_bytes_per_chip"] <= 12 * (1 << 18)
    assert r["bytes_all_ops_per_chip"] >= r["traffic_bytes_per_chip"]


# ---------------------------------------------------------------------------
# data auditing (repro.data.validate)
# ---------------------------------------------------------------------------

from repro.data.validate import all_finite, audit_pytree, tokens_in_range


def test_all_finite_clean_and_poisoned():
    x = np.ones(100_000, np.float32)
    assert all_finite(x).ok
    x[12345] = np.inf
    r = all_finite(x)
    assert not r.ok
    lo, hi = r.first_bad_block
    assert lo <= 12345 < hi
    assert r.stats.items_run < len(x)          # early abort


def test_tokens_in_range():
    t = np.array([[0, 5, 99], [3, -1, 98]], np.int32)
    assert tokens_in_range(t, 100).ok
    assert not tokens_in_range(t, 50).ok


def test_audit_pytree_flags_bad_leaf():
    tree = {"good": jnp.ones((8, 8)),
            "bad": jnp.array([1.0, float("nan")])}
    ok, bad = audit_pytree(tree)
    assert not ok and any("bad" in p for p in bad)


# ---------------------------------------------------------------------------
# kv cache utilities
# ---------------------------------------------------------------------------

from repro.configs.registry import get_smoke_config
from repro.models.model import Model
from repro.serve.kvcache import PageTable, cache_bytes


def test_cache_bytes_positive_and_scales():
    model = Model(get_smoke_config("llama3-8b"))
    b1 = cache_bytes(model, 2, 64)
    b2 = cache_bytes(model, 2, 128)
    assert 0 < b1 < b2 <= 2 * b1 + 1024


def test_page_table_lifecycle():
    pt = PageTable(page_size=16, num_pages=8)
    pages = pt.allocate(rid=1, seq_len=40)      # 3 pages
    assert len(pages) == 3 and pt.utilization == pytest.approx(3 / 8)
    assert pt.extend(1, 70)                     # grows to 5
    assert len(pt.owner[1]) == 5
    assert pt.allocate(2, 200) is None          # won't fit
    pt.release(1)
    assert pt.utilization == 0.0
