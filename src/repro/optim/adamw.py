"""AdamW with mixed-precision moments and ZeRO-1-shardable state.

No optax dependency — explicit state pytrees keep sharding control total:
moments live in ``cfg.moment_dtype`` (fp32 default; bf16 for Jamba-398B) and
are sharded over the data axis (ZeRO-1) by ``dist.sharding.moments_shardings``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    m: Any                     # pytree like params (moment_dtype)
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = cfg.lr_peak * jnp.minimum(1.0, s / max(1, cfg.warmup_steps))
    t = jnp.clip((s - cfg.warmup_steps) / max(1, cfg.decay_steps), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params: Any) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: AdamWState) -> Tuple[Any, AdamWState, Dict[str, Any]]:
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    mdt = jnp.dtype(cfg.moment_dtype)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


__all__ = ["AdamWConfig", "AdamWState", "init_state", "apply_updates",
           "lr_schedule", "global_norm"]
