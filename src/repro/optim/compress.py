"""Gradient compression with error feedback — DP all-reduce volume ÷4.

int8 block-quantized gradients: per-block (128 values) absmax scaling, the
quantization residual is carried to the next step (error feedback keeps
SGD/Adam convergence — Seide et al. / Karimireddy et al.).  The all-reduce
then moves 1 byte + 1/128 fp16 scale per element instead of 4 (or 2).

Wired into the trainer as an optional gradient transform; the dry-run
measures the collective-byte reduction on DP-bound cells (§Perf).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


def _pad_to_block(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x → (int8 payload, fp32 per-block scales)."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype
               ) -> jnp.ndarray:
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_tree(grads: Any, error: Any) -> Tuple[Any, Any, Any]:
    """Quantize grads+error; returns (q_tree, scales_tree, new_error)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        back = dequantize(q, s, g.shape, jnp.float32)
        return q, s, (target - back)

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = jax.tree_util.tree_flatten(error)[0]
    qs, ss, es = zip(*[one(g, e) for g, e in zip(leaves, errs)])
    u = jax.tree_util.tree_unflatten
    return u(treedef, qs), u(treedef, ss), u(treedef, es)


def decompress_tree(q_tree: Any, s_tree: Any, like: Any) -> Any:
    return jax.tree.map(
        lambda q, s, g: dequantize(q, s, g.shape, g.dtype),
        q_tree, s_tree, like)


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grad_transform(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Round-trip compress (what the wire would carry) with error feedback.

    In the SPMD program the psum happens over the int8 payload upstream of
    this call; on this host build we model the numerics exactly and let the
    dry-run count the byte reduction.
    """
    q, s, new_error = compress_tree(grads, error)
    return decompress_tree(q, s, grads), new_error


def compression_ratio(params: Any) -> float:
    total = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    wire = sum(x.size * 1 + (x.size // BLOCK + 1) * 4
               for x in jax.tree.leaves(params))
    return total / wire


__all__ = ["quantize", "dequantize", "compress_tree", "decompress_tree",
           "init_error", "compressed_grad_transform", "compression_ratio",
           "BLOCK"]
