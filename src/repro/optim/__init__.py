"""repro.optim"""
