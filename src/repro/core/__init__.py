"""repro.core — Kvik's policy layer, adapted to a statically-compiled target.

Public surface:

* Divisibles:  ``WorkRange``, ``BatchWork``, ``SeqWork``, ``TileGrid2D``,
               ``ZipDivisible``, ``PermRange``
* Adaptors:    ``bound_depth``, ``even_levels``, ``force_depth``,
               ``size_limit``, ``cap``, ``join_context``, ``thief_splitting``
* Schedulers:  ``JoinScheduler``/``schedule_join``, ``ByBlocks``/``by_blocks``,
               ``AdaptiveScheduler``/``adaptive``
* Plans:       ``build_plan``, ``demand_split``, ``geometric_blocks``
* Faults:      ``FaultPlan`` + event types (``WorkerDeath``, ``Slowdown``,
               ``CheckpointWriteFault``, ``CorruptionFault``,
               ``PreemptionFault``, ``HostDeath``) — deterministic fault
               injection into the Runtime and the chaos harness
* D&C:         ``wrap_iter``, ``work_loop``
* Runtime:     ``Runtime`` (the one discrete-event engine) + ``CostModel``/
               ``SimResult``; policies ``JoinPolicy``, ``DepJoinPolicy``,
               ``AdaptivePolicy``, ``StaticPartitionPolicy``,
               ``ByBlocksPolicy`` and the ``simulate`` face.  Legacy shims:
               ``WorkStealingSim``, ``AdaptiveSim``, ``static_partition_sim``.
"""

from .divisible import (Divisible, Producer, WorkRange, BatchWork, SeqWork,
                        TileGrid2D, ZipDivisible, WorkSet, PermRange,
                        total_permutations)
from .adaptors import (Adaptor, StealContext, bound_depth, even_levels,
                       force_depth, size_limit, cap, join_context,
                       thief_splitting, tagged, find_tag, BoundDepth,
                       EvenLevels, ForceDepth, SizeLimit, Cap, JoinContext,
                       ThiefSplitting, Tagged)
from .plan import (Plan, PlanNode, MergeLevel, DigitPass, SortSchedule,
                   MULTI_TILE_LAUNCHES_PER_PASS, digit_passes, build_plan,
                   demand_split, geometric_blocks)
from .schedulers import (JoinScheduler, schedule_join, ByBlocks, by_blocks,
                         BlockStats, AdaptiveScheduler, adaptive)
from .dnc import wrap_iter, WrappedIter, work_loop
from .faults import (FaultPlan, WorkerDeath, Slowdown, CheckpointWriteFault,
                     CorruptionFault, PreemptionFault, HostDeath, SlotDeath)
from .runtime import CostModel, SimResult, Task, Runtime
from .policies import (SchedulingPolicy, JoinPolicy, DepJoinPolicy,
                       AdaptivePolicy, StaticPartitionPolicy, ByBlocksPolicy,
                       PriorityPolicy, DeadlinePolicy, simulate)
from .simruntime import WorkStealingSim, AdaptiveSim, static_partition_sim

__all__ = [
    "Divisible", "Producer", "WorkRange", "BatchWork", "SeqWork",
    "TileGrid2D", "ZipDivisible", "WorkSet", "PermRange",
    "total_permutations",
    "Adaptor", "StealContext", "bound_depth", "even_levels", "force_depth",
    "size_limit", "cap", "join_context", "thief_splitting", "tagged",
    "find_tag", "BoundDepth", "EvenLevels", "ForceDepth", "SizeLimit", "Cap",
    "JoinContext", "ThiefSplitting", "Tagged",
    "Plan", "PlanNode", "MergeLevel", "DigitPass", "SortSchedule",
    "digit_passes", "MULTI_TILE_LAUNCHES_PER_PASS", "build_plan",
    "demand_split", "geometric_blocks",
    "JoinScheduler", "schedule_join", "ByBlocks", "by_blocks", "BlockStats",
    "AdaptiveScheduler", "adaptive",
    "wrap_iter", "WrappedIter", "work_loop",
    "FaultPlan", "WorkerDeath", "Slowdown", "CheckpointWriteFault",
    "CorruptionFault", "PreemptionFault", "HostDeath", "SlotDeath",
    "CostModel", "SimResult", "Task", "Runtime",
    "SchedulingPolicy", "JoinPolicy", "DepJoinPolicy", "AdaptivePolicy",
    "StaticPartitionPolicy", "ByBlocksPolicy", "PriorityPolicy",
    "DeadlinePolicy", "simulate",
    "WorkStealingSim", "AdaptiveSim", "static_partition_sim",
]
