"""Adaptors controlling task splitting (paper §3.3).

Every adaptor *wraps* a :class:`~repro.core.divisible.Divisible` and overrides
the division decision while delegating everything else.  Adaptors nest, giving
the composability that is Kvik's central claim::

    work = thief_splitting(bound_depth(BatchWork(0, 256), 5), p=16)

The seven adaptors from the paper are reproduced with their exact semantics:

* :func:`bound_depth`       — stop dividing past a depth limit.
* :func:`even_levels`       — force all leaves onto an even depth (the merge
                              sort uses this so data lands in the right buffer).
* :func:`force_depth`       — the division tree is complete to at least depth d.
* :func:`size_limit`        — stop dividing below a size threshold (the classic
                              "sequential fallback" knob the paper's policies
                              make unnecessary — provided for comparison).
* :func:`cap`               — refuse division while ≥ threshold tasks are live
                              (dynamic: exact under the simruntime; at plan time
                              the live-leaf count is used).
* :func:`join_context`      — divide to a depth; left children always divide,
                              right children only when stolen.
* :func:`thief_splitting`   — the TBB/Rayon counter policy (paper §2.1): halve
                              a counter on division, stop at zero, reset when
                              stolen.

Dynamic policies (``cap``, ``join_context``, ``thief_splitting``, and the
adaptive schedule) consult a :class:`StealContext`.  Under the simulated
work-stealing runtime the context reports *real* (virtual-time) steal events;
under the static plan builder it reports "demand" — how much parallelism the
target mesh axis still wants — which is the trace-time analogue of a steal
request (division happens only when the hardware demands it).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

from .divisible import Divisible


# ---------------------------------------------------------------------------
# Steal context: runtime signals threaded through dynamic policies
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StealContext:
    """Signals a dynamic policy may consult when deciding to divide.

    ``stolen``      — True when this task has been migrated to another worker
                      since its creation (resets thief_splitting's counter).
    ``demand``      — outstanding parallelism demand (idle workers / unfilled
                      mesh slots).  The static plan builder sets this from the
                      mesh axis size; the simruntime sets it from actually idle
                      workers.
    ``live_tasks``  — currently live (created, unfinished) task count, for cap.
    ``worker``      — executing worker id (thief_splitting compares the task's
                      creator against it).
    """

    stolen: bool = False
    demand: int = 0
    live_tasks: int = 0
    worker: int = 0


NULL_CONTEXT = StealContext()


class Adaptor:
    """Base class: a Divisible wrapping a Divisible."""

    base: Divisible

    def size(self) -> int:
        return self.base.size()

    # Division decisions may consult the StealContext.  ``should_be_divided``
    # keeps Kvik's exact signature; context-aware callers use
    # ``should_divide(ctx)``.
    def should_divide(self, ctx: StealContext) -> bool:
        return self.should_be_divided()

    def should_be_divided(self) -> bool:
        return self.base.should_be_divided()

    def divide(self):
        raise NotImplementedError

    def divide_at(self, index: int):
        raise NotImplementedError

    # Producer pass-through (present iff the base has it)
    def partial_fold(self, state, fold_op, limit):
        return self.base.partial_fold(state, fold_op, limit)  # type: ignore

    def unwrap(self) -> Divisible:
        """Peel all adaptors off, returning the underlying work descriptor."""
        b = self.base
        while isinstance(b, Adaptor):
            b = b.base
        return b

    def on_steal(self) -> None:
        """Notify the policy that this task was stolen (simruntime hook)."""
        if isinstance(self.base, Adaptor):
            self.base.on_steal()

    def on_finish(self) -> None:
        """Notify the policy that this task completed (cap decrements)."""
        if isinstance(self.base, Adaptor):
            self.base.on_finish()


def _rewrap(adaptor: Adaptor, new_base: Divisible, **updates) -> Adaptor:
    child = dataclasses.replace(adaptor, base=new_base, **updates)
    return child


# ---------------------------------------------------------------------------
# bound_depth
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BoundDepth(Adaptor):
    """Stop dividing once ``depth`` divisions have happened above us."""

    base: Divisible
    limit: int
    depth: int = 0

    def should_be_divided(self) -> bool:
        return self.depth < self.limit and self.base.should_be_divided()

    def should_divide(self, ctx: StealContext) -> bool:
        if self.depth >= self.limit:
            return False
        if isinstance(self.base, Adaptor):
            return self.base.should_divide(ctx)
        return self.base.should_be_divided()

    def _split(self, parts):
        l, r = parts
        return (_rewrap(self, l, depth=self.depth + 1),
                _rewrap(self, r, depth=self.depth + 1))

    def divide(self):
        return self._split(self.base.divide())

    def divide_at(self, index):
        return self._split(self.base.divide_at(index))


def bound_depth(base: Divisible, limit: int) -> BoundDepth:
    return BoundDepth(base, limit)


# ---------------------------------------------------------------------------
# even_levels
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EvenLevels(Adaptor):
    """All leaves end on an even depth level (flip a boolean per division)."""

    base: Divisible
    even: bool = True

    def should_be_divided(self) -> bool:
        # If we are on an odd level we *must* divide once more to get back to
        # an even level, whatever the base says.
        return (not self.even) or self.base.should_be_divided()

    def should_divide(self, ctx: StealContext) -> bool:
        if not self.even:
            return True
        if isinstance(self.base, Adaptor):
            return self.base.should_divide(ctx)
        return self.base.should_be_divided()

    def _split(self, parts):
        l, r = parts
        return (_rewrap(self, l, even=not self.even),
                _rewrap(self, r, even=not self.even))

    def divide(self):
        return self._split(self.base.divide())

    def divide_at(self, index):
        return self._split(self.base.divide_at(index))


def even_levels(base: Divisible) -> EvenLevels:
    return EvenLevels(base)


# ---------------------------------------------------------------------------
# force_depth
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ForceDepth(Adaptor):
    """Complete division tree for at least ``limit`` levels."""

    base: Divisible
    limit: int
    depth: int = 0

    def should_be_divided(self) -> bool:
        return self.depth < self.limit or self.base.should_be_divided()

    def should_divide(self, ctx: StealContext) -> bool:
        if self.depth < self.limit:
            return True
        if isinstance(self.base, Adaptor):
            return self.base.should_divide(ctx)
        return self.base.should_be_divided()

    def _split(self, parts):
        l, r = parts
        return (_rewrap(self, l, depth=self.depth + 1),
                _rewrap(self, r, depth=self.depth + 1))

    def divide(self):
        return self._split(self.base.divide())

    def divide_at(self, index):
        return self._split(self.base.divide_at(index))


def force_depth(base: Divisible, limit: int) -> ForceDepth:
    return ForceDepth(base, limit)


# ---------------------------------------------------------------------------
# size_limit
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SizeLimit(Adaptor):
    """Stop dividing when the underlying producer is ≤ ``limit`` items."""

    base: Divisible
    limit: int

    def should_be_divided(self) -> bool:
        return self.base.size() > self.limit and self.base.should_be_divided()

    def should_divide(self, ctx: StealContext) -> bool:
        if self.base.size() <= self.limit:
            return False
        if isinstance(self.base, Adaptor):
            return self.base.should_divide(ctx)
        return self.base.should_be_divided()

    def _split(self, parts):
        l, r = parts
        return (_rewrap(self, l), _rewrap(self, r))

    def divide(self):
        return self._split(self.base.divide())

    def divide_at(self, index):
        return self._split(self.base.divide_at(index))


def size_limit(base: Divisible, limit: int) -> SizeLimit:
    return SizeLimit(base, limit)


# ---------------------------------------------------------------------------
# cap — live-task counter shared across the whole tree
# ---------------------------------------------------------------------------

class _SharedCounter:
    __slots__ = ("value",)

    def __init__(self, value: int = 1):
        self.value = value


@dataclasses.dataclass
class Cap(Adaptor):
    """Refuse division when the number of live tasks reaches ``threshold``.

    The counter is shared by every clone produced through division and is
    decremented by :meth:`on_finish` — matching the paper: "counts the active
    number of tasks and refuses division when the number reaches a threshold.
    This also decrements the counter as the tasks finish."

    Two optional hooks make the cap *live* (the serving engine's admission
    control drives both; defaults keep the paper semantics bit-identical):

    * ``threshold_fn`` — a zero-arg callable consulted on every division
      decision; the effective threshold is ``min(threshold, threshold_fn())``,
      so external telemetry (cache headroom, measured decode cost) can shrink
      the cap below its static ceiling without rebuilding the adaptor stack.
    * ``on_event`` — called as ``on_event(kind, live)`` with kind in
      {"divide", "finish"} and the post-event live-task count, every time the
      shared counter changes.  Clones share the hook, so one observer sees
      the whole tree.
    """

    base: Divisible
    threshold: int
    counter: _SharedCounter = dataclasses.field(default_factory=_SharedCounter)
    threshold_fn: Optional[Any] = None
    on_event: Optional[Any] = None

    def live_threshold(self) -> int:
        if self.threshold_fn is None:
            return self.threshold
        return min(self.threshold, max(1, int(self.threshold_fn())))

    def _notify(self, kind: str) -> None:
        if self.on_event is not None:
            self.on_event(kind, self.counter.value)

    def should_be_divided(self) -> bool:
        return (self.counter.value < self.live_threshold()
                and self.base.should_be_divided())

    def should_divide(self, ctx: StealContext) -> bool:
        if self.counter.value >= self.live_threshold():
            return False
        if isinstance(self.base, Adaptor):
            return self.base.should_divide(ctx)
        return self.base.should_be_divided()

    def _split(self, parts):
        self.counter.value += 1  # one task became two
        self._notify("divide")
        l, r = parts
        return (_rewrap(self, l, counter=self.counter),
                _rewrap(self, r, counter=self.counter))

    def divide(self):
        return self._split(self.base.divide())

    def divide_at(self, index):
        return self._split(self.base.divide_at(index))

    def on_finish(self) -> None:
        self.counter.value = max(0, self.counter.value - 1)
        self._notify("finish")
        super().on_finish()


def cap(base: Divisible, threshold: int) -> Cap:
    return Cap(base, threshold)


# ---------------------------------------------------------------------------
# tagged — SLO metadata riding the adaptor stack (priority / deadline / tenant)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Tagged(Adaptor):
    """Attach scheduling metadata to a work descriptor without changing any
    division decision: ``priority`` (higher = more urgent), an absolute
    virtual-time ``deadline``, and a ``tenant`` label for accounting.

    Both children of a division inherit the tag, so an adaptor stack like
    ``cap(tagged(WorkRange(0, n), priority=2), 3)`` keeps its SLO identity
    through arbitrary splitting.  :class:`~repro.core.policies.PriorityPolicy`
    and :class:`~repro.core.policies.DeadlinePolicy` order their shared pool
    by these fields; every other policy ignores them (the tag delegates all
    Divisible decisions to its base), so tagging work is always safe.
    """

    base: Divisible
    priority: int = 0
    deadline: Optional[float] = None
    tenant: str = "default"

    def should_divide(self, ctx: StealContext) -> bool:
        if isinstance(self.base, Adaptor):
            return self.base.should_divide(ctx)
        return self.base.should_be_divided()

    def _split(self, parts):
        l, r = parts
        return (_rewrap(self, l), _rewrap(self, r))

    def divide(self):
        return self._split(self.base.divide())

    def divide_at(self, index):
        return self._split(self.base.divide_at(index))


def tagged(base: Divisible, *, priority: int = 0,
           deadline: Optional[float] = None,
           tenant: str = "default") -> Tagged:
    return Tagged(base, priority=priority, deadline=deadline, tenant=tenant)


def find_tag(w: Divisible) -> Optional[Tagged]:
    """First :class:`Tagged` in an adaptor stack (None if the work carries
    no tag) — how the SLO policies read priority/deadline through any
    wrapping, e.g. ``cap(tagged(...), k)`` or ``tagged(size_limit(...))``."""
    while isinstance(w, Adaptor):
        if isinstance(w, Tagged):
            return w
        w = w.base
    return None


# ---------------------------------------------------------------------------
# join_context_policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JoinContext(Adaptor):
    """Divide to ``limit`` depth; left children always divide, right children
    only when stolen (paper §3.3 ``join_context_policy``)."""

    base: Divisible
    limit: int
    depth: int = 0
    is_right: bool = False
    stolen: bool = False

    def should_be_divided(self) -> bool:
        return self.should_divide(NULL_CONTEXT)

    def should_divide(self, ctx: StealContext) -> bool:
        if self.depth >= self.limit:
            return False
        if not self.base.should_be_divided():
            return False
        if self.is_right and not (self.stolen or ctx.stolen):
            return False
        return True

    def _split(self, parts):
        l, r = parts
        return (_rewrap(self, l, depth=self.depth + 1, is_right=False,
                        stolen=False),
                _rewrap(self, r, depth=self.depth + 1, is_right=True,
                        stolen=False))

    def divide(self):
        return self._split(self.base.divide())

    def divide_at(self, index):
        return self._split(self.base.divide_at(index))

    def on_steal(self) -> None:
        self.stolen = True
        super().on_steal()


def join_context(base: Divisible, limit: int) -> JoinContext:
    return JoinContext(base, limit)


# ---------------------------------------------------------------------------
# thief_splitting — the TBB / Rayon policy (paper §2.1, §3.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ThiefSplitting(Adaptor):
    """TBB/Rayon counter policy:

    1. start with a counter and the creator's worker id;
    2. on division the counter decreases by one, children copy the creator id;
    3. at zero, refuse division **unless** the executing worker differs from
       the creator (i.e. the task was stolen);
    4. on steal, reset the counter to its initial value.

    With ``counter = log2(p)+1`` and balanced work this creates O(p) tasks
    (validated by tests/test_simruntime.py against the simulated runtime).
    """

    base: Divisible
    init: int
    counter: Optional[int] = None
    creator: int = 0

    def __post_init__(self):
        if self.counter is None:
            self.counter = self.init

    def should_be_divided(self) -> bool:
        return self.counter > 0 and self.base.should_be_divided()

    def should_divide(self, ctx: StealContext) -> bool:
        if not self.base.should_be_divided():
            return False
        if self.counter > 0:
            return True
        # counter exhausted: divide anyway if we've been migrated
        return ctx.stolen or (ctx.worker != self.creator)

    def _split(self, parts, ctx: StealContext):
        new_counter = self.init if (ctx.stolen or ctx.worker != self.creator) \
            else self.counter - 1
        l, r = parts
        return (_rewrap(self, l, counter=new_counter, creator=ctx.worker),
                _rewrap(self, r, counter=new_counter, creator=ctx.worker))

    def divide(self):
        return self._split(self.base.divide(), NULL_CONTEXT)

    def divide_at(self, index):
        return self._split(self.base.divide_at(index), NULL_CONTEXT)

    def divide_ctx(self, ctx: StealContext):
        return self._split(self.base.divide(), ctx)

    def on_steal(self) -> None:
        self.counter = self.init
        super().on_steal()


def thief_splitting(base: Divisible, p: int, init: Optional[int] = None
                    ) -> ThiefSplitting:
    """Rayon's default counter is ``log2(p) + 1`` (forces ~2p tasks); Kvik lets
    the programmer pick — so do we."""
    if init is None:
        init = int(math.log2(max(2, p))) + 1
    return ThiefSplitting(base, init)


__all__ = [
    "Adaptor", "StealContext", "NULL_CONTEXT",
    "BoundDepth", "bound_depth", "EvenLevels", "even_levels",
    "ForceDepth", "force_depth", "SizeLimit", "size_limit",
    "Cap", "cap", "JoinContext", "join_context",
    "ThiefSplitting", "thief_splitting",
    "Tagged", "tagged", "find_tag",
]
