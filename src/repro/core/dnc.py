"""Divide-and-conquer abstractions: ``wrap_iter`` and ``work`` (paper §3.4, §3.6.1).

``wrap_iter`` turns any :class:`Divisible` into a plan-time "parallel iterator
over sub-pieces": the middleware owns every splitting decision, the user maps
a sequential function over the leaves and fuses results back in a symmetric
reduction tree — the paper's maximum-subarray-sum shape.

``work_loop`` is the stateful nano-loop (paper §3.6.1 ``work()``): given a
carried state and an ``advance(state, n)`` step, it executes geometrically
growing iteration grants inside a single ``lax.while_loop`` so the compiled
program regains control between grants (the TPU analogue of "check for steal
requests / cancellation between nano-loops").  This is the primitive under
early-exit decode and the fannkuch benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .adaptors import Adaptor, StealContext
from .divisible import Divisible
from .plan import Plan, build_plan


@dataclasses.dataclass
class WrappedIter:
    """Plan-time parallel iterator over the leaves of a division tree."""

    work: Divisible
    ctx: Optional[StealContext] = None

    def plan(self) -> Plan:
        return build_plan(self.work, ctx=self.ctx)

    def map_reduce(self, map_fn: Callable[[Divisible], Any],
                   reduce_fn: Callable[[Any, Any], Any]) -> Any:
        """The paper's ``wrap_iter().map(...).reduce(...)`` in one call."""
        return self.plan().map_reduce(map_fn, reduce_fn)

    def leaves(self):
        return self.plan().leaves()


def wrap_iter(work: Divisible, *, ctx: Optional[StealContext] = None
              ) -> WrappedIter:
    return WrappedIter(work, ctx)


def work_loop(state: Any,
              advance: Callable[[Any, jnp.ndarray], Any],
              total: int,
              *,
              should_stop: Optional[Callable[[Any], jnp.ndarray]] = None,
              first_grant: int = 1,
              growth: int = 2,
              max_grant: Optional[int] = None) -> Any:
    """Stateful geometric nano-loop inside one compiled program.

    ``advance(state, n)`` performs ``n`` iterations on ``state`` (n is a traced
    int32 scalar — implement with ``lax.fori_loop``).  ``should_stop(state)``
    is evaluated between grants; a True aborts the remaining grants.  The grant
    sequence is ``first_grant * growth**k`` capped at ``max_grant`` — at most
    O(log total) interruption checks, the paper's amortization argument.
    """
    max_grant = max_grant or total

    def cond(carry):
        state, done, grant, stop = carry
        return jnp.logical_and(done < total, jnp.logical_not(stop))

    def body(carry):
        state, done, grant, stop = carry
        n = jnp.minimum(grant, total - done)
        state = advance(state, n)
        done = done + n
        stop2 = should_stop(state) if should_stop is not None else jnp.asarray(False)
        grant = jnp.minimum(grant * growth, max_grant)
        return (state, done, grant, stop2)

    init = (state, jnp.asarray(0, jnp.int32),
            jnp.asarray(first_grant, jnp.int32), jnp.asarray(False))
    state, done, _, stopped = jax.lax.while_loop(cond, body, init)
    return state


__all__ = ["wrap_iter", "WrappedIter", "work_loop"]
