"""The unified discrete-event scheduling runtime (engine half of Kvik's split).

Kvik's contribution is *composable scheduling policies*; composability only
exists if there is exactly one execution engine for policies to compose over.
This module is that engine.  It owns everything that is *mechanism*:

* p virtual workers with per-worker clocks, speed factors and busy accounting
  (heterogeneous pods, straggler studies);
* per-worker deques, a steal-request queue, and seeded victim selection
  (a single ``random.Random`` stream per run — fixed seed ⇒ bit-identical
  :class:`SimResult`);
* the join-tree bookkeeping (:class:`_JoinNode`) shared by join and depjoin;
* leaf execution, nano-loop grants (``partial_fold``), interruption flags and
  wasted-work accounting;
* the :class:`CostModel` charging rules (split / reduce / check / steal).

Everything that is *decision* lives in a :class:`~repro.core.policies.
SchedulingPolicy` object (see ``policies.py``): when to divide, what an idle
worker does, how a steal request is served, who runs a reduction.  The paper's
four schedulers — join (§3.2), depjoin (§3.2), by_blocks (§3.5), adaptive
(§2.2/§3.6) — plus the OpenMP-static baseline (§4.3) are each ~50-line
policies over this one engine, so they can be mixed (a ``by_blocks`` outer
loop over adaptive inner blocks, an adaptor-wrapped adaptive task), which the
four disjoint pre-refactor engines could not do.

Why a simulator at all: the paper's dynamic claims (task counts under
thief_splitting, "tasks = successful steals + 1", depjoin's no-wait
reductions, fannkuch's split-cost sensitivity) are about a work-stealing
execution engine.  A statically-compiled TPU program has no such engine, and
this 1-core container could not exhibit real parallelism anyway.  So we
validate those claims bit-exactly in virtual time, then carry the *validated
policies* into the static/replan world of the rest of the framework.

The legacy entry points ``WorkStealingSim`` / ``AdaptiveSim`` /
``static_partition_sim`` survive as thin deprecation shims in
:mod:`repro.core.simruntime`; their results are bit-identical to the
pre-refactor engines under fixed seeds (pinned by tests/test_runtime.py).
"""

from __future__ import annotations

import dataclasses
import random
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from .adaptors import Adaptor, StealContext
from .divisible import Divisible
from .faults import FaultPlan


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CostModel:
    """Virtual-time costs.

    ``per_item``      — sequential cost per work item.
    ``split_overhead``— fixed cost of one division (task creation).
    ``split_cost_fn`` — extra, work-dependent division cost (e.g. fannkuch's
                        first-permutation generation, merge sort's binary
                        search); receives the divided work.
    ``reduce_cost``   — cost of one reduction.
    ``check_overhead``— cost of one steal-request check (the reason nano-loops
                        exist at all).
    ``steal_latency`` — time for a steal attempt (success or failure).
    """

    per_item: float = 1.0
    split_overhead: float = 1.0
    split_cost_fn: Optional[Callable[[Divisible], float]] = None
    reduce_cost: float = 0.0
    check_overhead: float = 0.05
    steal_latency: float = 0.5

    def split_cost(self, work: Divisible) -> float:
        extra = 0.0
        if self.split_cost_fn is not None:
            extra = self.split_cost_fn(work)
        else:
            u = work.unwrap() if isinstance(work, Adaptor) else work
            extra = float(getattr(u, "split_cost", 0.0))
        return self.split_overhead + extra


@dataclasses.dataclass
class SimResult:
    makespan: float
    tasks_created: int           # leaves actually executed as separate tasks
    divisions: int
    steals_attempted: int
    steals_successful: int
    reductions: int
    items_processed: int
    items_total: int
    per_worker_busy: List[float]
    stopped_early: bool = False
    wasted_items: int = 0        # items beyond the stop index (0 if not stopped)
    deaths: int = 0              # workers killed by the fault plan
    lost_items: int = 0          # items whose fold state died with a worker
    recoveries: int = 0          # orphaned tasks adopted by survivors
    expired_items: int = 0       # items dropped past their deadline (EDF)

    @property
    def lost_work_fraction(self) -> float:
        return self.lost_items / self.items_total if self.items_total else 0.0

    @property
    def speedup_vs_serial(self) -> float:
        serial = self.items_total  # with per_item=1
        return serial / self.makespan if self.makespan > 0 else 0.0

    @property
    def load_balance(self) -> float:
        b = self.per_worker_busy
        return (min(b) / max(b)) if max(b) > 0 else 1.0


# ---------------------------------------------------------------------------
# Tasks and join-tree nodes (shared mechanism)
# ---------------------------------------------------------------------------

class _JoinNode:
    __slots__ = ("pending", "owner", "parent", "reduce_ready")

    def __init__(self, owner: int, parent: Optional["_JoinNode"]):
        self.pending = 2
        self.owner = owner
        self.parent = parent
        self.reduce_ready = False


@dataclasses.dataclass
class Task:
    """A schedulable unit: a work descriptor plus runtime bookkeeping.

    ``nano`` is only meaningful under nano-loop policies (adaptive): the
    current micro-loop grant size.
    """

    work: Divisible
    parent: Optional[_JoinNode] = None
    creator: int = 0
    stolen: bool = False
    nano: int = 1
    orphan_t: float = 0.0        # region time its previous owner died


def _unwrap(w: Divisible) -> Divisible:
    return w.unwrap() if isinstance(w, Adaptor) else w


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class Runtime:
    """Discrete-event virtual-time engine, parameterized by a policy.

    One instance = one (p, cost, policy, seed, speeds, stop_predicate)
    configuration; ``run(work)`` executes the policy over the work and returns
    a :class:`SimResult`.  Runs are independent: all mutable state is reset at
    the top of :meth:`run`, so the same Runtime re-run with the same work is
    deterministic.
    """

    def __init__(self, p: int, cost: CostModel, policy: "Any", *,
                 seed: int = 0, speeds: Optional[List[float]] = None,
                 stop_predicate: Optional[Callable[[Any], Optional[int]]] = None,
                 faults: Optional[FaultPlan] = None):
        self.p = p
        self.cost = cost
        self.policy = policy
        self.seed = seed
        self.speeds = speeds or [1.0] * p
        assert len(self.speeds) == p
        self.stop_predicate = stop_predicate
        # only runtime-facing events matter here; a plan with none is inert
        self.faults = faults if (faults is not None
                                 and faults.has_runtime_events()) else None
        self._base_speeds = list(self.speeds)

    # -- top level -----------------------------------------------------------

    def run(self, work: Divisible) -> SimResult:
        self.rng = random.Random(self.seed)
        self.busy = [0.0] * self.p
        self.stats: Dict[str, int] = dict(
            tasks=0, divisions=0, steal_try=0, steal_ok=0, reductions=0,
            items=0, deaths=0, lost=0, recoveries=0, expired=0)
        self.stop_flag = False
        self.stop_hit: Any = None
        self.items_total = work.size()
        # fault state spans regions: dead stays dead across by_blocks blocks,
        # and event times are absolute (abs_offset accumulates region spans)
        self.dead = [False] * self.p
        self.orphans: deque = deque()
        self.abs_offset = 0.0
        if self.faults is not None:      # slowdowns mutate speeds in place
            self.speeds = list(self._base_speeds)
        # processed index ranges, for exact wasted-work accounting on
        # integer-indexed work (WorkRange family)
        self._segments: List[Tuple[int, int]] = []
        makespan = self.policy.drive(self, work)
        return self._build_result(makespan)

    def run_region(self, work: Divisible, policy: "Any") -> float:
        """Run one parallel region (all workers synchronize at entry and
        exit) under ``policy``; returns the region's makespan.  Policies that
        sequence regions (by_blocks) call this once per block; everything
        else is a single region."""
        p = self.p
        self.time = [0.0] * p
        self.deques: List[deque] = [deque() for _ in range(p)]
        self.pending_reductions: List[List[_JoinNode]] = [[] for _ in range(p)]
        self.current: List[Optional[Task]] = [None] * p
        self.waiting: Dict[int, float] = {}   # thief id -> request time
        self.outstanding = 0
        self.idle_spin = 0
        self.region_done = False
        policy.on_region_start(self, work)
        while not self.region_done:
            if self.faults is not None:
                self.fault_service()
            wid = policy.select_worker(self)
            if wid is None:
                if self.faults is not None and self.orphans:
                    continue      # next fault_service adopts the orphans
                break
            policy.quantum(self, wid)
        span = policy.on_region_end(self)
        if self.faults is not None:
            self.abs_offset += span
        return span

    def _build_result(self, makespan: float) -> SimResult:
        # wasted work = processed items strictly beyond the stop index (the
        # items a perfectly-informed sequential scan would never touch)
        wasted = 0
        if (self.stop_flag and isinstance(self.stop_hit, int)
                and not isinstance(self.stop_hit, bool)):
            cut = self.stop_hit + 1
            wasted = sum(max(0, hi - max(lo, cut))
                         for (lo, hi) in self._segments)
        return SimResult(
            makespan=makespan, tasks_created=self.stats["tasks"],
            divisions=self.stats["divisions"],
            steals_attempted=self.stats["steal_try"],
            steals_successful=self.stats["steal_ok"],
            reductions=self.stats["reductions"],
            items_processed=self.stats["items"],
            items_total=self.items_total,
            per_worker_busy=self.busy, stopped_early=self.stop_flag,
            wasted_items=wasted, deaths=self.stats["deaths"],
            lost_items=self.stats["lost"],
            recoveries=self.stats["recoveries"],
            expired_items=self.stats["expired"])

    # -- time & cost charging ------------------------------------------------

    def charge(self, wid: int, cost: float) -> None:
        t = cost / self.speeds[wid]
        self.time[wid] += t
        self.busy[wid] += t

    def idle_count(self) -> int:
        if self.faults is not None:
            return sum(1 for i, c in enumerate(self.current)
                       if c is None and not self.dead[i])
        return sum(1 for c in self.current if c is None)

    # -- fault injection (all paths gated on a live FaultPlan) ---------------

    def alive(self, wid: int) -> bool:
        return self.faults is None or not self.dead[wid]

    def worker_died(self, wid: int) -> bool:
        """Policy-facing: did the current quantum end in this worker's
        death (mid-grant truncation)?"""
        return self.faults is not None and self.dead[wid]

    def has_demand(self, wid: int) -> bool:
        """Is any *other* alive worker idle right now?  The mid-region
        preemption hook consults this to keep steal-service boundaries
        frequent while demand exists."""
        return any(self.current[i] is None and self.alive(i)
                   for i in range(self.p) if i != wid)

    def seed_worker(self) -> int:
        """Worker that seeds a region's initial task (0 unless dead)."""
        if self.faults is None:
            return 0
        for i in range(self.p):
            if not self.dead[i]:
                return i
        raise RuntimeError("fault plan killed every worker")

    def _abs_time(self, wid: int) -> float:
        return self.abs_offset + self.time[wid]

    def fault_service(self) -> None:
        """One discrete-event service pass: fire due deaths and slowdowns,
        then let idle survivors adopt orphaned tasks (the recovery steal)."""
        f = self.faults
        for i in range(self.p):
            if self.dead[i]:
                continue
            self.speeds[i] = self._base_speeds[i] * f.speed_factor(
                i, self._abs_time(i))
            td = f.death_time(i)
            if td is not None and self._abs_time(i) >= td:
                self.kill_worker(i)
        if not self.orphans:
            return
        survivors = [i for i in range(self.p) if not self.dead[i]]
        if not survivors:
            raise RuntimeError(
                "fault plan killed every worker with work outstanding")
        for i in survivors:
            if not self.orphans:
                break
            if self.current[i] is not None:
                continue
            task = self.orphans.popleft()
            task.stolen = True
            task.nano = 1            # fresh micro-loop: re-splittable at once
            lat = self.cost.steal_latency / self.speeds[i]
            self.time[i] = max(self.time[i], task.orphan_t) + lat
            if isinstance(task.work, Adaptor):
                task.work.on_steal()
            self.current[i] = task
            self.waiting.pop(i, None)
            self.stats["recoveries"] += 1

    def kill_worker(self, wid: int) -> None:
        """Process a worker death: its in-flight task and queued tasks
        re-enter the steal pool; deferred reductions move to a survivor."""
        self.dead[wid] = True
        self.stats["deaths"] += 1
        t = self.time[wid]
        task = self.current[wid]
        self.current[wid] = None
        if task is not None:
            task.orphan_t = t
            self.orphans.append(task)
        while self.deques[wid]:
            q = self.deques[wid].popleft()
            q.orphan_t = t
            self.orphans.append(q)
        if self.pending_reductions[wid]:
            succ = self._successor(wid)
            if succ is not None:
                self.pending_reductions[succ].extend(
                    self.pending_reductions[wid])
            self.pending_reductions[wid] = []
        self.waiting.pop(wid, None)

    def _successor(self, wid: int) -> Optional[int]:
        for i in range(self.p):
            if i != wid and not self.dead[i]:
                return i
        return None

    def _death_cut(self, wid: int, dur: float) -> Optional[float]:
        """If a charge of worker-time ``dur`` starting now spans this
        worker's death, return the surviving fraction in [0, 1)."""
        if self.faults is None or self.dead[wid]:
            return None
        td = self.faults.death_time(wid)
        if td is None:
            return None
        t0 = self._abs_time(wid)
        if dur <= 0 or td >= t0 + dur:
            return None
        return max(0.0, (td - t0) / dur)

    # -- division ------------------------------------------------------------

    def wants_division(self, w: Divisible, ctx: StealContext) -> bool:
        if isinstance(w, Adaptor):
            return w.should_divide(ctx)
        return w.should_be_divided()

    def divide(self, w: Divisible, ctx: StealContext
               ) -> Tuple[Divisible, Divisible]:
        l, r = (w.divide_ctx(ctx) if hasattr(w, "divide_ctx")
                else w.divide())
        self.stats["divisions"] += 1
        return l, r

    def new_join_node(self, owner: int, parent: Optional[_JoinNode]
                      ) -> _JoinNode:
        return _JoinNode(owner=owner, parent=parent)

    def push_task(self, wid: int, task: Task) -> None:
        self.deques[wid].append(task)
        self.outstanding += 1

    # -- leaf / grant execution ---------------------------------------------

    def run_leaf(self, wid: int, task: Task) -> None:
        """Run a whole leaf sequentially (join-family semantics): tasks only
        check the interruption flag *before* starting — classical schedulers
        can only cancel non-started tasks (paper §4.1)."""
        w = task.work
        n_items = w.size()
        if self.stop_flag:
            n_items = 0  # cancelled before start
        if self.faults is not None:
            dur = n_items * self.cost.per_item / self.speeds[wid]
            frac = self._death_cut(wid, dur)
            if frac is not None:
                # the leaf is truncated at the death point: items executed
                # before the cut are lost (their fold state died with the
                # worker) and the WHOLE leaf re-enters the steal pool — the
                # producer was never advanced, so re-execution is exact
                done = int(n_items * frac)
                self.time[wid] += frac * dur
                self.busy[wid] += frac * dur
                self.stats["lost"] += done
                self.current[wid] = task  # the object kill_worker orphans
                self.kill_worker(wid)
                return
        self.stats["tasks"] += 1
        self.charge(wid, n_items * self.cost.per_item)
        self.stats["items"] += n_items
        self._record_segment(w, n_items)
        if self.stop_predicate is not None and n_items > 0:
            hit = self.stop_predicate(_unwrap(w))
            if hit is not None:
                self.raise_stop(hit)
        if isinstance(w, Adaptor):
            w.on_finish()
        self.current[wid] = None
        self.outstanding -= 1
        self.finish_join(task.parent, wid)

    def run_grant(self, wid: int, w: Divisible, grant: int) -> Any:
        """Run ``grant`` items of a producer via ``partial_fold`` (nano-loop
        semantics): the interruption predicate sees every item, and one
        check_overhead is charged for the micro-loop boundary.  Returns the
        predicate's hit value (or None)."""
        run_t = ((grant * self.cost.per_item + self.cost.check_overhead)
                 / self.speeds[wid])
        if self.faults is not None:
            frac = self._death_cut(wid, run_t)
            if frac is not None:
                # grant truncated at the death point: the partial fold is
                # lost, the producer does NOT advance, and the worker's
                # current task (holding the full remaining extent) is
                # orphaned into the steal pool by kill_worker
                done = min(grant, int(grant * frac))
                self.time[wid] += frac * run_t
                self.busy[wid] += frac * run_t
                self.stats["lost"] += done
                self.kill_worker(wid)
                return None
        hit = [None]
        pred = self.stop_predicate

        def fold(st, item):
            if pred is not None:
                r = pred(item)
                if r is not None:
                    hit[0] = r
            return st

        self._record_segment(w, grant)   # before partial_fold advances it
        w.partial_fold(None, fold, grant)
        self.time[wid] += run_t
        self.busy[wid] += run_t
        self.stats["items"] += grant
        return hit[0]

    def _record_segment(self, w: Divisible, n: int) -> None:
        if n <= 0 or self.stop_predicate is None:
            return
        start = getattr(_unwrap(w), "start", None)
        if isinstance(start, int):
            self._segments.append((start, start + n))

    def retire(self, wid: int) -> None:
        """Drop a worker's current task (adaptive: exhausted / cancelled)."""
        task = self.current[wid]
        self.current[wid] = None
        if task is not None and isinstance(task.work, Adaptor):
            task.work.on_finish()

    def raise_stop(self, hit: Any) -> None:
        if not self.stop_flag:
            self.stop_flag = True
            self.stop_hit = hit

    # -- join-tree bookkeeping ----------------------------------------------

    def finish_join(self, node: Optional[_JoinNode], wid: int) -> None:
        """Walk up the join tree after a child completes.  When both children
        of a node are done the policy's ``on_join_complete`` decides who runs
        the reduction: True = the finishing worker runs it now and we ascend
        (depjoin, paper §3.2); False = it is deferred to the dividing owner's
        reduction queue (plain join)."""
        while node is not None:
            node.pending -= 1
            if node.pending > 0:
                return
            if self.policy.on_join_complete(self, node, wid):
                self.charge(wid, self.cost.reduce_cost)
                self.stats["reductions"] += 1
                node = node.parent
            else:
                node.reduce_ready = True
                owner = node.owner
                if self.faults is not None and self.dead[owner]:
                    owner = self._successor(owner)
                    if owner is None:
                        owner = wid   # last survivor reduces its own tree
                self.pending_reductions[owner].append(node)
                return

    def run_deferred_reduction(self, wid: int) -> None:
        node = self.pending_reductions[wid].pop()
        self.charge(wid, self.cost.reduce_cost)
        self.stats["reductions"] += 1
        self.finish_join(node.parent, wid)

    # -- stealing (join family: thief-initiated deque steal) -----------------

    def steal_from_random_victim(self, wid: int) -> bool:
        """Attempt one steal from the top of a random non-empty deque.
        Returns True if an attempt was made (charging steal_latency)."""
        victims = [i for i in range(self.p) if i != wid and self.deques[i]]
        if not victims:
            return False
        self.stats["steal_try"] += 1
        v = self.rng.choice(victims)
        self.time[wid] += self.cost.steal_latency / self.speeds[wid]
        if self.deques[v]:
            stolen = self.deques[v].popleft()
            stolen.stolen = True
            if isinstance(stolen.work, Adaptor):
                stolen.work.on_steal()
            self.stats["steal_ok"] += 1
            self.current[wid] = stolen
        return True

    # -- stealing (adaptive family: victim-served request queue) -------------

    def post_steal_requests(self) -> None:
        """Register every idle worker in the single request queue (lazily:
        any idle worker has, by construction, nothing else to do).  Each idle
        spell counts as one steal attempt."""
        for thief in range(self.p):
            if self.current[thief] is None and self.alive(thief):
                if thief not in self.waiting:
                    self.waiting[thief] = self.time[thief]
                    self.stats["steal_try"] += 1

    def next_steal_request(self) -> Optional[int]:
        """Pick one pending request (seeded-random among requesters)."""
        idle = [i for i in self.waiting if self.current[i] is None]
        return self.rng.choice(idle) if idle else None

    def grant_steal(self, wid: int, thief: int, task: Task, nano0: int
                    ) -> None:
        """Serve a steal request: divide the victim's remaining work in half,
        hand the right part to the thief, reset both nano sizes."""
        w = task.work
        ctx = StealContext(stolen=True, worker=thief,
                           demand=self.idle_count())
        l, r = self.divide(w, ctx)
        self.stats["steal_ok"] += 1
        self.stats["tasks"] += 1
        del self.waiting[thief]
        lat = self.cost.steal_latency / self.speeds[thief]
        self.time[thief] = max(self.time[thief], self.time[wid]) + lat
        if isinstance(r, Adaptor):
            r.on_steal()
        self.current[thief] = Task(work=r, creator=thief, stolen=True,
                                   nano=nano0)
        task.work = l
        task.nano = nano0

    # -- idle / termination (join family) ------------------------------------

    def idle_or_finish(self, wid: int) -> None:
        """Nothing to run, pop, or steal: either the region is over, or this
        worker's clock jumps to the next busy worker's time."""
        if self.faults is not None and self.orphans:
            return   # the next fault_service pass adopts into this worker
        p = self.p
        if self.outstanding <= 0 and not any(
                self.pending_reductions[i] for i in range(p)):
            self.region_done = True
            return
        others = [self.time[i] for i in range(p) if i != wid and
                  (self.current[i] is not None or self.deques[i]
                   or self.pending_reductions[i])]
        if not others:
            self.idle_spin += 1
            if self.idle_spin > 10 * p:
                self.region_done = True
                return
            self.time[wid] += self.cost.steal_latency
            return
        self.idle_spin = 0
        self.time[wid] = max(self.time[wid], min(others)) + 1e-9


__all__ = ["CostModel", "SimResult", "Task", "Runtime"]
