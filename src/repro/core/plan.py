"""Plans: the static artifact a scheduling policy produces.

In Kvik the division tree exists only transiently inside the work-stealing
execution.  On a statically-compiled target the tree *is* the deliverable: we
run the policy at plan time, record the division tree, and use it to
parameterize compiled programs (microbatch counts, chunk grids, reduction
trees).  ``Plan`` is that recorded tree.

``build_plan`` is the static analogue of the join scheduler's divide phase:
divide while the (adaptor-wrapped) divisible agrees, depth-first, exactly as
``rayon::join`` would have (left eagerly, right deferred).

``demand_split`` is the static analogue of the *adaptive* scheduler: split
only while parallelism demand remains, yielding ``demand`` leaves with the
minimum number of divisions (= demand − 1, mirroring the paper's
"tasks created = successful steals + 1").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, List, Optional, Sequence, Tuple

from .adaptors import Adaptor, StealContext
from .divisible import Divisible


@dataclasses.dataclass
class PlanNode:
    """A node of the division tree.  Leaves carry the work descriptor."""

    work: Optional[Divisible]  # set on leaves
    left: Optional["PlanNode"] = None
    right: Optional["PlanNode"] = None
    depth: int = 0

    @property
    def is_leaf(self) -> bool:
        return self.left is None and self.right is None

    def leaves(self) -> Iterator["PlanNode"]:
        if self.is_leaf:
            yield self
        else:
            yield from self.left.leaves()
            yield from self.right.leaves()

    def span(self) -> Tuple[int, int]:
        """[start, stop) covered by this subtree, from the leaves' work
        descriptors (requires range-like work: ``start``/``stop``)."""
        if self.is_leaf:
            w = _underlying(self.work)
            return (w.start, w.stop)
        ls, _ = self.left.span()
        _, rs = self.right.span()
        return (ls, rs)


def _underlying(work: Divisible) -> Divisible:
    return work.unwrap() if isinstance(work, Adaptor) else work


@dataclasses.dataclass(frozen=True)
class DigitPass:
    """One LSD radix digit pass of a tile-sort phase: rank (and stably
    permute) by the ``bits``-wide digit at ``shift``.  Pure metadata — the
    kernel layer turns a tuple of these into one in-kernel ``fori_loop``."""

    shift: int
    bits: int

    @property
    def radix(self) -> int:
        return 1 << self.bits


#: launches one multi-tile digit pass costs: local rank/sort, the
#: cross-tile carry scan of the histogram matrix, and the global scatter.
MULTI_TILE_LAUNCHES_PER_PASS = 3


@dataclasses.dataclass(frozen=True)
class SortSchedule:
    """A complete sort schedule: the tile-sort phase as LSD digit passes
    plus either the level-synchronous merge schedule (``mode="merge"``) or
    the multi-tile pass structure (``mode="multi_tile"``).

    ``key_shift`` is the bit position of the sort key inside the packed
    word (bits below it are tie-order-free: for the fused pack path they
    hold the in-tile position — and for the multi-tile path the global
    index — which LSD stability preserves without ranking; that is why
    ``tile_passes`` covers only ``sort_bits`` key bits rather than the
    full packed width).

    In ``multi_tile`` mode there are no merge levels: every digit pass is
    *global* (per-tile histogram + stable local rank, an exclusive scan
    across the ``(num_tiles × radix)`` histogram matrix, a scatter to
    global rank), so the launch count is
    ``MULTI_TILE_LAUNCHES_PER_PASS · num_passes`` — independent of ``n``,
    versus the merge tree's ``1 + log2(n/tile)``."""

    tile_passes: Tuple[DigitPass, ...]
    levels: Tuple["MergeLevel", ...]
    key_shift: int = 0
    mode: str = "merge"          # "merge" | "multi_tile"
    num_tiles: int = 1

    def __post_init__(self):
        if self.mode not in ("merge", "multi_tile"):
            raise ValueError(f"unknown sort schedule mode {self.mode!r}")
        if self.mode == "multi_tile" and self.levels:
            raise ValueError("multi_tile schedules have no merge levels — "
                             "every digit pass is already global")

    @property
    def num_passes(self) -> int:
        return len(self.tile_passes)

    @property
    def num_launches(self) -> int:
        """Kernel launches when executed fused.  ``merge``: one tile-sort
        launch (all digit passes run in-kernel) plus one per merge level.
        ``multi_tile``: rank + carry-scan + scatter per digit pass, with a
        single-tile input degenerating to the one-launch fused tile sort."""
        if self.mode == "multi_tile":
            if self.num_tiles <= 1:
                return 1
            return MULTI_TILE_LAUNCHES_PER_PASS * self.num_passes
        return 1 + len(self.levels)


def digit_passes(sort_bits: int, digit_bits: int, *,
                 key_shift: int = 0) -> Tuple[DigitPass, ...]:
    """The LSD pass list covering ``sort_bits`` key bits in ``digit_bits``
    chunks: ``ceil(sort_bits / digit_bits)`` passes, the last one narrower
    when ``digit_bits`` does not divide ``sort_bits``."""
    if sort_bits <= 0:
        return ()
    if digit_bits <= 0:
        raise ValueError(f"digit_bits must be positive, got {digit_bits}")
    out = []
    for lo in range(0, sort_bits, digit_bits):
        out.append(DigitPass(shift=key_shift + lo,
                             bits=min(digit_bits, sort_bits - lo)))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class MergeLevel:
    """One level of a level-synchronous reduction schedule.

    ``pairs`` lists, for every merge happening at this level, the half-open
    spans of its left and right operands: ``((a_start, a_stop),
    (b_start, b_stop))``.  A *uniform* level (equal-length, adjacent,
    contiguous pairs — what a balanced power-of-two sort plan produces) can
    drive a single fixed-block kernel launch with ``grid=(num_pairs, ...)``.
    """

    pairs: Tuple[Tuple[Tuple[int, int], Tuple[int, int]], ...]

    @property
    def num_pairs(self) -> int:
        return len(self.pairs)

    @property
    def uniform(self) -> bool:
        """True iff every pair merges two adjacent equal-length runs and the
        pairs tile a contiguous region in order."""
        if not self.pairs:
            return False
        run = self.pairs[0][0][1] - self.pairs[0][0][0]
        pos = self.pairs[0][0][0]
        for (a0, a1), (b0, b1) in self.pairs:
            if a1 - a0 != run or b1 - b0 != run or a1 != b0 or a0 != pos:
                return False
            pos = b1
        return True

    @property
    def run_length(self) -> int:
        """Uniform operand length (left == right) — only valid if uniform."""
        return self.pairs[0][0][1] - self.pairs[0][0][0]


@dataclasses.dataclass
class Plan:
    """A completed division tree plus bookkeeping counters."""

    root: PlanNode
    divisions: int = 0

    # -- structure queries ---------------------------------------------------
    def leaves(self) -> List[Divisible]:
        return [_underlying(n.work) for n in self.root.leaves()]

    def leaf_nodes(self) -> List[PlanNode]:
        return list(self.root.leaves())

    def num_tasks(self) -> int:
        return len(self.leaf_nodes())

    def depth(self) -> int:
        return max((n.depth for n in self.root.leaves()), default=0)

    def leaf_sizes(self) -> List[int]:
        return [w.size() for w in self.leaves()]

    def is_balanced(self) -> bool:
        sizes = self.leaf_sizes()
        return len(set(sizes)) <= 1

    def levels(self) -> List[List[PlanNode]]:
        """Nodes grouped by depth, root (depth 0) first, left-to-right within
        a level — the level-order view of the division tree."""
        out: List[List[PlanNode]] = []

        def go(node: PlanNode, d: int) -> None:
            if d == len(out):
                out.append([])
            out[d].append(node)
            if not node.is_leaf:
                go(node.left, d + 1)
                go(node.right, d + 1)

        go(self.root, 0)
        return out

    def merge_schedule(self) -> List[MergeLevel]:
        """Bottom-up level-synchronous reduction schedule.

        Level ``i`` merges the children of every internal node at the
        ``i``-th deepest internal depth; running the levels in order performs
        the same tree reduction as :meth:`map_reduce`, but batched so one
        kernel launch can cover a whole level.  A plan built over
        ``even_levels(...)`` work yields an even number of levels (every leaf
        sits at even depth), which is how the paper's merge sort keeps
        results landing in the right buffer.
        """
        out: List[MergeLevel] = []
        for nodes in reversed(self.levels()):
            internal = [n for n in nodes if not n.is_leaf]
            if internal:
                out.append(MergeLevel(pairs=tuple(
                    (n.left.span(), n.right.span()) for n in internal)))
        return out

    def sort_schedule(self, *, sort_bits: int, digit_bits: int = 4,
                      key_shift: int = 0,
                      mode: str = "merge") -> SortSchedule:
        """:meth:`merge_schedule` extended with the tile-sort phase's radix
        digit-pass metadata (the plan's leaves are the tiles; each digit
        pass ranks by ``digit_bits`` key bits starting at ``key_shift``).
        ``sort_bits`` is the key width that actually needs ranking — for
        the fused pack path that is ``num_key_bits`` alone, because the
        packed in-tile position bits below ``key_shift`` ride along
        tie-order-free under a stable LSD pass.

        ``mode="multi_tile"`` describes the merge-tree-free execution: the
        same digit passes, but each one global (histogram / carry scan /
        scatter) over the plan's ``num_tasks()`` tiles, no merge levels."""
        if mode == "multi_tile":
            return SortSchedule(
                tile_passes=digit_passes(sort_bits, digit_bits,
                                         key_shift=key_shift),
                levels=(), key_shift=key_shift, mode="multi_tile",
                num_tiles=self.num_tasks())
        return SortSchedule(
            tile_passes=digit_passes(sort_bits, digit_bits,
                                     key_shift=key_shift),
            levels=tuple(self.merge_schedule()),
            key_shift=key_shift)

    # -- execution helpers ---------------------------------------------------
    def map_reduce(self, map_fn: Callable[[Divisible], Any],
                   reduce_fn: Callable[[Any, Any], Any]) -> Any:
        """Execute the plan's symmetric map/tree-reduce (paper §2.3.2: "results
        are reduced two-by-two forming a reduction tree symmetrical to the
        division tree").  Runs at trace time: with JAX values this emits a
        tree-shaped reduction into the jaxpr."""
        def go(node: PlanNode) -> Any:
            if node.is_leaf:
                return map_fn(_underlying(node.work))
            return reduce_fn(go(node.left), go(node.right))
        return go(self.root)

    def describe(self) -> str:
        sizes = self.leaf_sizes()
        return (f"Plan(tasks={self.num_tasks()}, divisions={self.divisions}, "
                f"depth={self.depth()}, leaf_sizes={sizes})")


def build_plan(work: Divisible, *, ctx: Optional[StealContext] = None,
               max_tasks: int = 1 << 16) -> Plan:
    """Divide while the policy agrees — the static join-scheduler divide phase.

    ``ctx`` lets dynamic policies (thief_splitting / join_context) see a
    synthetic steal context; by default they see no steals, reproducing the
    "all threads busy" baseline.
    """
    ctx = ctx or StealContext()
    divisions = 0

    def should(w: Divisible) -> bool:
        if isinstance(w, Adaptor):
            return w.should_divide(ctx)
        return w.should_be_divided()

    def go(w: Divisible, depth: int) -> PlanNode:
        nonlocal divisions
        if divisions + 1 >= max_tasks or not should(w):
            return PlanNode(work=w, depth=depth)
        l, r = w.divide()
        divisions += 1
        node = PlanNode(work=None, depth=depth)
        node.left = go(l, depth + 1)
        node.right = go(r, depth + 1)
        return node

    root = go(work, 0)
    return Plan(root=root, divisions=divisions)


def demand_split(work: Divisible, demand: int) -> Plan:
    """Adaptive-schedule analogue: create exactly ``min(demand, size)`` leaves
    with the minimal number of divisions.

    The paper's adaptive scheduler divides *remaining* work in half on each
    steal, so after k steals there are k+1 tasks.  Statically we know the
    demand (idle mesh slots) up front; we split the *largest remaining* part
    first, which is what the runtime's steal pattern converges to.
    """
    demand = max(1, min(demand, max(1, work.size())))
    import heapq
    counter = 0
    heap: list[tuple[int, int, Divisible]] = [(-work.size(), counter, work)]
    divisions = 0
    while len(heap) < demand:
        size, _, biggest = heapq.heappop(heap)
        if -size <= 1 or not biggest.size() > 1:
            heapq.heappush(heap, (size, counter, biggest))
            break
        l, r = biggest.divide()
        divisions += 1
        counter += 1
        heapq.heappush(heap, (-l.size(), counter, l))
        counter += 1
        heapq.heappush(heap, (-r.size(), counter, r))
    parts = [w for _, _, w in sorted(heap, key=lambda t: _sort_key(t[2]))]
    # Build a right-deep tree over the parts (reduction order irrelevant for
    # associative ops; leaf order preserved for stability).
    nodes = [PlanNode(work=p, depth=1) for p in parts]
    root = nodes[0] if len(nodes) == 1 else _balanced_tree(nodes)
    return Plan(root=root, divisions=divisions)


def _sort_key(w: Divisible):
    u = _underlying(w)
    return getattr(u, "start", 0)


def _balanced_tree(nodes: Sequence[PlanNode]) -> PlanNode:
    if len(nodes) == 1:
        return nodes[0]
    mid = len(nodes) // 2
    n = PlanNode(work=None)
    n.left = _balanced_tree(nodes[:mid])
    n.right = _balanced_tree(nodes[mid:])
    return n


def geometric_blocks(total: int, *, first: int, growth: float = 2.0,
                     align: int = 1, cap: Optional[int] = None) -> List[Tuple[int, int]]:
    """The by_blocks size sequence (paper §3.5): geometric series of block
    sizes, so #blocks is O(log n) and wasted work ≤ growth/(1+growth).

    Returns [start, stop) pairs covering [0, total).  ``align`` snaps block
    boundaries (Pallas block sizes / page sizes); ``cap`` bounds block size
    (VMEM / HBM working-set ceilings).
    """
    out: List[Tuple[int, int]] = []
    pos = 0
    size = max(1, first)
    while pos < total:
        step = min(size, total - pos)
        if align > 1 and pos + step < total:
            step = max(align, (step // align) * align)
        stop = min(total, pos + step)
        out.append((pos, stop))
        pos = stop
        size = int(size * growth)
        if cap is not None:
            size = min(size, cap)
    return out


__all__ = ["Plan", "PlanNode", "MergeLevel", "DigitPass", "SortSchedule",
           "MULTI_TILE_LAUNCHES_PER_PASS", "digit_passes", "build_plan",
           "demand_split", "geometric_blocks"]
