"""The ``Divisible`` abstraction — Kvik's most fundamental trait (paper §3.1).

Kvik defines::

    fn should_be_divided(&self) -> bool;
    fn divide(self) -> (Self, Self);
    fn divide_at(self, index: usize) -> (Self, Self);

We reproduce the trait verbatim as a Python protocol.  In this framework a
``Divisible`` is a *work descriptor* — it never holds device arrays, only the
coordinates of work (batch ranges, sequence ranges, KV-block grids, expert
buckets, permutation ranges).  Division happens in Python at *plan time*
("user space" in the paper's sense: outside the compiled program), and the
resulting :class:`~repro.core.plan.Plan` parameterizes jitted JAX programs.

Concrete divisibles provided here:

* :class:`WorkRange`     — half-open integer range (the paper's slice).
* :class:`BatchWork`     — a range over a batch dimension (microbatching).
* :class:`SeqWork`       — a range over a sequence dimension (chunked prefill,
                           KV-block splitting).
* :class:`TileGrid2D`    — a 2-D tile grid (Pallas grid decomposition); divides
                           along its longest axis, exactly like TBB's
                           ``blocked_range2d``.
* :class:`ZipDivisible`  — a tuple of divisibles dividing in lock-step (the
                           paper's ``(input_slice, buffer_slice)`` tuple used by
                           the merge sort, §3.7).
* :class:`PermRange`     — a range over the permutation set of (1..n) where
                           ``divide_at`` is *expensive* (must generate the first
                           permutation from its rank) but sequential iteration
                           is cheap — the fannkuch-redux structure (paper §4.3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Protocol, Sequence, Tuple, runtime_checkable


@runtime_checkable
class Divisible(Protocol):
    """Protocol mirroring Kvik's ``Divisible`` trait."""

    def should_be_divided(self) -> bool:
        """Ask the work whether it wants to be divided further."""
        ...

    def divide(self) -> Tuple["Divisible", "Divisible"]:
        """Split into two approximately balanced halves."""
        ...

    def divide_at(self, index: int) -> Tuple["Divisible", "Divisible"]:
        """Split so the left part has approximately ``index`` elements."""
        ...

    def size(self) -> int:
        """Number of remaining work items (``len`` in Kvik's producers)."""
        ...


class Producer(Divisible, Protocol):
    """Kvik ``Producer`` = ``Divisible`` + sequential iteration (paper §2.3.2).

    ``partial_fold`` is the nano-loop primitive of the adaptive scheduler
    (paper §3.6): fold at most ``limit`` items into ``state`` and return the
    new state; the producer advances in place.
    """

    def partial_fold(self, state: Any, fold_op: Callable[[Any, Any], Any],
                     limit: int) -> Any:
        ...


def _check_fraction(index: int, n: int) -> int:
    return max(0, min(int(index), n))


@dataclasses.dataclass
class WorkRange:
    """Half-open integer range ``[start, stop)`` — the basic divisible.

    ``min_size`` plays the role of the producer's intrinsic division floor
    (basic Kvik producers divide down to size 1 by default).
    """

    start: int
    stop: int
    min_size: int = 1

    def size(self) -> int:
        return max(0, self.stop - self.start)

    def should_be_divided(self) -> bool:
        return self.size() > self.min_size

    def divide(self) -> Tuple["WorkRange", "WorkRange"]:
        return self.divide_at(self.size() // 2)

    def divide_at(self, index: int) -> Tuple["WorkRange", "WorkRange"]:
        index = _check_fraction(index, self.size())
        mid = self.start + index
        left = dataclasses.replace(self, start=self.start, stop=mid)
        right = dataclasses.replace(self, start=mid, stop=self.stop)
        return left, right

    # --- Producer interface -------------------------------------------------
    def partial_fold(self, state, fold_op, limit):
        take = min(limit, self.size())
        for i in range(self.start, self.start + take):
            state = fold_op(state, i)
        self.start += take
        return state

    def indices(self) -> range:
        return range(self.start, self.stop)

    def __repr__(self) -> str:  # compact for plan dumps
        return f"[{self.start},{self.stop})"


@dataclasses.dataclass
class BatchWork(WorkRange):
    """A range over a global-batch dimension.  ``axis`` documents intent."""

    axis: str = "batch"


@dataclasses.dataclass
class SeqWork(WorkRange):
    """A range over a sequence dimension (prefill chunks / KV blocks).

    ``align`` forces division points onto multiples (e.g. Pallas block sizes,
    page sizes): divide_at rounds the cut to the alignment grid.
    """

    align: int = 1

    def divide_at(self, index: int) -> Tuple["SeqWork", "SeqWork"]:
        index = _check_fraction(index, self.size())
        if self.align > 1:
            index = (index // self.align) * self.align
            if index == 0 and self.size() > self.align:
                index = self.align
        mid = self.start + index
        left = dataclasses.replace(self, start=self.start, stop=mid)
        right = dataclasses.replace(self, start=mid, stop=self.stop)
        return left, right

    def should_be_divided(self) -> bool:
        return self.size() > max(self.min_size, self.align)


@dataclasses.dataclass
class TileGrid2D:
    """A 2-D tile grid dividing along its longest axis (TBB blocked_range2d)."""

    rows: WorkRange
    cols: WorkRange

    def size(self) -> int:
        return self.rows.size() * self.cols.size()

    def should_be_divided(self) -> bool:
        return self.rows.should_be_divided() or self.cols.should_be_divided()

    def _divide_axis(self, index_rows: int | None, index_cols: int | None):
        if index_rows is not None:
            rl, rr = self.rows.divide_at(index_rows)
            return (TileGrid2D(rl, self.cols), TileGrid2D(rr, self.cols))
        cl, cr = self.cols.divide_at(index_cols)
        return (TileGrid2D(self.rows, cl), TileGrid2D(self.rows, cr))

    def divide(self):
        if self.rows.size() >= self.cols.size():
            return self._divide_axis(self.rows.size() // 2, None)
        return self._divide_axis(None, self.cols.size() // 2)

    def divide_at(self, index: int):
        # index counts items; translate to a cut on the longest axis.
        if self.rows.size() >= self.cols.size():
            per_row = max(1, self.cols.size())
            return self._divide_axis(index // per_row, None)
        per_col = max(1, self.rows.size())
        return self._divide_axis(None, index // per_col)

    def __repr__(self) -> str:
        return f"Tile({self.rows!r}x{self.cols!r})"


@dataclasses.dataclass
class ZipDivisible:
    """Tuple of divisibles dividing in lock-step (paper §3.7: the merge sort
    divides ``(input_slice, buffer_slice)`` together)."""

    parts: Tuple[Divisible, ...]

    def size(self) -> int:
        return min(p.size() for p in self.parts)

    def should_be_divided(self) -> bool:
        return all(p.should_be_divided() for p in self.parts)

    def divide(self):
        return self.divide_at(self.size() // 2)

    def divide_at(self, index: int):
        lefts, rights = [], []
        for p in self.parts:
            l, r = p.divide_at(index)
            lefts.append(l)
            rights.append(r)
        return (ZipDivisible(tuple(lefts)), ZipDivisible(tuple(rights)))


@dataclasses.dataclass
class WorkSet:
    """An ordered bag of independent work items — the multi-tenant analogue
    of a single range.  ``size`` is the total item count; ``divide_at`` cuts
    the *list* at the part boundary nearest the requested item count, so a
    ``by_blocks`` outer loop over a WorkSet sequences whole submissions.

    The SLO policies (:class:`~repro.core.policies.PriorityPolicy`,
    :class:`~repro.core.policies.DeadlinePolicy`) treat each part as one
    pool entry ordered by its :class:`~repro.core.adaptors.Tagged` metadata;
    every other policy sees an ordinary Divisible.
    """

    parts: Tuple[Divisible, ...]

    def size(self) -> int:
        return sum(p.size() for p in self.parts)

    def should_be_divided(self) -> bool:
        return len(self.parts) > 1

    def divide(self) -> Tuple["WorkSet", "WorkSet"]:
        return self.divide_at(self.size() // 2)

    def divide_at(self, index: int) -> Tuple["WorkSet", "WorkSet"]:
        index = _check_fraction(index, self.size())
        cut, acc = 0, 0
        for p in self.parts:       # smallest non-empty prefix >= index items
            acc += p.size()
            cut += 1
            if acc >= index:
                break
        return (WorkSet(self.parts[:cut]), WorkSet(self.parts[cut:]))

    def __repr__(self) -> str:
        return f"WorkSet({len(self.parts)} parts, {self.size()} items)"


# ---------------------------------------------------------------------------
# Fannkuch-style permutation ranges (paper §4.3)
# ---------------------------------------------------------------------------

def _perm_from_rank(n: int, rank: int) -> list[int]:
    """Generate the rank-th permutation of (1..n) in the benchmark's factorial
    number system.  This is the *expensive* first-permutation generation the
    paper highlights: cost O(n^2)-ish vs O(1) amortized for next-permutation."""
    items = list(range(1, n + 1))
    out = []
    # standard factoradic decode
    fact = [1] * n
    for i in range(1, n):
        fact[i] = fact[i - 1] * i
    r = rank
    for i in range(n - 1, -1, -1):
        d, r = divmod(r, fact[i])
        out.append(items.pop(d))
    return out


@dataclasses.dataclass
class PermRange:
    """Range [start, stop) over ranks of permutations of (1..n).

    ``divide_at`` is charged an extra ``split_cost`` (first-permutation
    generation) by cost models; sequential iteration via ``partial_fold`` walks
    permutations with the O(1)-amortized next-permutation step.  This is the
    structure that makes the paper's adaptive scheduler win on fannkuch: fewer
    divisions ⇒ fewer expensive from-rank generations.
    """

    n: int
    start: int
    stop: int
    min_size: int = 1
    _current: list[int] | None = dataclasses.field(default=None, repr=False)

    def size(self) -> int:
        return max(0, self.stop - self.start)

    def should_be_divided(self) -> bool:
        return self.size() > self.min_size

    def divide(self):
        return self.divide_at(self.size() // 2)

    def divide_at(self, index: int):
        index = _check_fraction(index, self.size())
        mid = self.start + index
        left = PermRange(self.n, self.start, mid, self.min_size,
                         self._current.copy() if self._current else None)
        right = PermRange(self.n, mid, self.stop, self.min_size, None)
        return left, right

    @property
    def split_cost(self) -> float:
        """Virtual cost of materializing the first permutation from a rank."""
        return float(self.n * self.n)

    def current_permutation(self) -> list[int]:
        if self._current is None:
            self._current = _perm_from_rank(self.n, self.start)
        return self._current

    @staticmethod
    def _next_permutation(p: list[int]) -> None:
        """In-place lexicographic next permutation (amortized O(1))."""
        i = len(p) - 2
        while i >= 0 and p[i] >= p[i + 1]:
            i -= 1
        if i < 0:
            return
        j = len(p) - 1
        while p[j] <= p[i]:
            j -= 1
        p[i], p[j] = p[j], p[i]
        p[i + 1:] = reversed(p[i + 1:])

    def partial_fold(self, state, fold_op, limit):
        take = min(limit, self.size())
        perm = self.current_permutation()
        for _ in range(take):
            state = fold_op(state, perm)
            self._next_permutation(perm)
        self.start += take
        return state


def total_permutations(n: int) -> int:
    return math.factorial(n)


__all__ = [
    "Divisible", "Producer", "WorkRange", "BatchWork", "SeqWork",
    "TileGrid2D", "ZipDivisible", "WorkSet", "PermRange",
    "total_permutations",
]
