"""A deterministic virtual-time work-stealing runtime.

Why this exists: the paper's dynamic claims (task counts under
thief_splitting, "tasks = successful steals + 1" for the adaptive scheduler,
depjoin's no-wait reductions, fannkuch's split-cost sensitivity) are about a
*work-stealing execution engine*.  A statically-compiled TPU program has no
such engine, and this 1-core container could not exhibit real parallelism
anyway.  So we validate those claims bit-exactly on a discrete-event simulator
with p virtual workers, seeded victim selection, and explicit cost models —
then carry the *validated policies* into the static/replan world of the rest
of the framework.

Semantics follow Rayon/Kvik:

* join mode — executing a task first consults the policy; division pushes the
  right child to the worker's own deque (stealable) and continues with the
  left.  Leaves run sequentially for ``cost_fn(work)`` virtual seconds.
  Idle workers steal from the *top* of a random victim's deque.
* reductions — plain ``join``: the reduction is owned by the worker that
  divided; it runs it when it next becomes idle.  ``depjoin``: the worker that
  completes the *second* child runs the reduction immediately (paper §3.2).
* adaptive mode — a single initial task; the executing worker folds in
  geometrically growing nano-loops (1, 2, 4, ...), checking a steal-request
  mailbox between loops; a pending request splits the *remaining* work in half
  and hands it to the thief directly; nano size resets (paper §2.2/§3.6).
* heterogeneous workers — per-worker speed factors (straggler studies,
  fannkuch's load imbalance).
* interruptible work — a global flag set by a predicate on processed items;
  join-mode tasks only check it before starting (classical schedulers can only
  cancel non-started tasks — paper §4.1); adaptive tasks also check at
  nano-loop boundaries.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from .adaptors import Adaptor, StealContext
from .divisible import Divisible


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CostModel:
    """Virtual-time costs.

    ``per_item``      — sequential cost per work item.
    ``split_overhead``— fixed cost of one division (task creation).
    ``split_cost_fn`` — extra, work-dependent division cost (e.g. fannkuch's
                        first-permutation generation, merge sort's binary
                        search); receives the divided work.
    ``reduce_cost``   — cost of one reduction.
    ``check_overhead``— cost of one steal-request check (the reason nano-loops
                        exist at all).
    ``steal_latency`` — time for a steal attempt (success or failure).
    """

    per_item: float = 1.0
    split_overhead: float = 1.0
    split_cost_fn: Optional[Callable[[Divisible], float]] = None
    reduce_cost: float = 0.0
    check_overhead: float = 0.05
    steal_latency: float = 0.5

    def split_cost(self, work: Divisible) -> float:
        extra = 0.0
        if self.split_cost_fn is not None:
            extra = self.split_cost_fn(work)
        else:
            u = work.unwrap() if isinstance(work, Adaptor) else work
            extra = float(getattr(u, "split_cost", 0.0))
        return self.split_overhead + extra


@dataclasses.dataclass
class SimResult:
    makespan: float
    tasks_created: int           # leaves actually executed as separate tasks
    divisions: int
    steals_attempted: int
    steals_successful: int
    reductions: int
    items_processed: int
    items_total: int
    per_worker_busy: List[float]
    stopped_early: bool = False

    @property
    def speedup_vs_serial(self) -> float:
        serial = self.items_total  # with per_item=1
        return serial / self.makespan if self.makespan > 0 else 0.0

    @property
    def wasted_items(self) -> int:
        return 0  # overwritten by interruptible runs via dataclasses.replace

    @property
    def load_balance(self) -> float:
        b = self.per_worker_busy
        return (min(b) / max(b)) if max(b) > 0 else 1.0


# ---------------------------------------------------------------------------
# Join-mode simulation
# ---------------------------------------------------------------------------

class _JoinNode:
    __slots__ = ("pending", "owner", "parent", "reduce_ready")

    def __init__(self, owner: int, parent: Optional["_JoinNode"]):
        self.pending = 2
        self.owner = owner
        self.parent = parent
        self.reduce_ready = False


@dataclasses.dataclass
class _Task:
    work: Divisible
    parent: Optional[_JoinNode]
    creator: int
    stolen: bool = False


class WorkStealingSim:
    """Discrete-event work-stealing simulator (join / depjoin modes)."""

    def __init__(self, p: int, cost: CostModel, *, depjoin: bool = False,
                 seed: int = 0, speeds: Optional[List[float]] = None,
                 stop_predicate: Optional[Callable[[Divisible], Optional[int]]] = None):
        self.p = p
        self.cost = cost
        self.depjoin = depjoin
        self.rng = random.Random(seed)
        self.speeds = speeds or [1.0] * p
        assert len(self.speeds) == p
        self.stop_predicate = stop_predicate

    def run(self, work: Divisible) -> SimResult:
        p, cost = self.p, self.cost
        time = [0.0] * p
        busy = [0.0] * p
        deques: List[deque] = [deque() for _ in range(p)]
        pending_reductions: List[List[_JoinNode]] = [[] for _ in range(p)]
        current: List[Optional[_Task]] = [None] * p
        items_total = work.size()
        stats = dict(tasks=0, divisions=0, steal_try=0, steal_ok=0,
                     reductions=0, items=0)
        stop_flag = [False]
        outstanding = [1]  # live leaf tasks + queued work

        current[0] = _Task(work=work, parent=None, creator=0)

        def policy_divide(w: Divisible, ctx: StealContext) -> bool:
            if isinstance(w, Adaptor):
                return w.should_divide(ctx)
            return w.should_be_divided()

        def finish_join(node: Optional[_JoinNode], wid: int) -> None:
            while node is not None:
                node.pending -= 1
                if node.pending > 0:
                    return
                # both children complete → reduction
                if self.depjoin:
                    time[wid] += cost.reduce_cost / self.speeds[wid]
                    busy[wid] += cost.reduce_cost / self.speeds[wid]
                    stats["reductions"] += 1
                    node = node.parent
                else:
                    node.reduce_ready = True
                    pending_reductions[node.owner].append(node)
                    return

        # Discrete-event loop: always advance the earliest-time worker.
        idle_spin = 0
        while True:
            wid = min(range(p), key=lambda i: time[i])
            t = time[wid]

            task = current[wid]
            if task is not None:
                # divide until the policy says stop
                ctx = StealContext(stolen=task.stolen, worker=wid,
                                   demand=sum(1 for c in current if c is None))
                w = task.work
                while policy_divide(w, ctx):
                    sc = cost.split_cost(w) / self.speeds[wid]
                    time[wid] += sc
                    busy[wid] += sc
                    l, r = (w.divide_ctx(ctx) if hasattr(w, "divide_ctx")
                            else w.divide())
                    stats["divisions"] += 1
                    node = _JoinNode(owner=wid, parent=task.parent)
                    deques[wid].append(_Task(work=r, parent=node, creator=wid))
                    outstanding[0] += 1
                    task = _Task(work=l, parent=node, creator=wid,
                                 stolen=False)
                    w = task.work
                    ctx = StealContext(stolen=False, worker=wid,
                                       demand=sum(1 for c in current if c is None))
                # run leaf sequentially
                stats["tasks"] += 1
                n_items = w.size()
                if stop_flag[0]:
                    n_items = 0  # cancelled before start
                run_t = (n_items * cost.per_item) / self.speeds[wid]
                time[wid] += run_t
                busy[wid] += run_t
                stats["items"] += n_items
                if self.stop_predicate is not None and n_items > 0:
                    hit = self.stop_predicate(
                        w.unwrap() if isinstance(w, Adaptor) else w)
                    if hit is not None:
                        stop_flag[0] = True
                if isinstance(w, Adaptor):
                    w.on_finish()
                current[wid] = None
                outstanding[0] -= 1
                finish_join(task.parent, wid)
                continue

            # idle: pending reductions first (plain join semantics)
            if pending_reductions[wid]:
                node = pending_reductions[wid].pop()
                rt = cost.reduce_cost / self.speeds[wid]
                time[wid] += rt
                busy[wid] += rt
                stats["reductions"] += 1
                finish_join(node.parent, wid)
                continue

            # own deque
            if deques[wid]:
                current[wid] = deques[wid].pop()
                continue

            # steal
            victims = [i for i in range(p) if i != wid and deques[i]]
            if victims:
                stats["steal_try"] += 1
                v = self.rng.choice(victims)
                time[wid] += cost.steal_latency / self.speeds[wid]
                if deques[v]:
                    stolen = deques[v].popleft()
                    stolen.stolen = True
                    if isinstance(stolen.work, Adaptor):
                        stolen.work.on_steal()
                    stats["steal_ok"] += 1
                    current[wid] = stolen
                continue

            # nothing to do anywhere?
            if outstanding[0] <= 0 and not any(pending_reductions[i] for i in range(p)):
                break
            # wait: jump to the next busy worker's time
            others = [time[i] for i in range(p) if i != wid and
                      (current[i] is not None or deques[i] or pending_reductions[i])]
            if not others:
                idle_spin += 1
                if idle_spin > 10 * p:
                    break
                time[wid] += cost.steal_latency
                continue
            idle_spin = 0
            time[wid] = max(time[wid], min(others)) + 1e-9

        return SimResult(
            makespan=max(time), tasks_created=stats["tasks"],
            divisions=stats["divisions"], steals_attempted=stats["steal_try"],
            steals_successful=stats["steal_ok"], reductions=stats["reductions"],
            items_processed=stats["items"], items_total=items_total,
            per_worker_busy=busy, stopped_early=stop_flag[0])


# ---------------------------------------------------------------------------
# Adaptive-mode simulation (paper §2.2 / §3.6)
# ---------------------------------------------------------------------------

class AdaptiveSim:
    """Steal-driven splitting with geometric nano-loops.

    One initial task; idle workers post steal *requests* to a random busy
    worker's mailbox; the victim serves the request at its next micro-loop
    boundary by dividing the remaining work in half.  Nano size starts at
    ``nano0`` and doubles per un-stolen micro-loop, resetting on split.
    """

    def __init__(self, p: int, cost: CostModel, *, seed: int = 0,
                 speeds: Optional[List[float]] = None, nano0: int = 1,
                 stop_predicate: Optional[Callable[[Any], Optional[int]]] = None):
        self.p = p
        self.cost = cost
        self.rng = random.Random(seed)
        self.speeds = speeds or [1.0] * p
        self.stop_predicate = stop_predicate
        self.nano0 = nano0

    def run(self, work: Divisible) -> SimResult:
        p, cost = self.p, self.cost
        time = [0.0] * p
        busy = [0.0] * p
        # each busy worker holds (work, nano_size); mailbox[w] = list of thief ids
        holding: List[Optional[list]] = [None] * p
        mailbox: List[List[int]] = [[] for _ in range(p)]
        waiting: Dict[int, float] = {}  # thief id -> since
        items_total = work.size()
        stats = dict(tasks=1, divisions=0, steal_try=0, steal_ok=0,
                     reductions=0, items=0)
        stop_flag = [False]
        holding[0] = [work, self.nano0]

        def busy_workers():
            return [i for i in range(p) if holding[i] is not None]

        while True:
            active = busy_workers()
            if not active:
                break
            # advance the earliest active worker by one micro-loop
            wid = min(active, key=lambda i: time[i])
            slot = holding[wid]
            w, nano = slot
            remaining = w.size()
            if remaining == 0 or stop_flag[0]:
                holding[wid] = None
                if isinstance(w, Adaptor):
                    w.on_finish()
                continue
            grant = min(nano, remaining)
            run_t = (grant * cost.per_item + cost.check_overhead) / self.speeds[wid]
            # consume `grant` items via partial_fold
            hit = [None]

            def fold(st, item):
                if self.stop_predicate is not None:
                    r = self.stop_predicate(item)
                    if r is not None:
                        hit[0] = r
                return st

            w.partial_fold(None, fold, grant)
            time[wid] += run_t
            busy[wid] += run_t
            stats["items"] += grant
            if hit[0] is not None:
                stop_flag[0] = True
                holding[wid] = None
                continue
            if w.size() == 0:
                holding[wid] = None
                continue
            # micro-loop boundary: serve one pending steal request
            served = False
            # collect requests from idle workers (they request lazily here:
            # any idle worker with time <= current boundary is a requester)
            for thief in range(p):
                if holding[thief] is None and thief != wid:
                    if thief not in waiting:
                        waiting[thief] = time[thief]
                        stats["steal_try"] += 1
            if mailbox[wid]:
                thief = mailbox[wid].pop(0)
            else:
                idle = [i for i in waiting if holding[i] is None]
                thief = self.rng.choice(idle) if idle else None
            if thief is not None and w.size() > 1:
                l, r = w.divide()
                stats["divisions"] += 1
                stats["steal_ok"] += 1
                stats["tasks"] += 1
                del waiting[thief]
                lat = cost.steal_latency / self.speeds[thief]
                time[thief] = max(time[thief], time[wid]) + lat
                holding[thief] = [r, self.nano0]
                holding[wid] = [l, self.nano0]
                served = True
            if not served:
                slot[0] = w
                slot[1] = min(nano * 2, 1 << 20)

        # reductions: tasks-1 merges (tree), charged to the final makespan
        stats["reductions"] = max(0, stats["tasks"] - 1)
        mk = max(time) + stats["reductions"] * cost.reduce_cost / max(self.speeds)
        return SimResult(
            makespan=mk, tasks_created=stats["tasks"],
            divisions=stats["divisions"], steals_attempted=stats["steal_try"],
            steals_successful=stats["steal_ok"], reductions=stats["reductions"],
            items_processed=stats["items"], items_total=items_total,
            per_worker_busy=busy, stopped_early=stop_flag[0])


# ---------------------------------------------------------------------------
# Static partition executor (for "rust static"-style baselines)
# ---------------------------------------------------------------------------

def static_partition_sim(work: Divisible, p: int, cost: CostModel, *,
                         speeds: Optional[List[float]] = None,
                         num_blocks: Optional[int] = None) -> SimResult:
    """OpenMP-static-style baseline: pre-split into ``num_blocks`` equal chunks
    assigned round-robin; no stealing.  (fannkuch's "rust static" and the
    naive find_first partitioning.)"""
    speeds = speeds or [1.0] * p
    num_blocks = num_blocks or p
    items_total = work.size()
    chunks: List[Divisible] = []
    rest = work
    for i in range(num_blocks - 1):
        sz = rest.size() // (num_blocks - i)
        l, rest = rest.divide_at(sz)
        chunks.append(l)
    chunks.append(rest)
    time = [0.0] * p
    split_cost = sum(cost.split_cost(work) for _ in range(num_blocks - 1))
    for i, ch in enumerate(chunks):
        wkr = i % p
        time[wkr] += (ch.size() * cost.per_item) / speeds[wkr]
    mk = max(time) + split_cost / max(speeds)
    return SimResult(makespan=mk, tasks_created=num_blocks,
                     divisions=num_blocks - 1, steals_attempted=0,
                     steals_successful=0, reductions=num_blocks - 1,
                     items_processed=items_total, items_total=items_total,
                     per_worker_busy=list(time))


__all__ = ["CostModel", "SimResult", "WorkStealingSim", "AdaptiveSim",
           "static_partition_sim"]
