"""Deprecation shims over the unified scheduling runtime.

The three engines that used to live here — ``WorkStealingSim`` (join /
depjoin), ``AdaptiveSim``, and ``static_partition_sim`` — are now ~50-line
policies (:mod:`repro.core.policies`) over one shared discrete-event engine
(:mod:`repro.core.runtime`).  These shims keep the historical constructor
signatures and produce **bit-identical** :class:`~repro.core.runtime.
SimResult` values under fixed seeds (pinned by ``tests/test_runtime.py``'s
golden table), so existing callers and the paper-claim tests keep passing.

New code should use :class:`~repro.core.runtime.Runtime` with an explicit
policy (or the schedulers' ``simulate`` faces), which additionally allows
compositions these shims never could: ``by_blocks`` outer loops over
adaptive inner blocks, adaptor-wrapped adaptive tasks, depjoin under
by_blocks, and so on.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional

from .divisible import Divisible
from .policies import (AdaptivePolicy, DepJoinPolicy, JoinPolicy,
                       StaticPartitionPolicy)
from .runtime import CostModel, Runtime, SimResult


class WorkStealingSim:
    """Deprecated shim: join/depjoin work stealing on the unified Runtime."""

    def __init__(self, p: int, cost: CostModel, *, depjoin: bool = False,
                 seed: int = 0, speeds: Optional[List[float]] = None,
                 stop_predicate: Optional[Callable[[Divisible], Optional[int]]] = None):
        self.p = p
        self.cost = cost
        self.depjoin = depjoin
        policy = DepJoinPolicy() if depjoin else JoinPolicy()
        self._rt = Runtime(p, cost, policy, seed=seed, speeds=speeds,
                           stop_predicate=stop_predicate)

    def run(self, work: Divisible) -> SimResult:
        return self._rt.run(work)


class AdaptiveSim:
    """Deprecated shim: steal-driven adaptive splitting on the unified
    Runtime.  The old per-victim ``mailbox`` (which nothing ever posted to)
    is gone — steal requests live in the engine's single request queue."""

    def __init__(self, p: int, cost: CostModel, *, seed: int = 0,
                 speeds: Optional[List[float]] = None, nano0: int = 1,
                 stop_predicate: Optional[Callable[[Any], Optional[int]]] = None):
        self.p = p
        self.cost = cost
        self._rt = Runtime(p, cost, AdaptivePolicy(nano0=nano0), seed=seed,
                           speeds=speeds, stop_predicate=stop_predicate)

    def run(self, work: Divisible) -> SimResult:
        return self._rt.run(work)


def static_partition_sim(work: Divisible, p: int, cost: CostModel, *,
                         speeds: Optional[List[float]] = None,
                         num_blocks: Optional[int] = None) -> SimResult:
    """Deprecated shim: OpenMP-static baseline on the unified Runtime."""
    rt = Runtime(p, cost, StaticPartitionPolicy(num_blocks=num_blocks),
                 speeds=speeds)
    return rt.run(work)


__all__ = ["CostModel", "SimResult", "WorkStealingSim", "AdaptiveSim",
           "static_partition_sim"]
