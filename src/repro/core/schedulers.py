"""Schedulers (paper §3.2, §3.5, §3.6).

Four schedulers, mirroring Kvik:

* :class:`JoinScheduler`   — fork-join divide/map/tree-reduce (paper §3.2).
  Statically: builds a :class:`~repro.core.plan.Plan` and emits a symmetric
  reduction tree at trace time.
* ``depjoin``              — same division tree; the "reduce by last finisher"
  optimization only exists dynamically, so it is a policy of the unified
  virtual-time runtime (``repro.core.runtime`` + ``repro.core.policies``),
  where its benefit is measured — reachable via ``simulate(depjoin=True)``.

Each scheduler has two faces: the *static* ``plan``/``schedule`` face
(division recorded at trace time, parameterizing compiled programs) and a
*dynamic* ``simulate(work, p, cost)`` face running the same policy on the
unified discrete-event runtime.
* :class:`ByBlocks`        — a *sequential* outer loop over *parallel* blocks
  of geometrically growing size (paper §3.5).  This is the scheduler for
  interruptible computations: chunked prefill, early-exit decode, all-finite
  audits.  Wasted work is bounded by growth/(1+growth) of useful work.
* :class:`AdaptiveScheduler` — split only on demand (paper §3.6).  Statically
  the demand is the mesh-axis width (``demand_split``); dynamically the
  simruntime reproduces the steal-driven nano/micro-loop behaviour.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, List, Optional, Tuple

from .adaptors import Adaptor, StealContext
from .divisible import Divisible
from .plan import Plan, build_plan, demand_split, geometric_blocks
from .policies import (AdaptivePolicy, ByBlocksPolicy, DepJoinPolicy,
                       JoinPolicy, SchedulingPolicy)
from .runtime import CostModel, Runtime, SimResult


# ---------------------------------------------------------------------------
# Join scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JoinScheduler:
    """Static fork-join scheduling: divide per policy, map leaves, tree-reduce.

    ``ctx`` feeds dynamic policies a synthetic steal context (default: no
    steals — the all-threads-busy baseline).
    """

    ctx: Optional[StealContext] = None

    def plan(self, work: Divisible) -> Plan:
        return build_plan(work, ctx=self.ctx)

    def schedule(self, work: Divisible, map_fn: Callable[[Divisible], Any],
                 reduce_fn: Callable[[Any, Any], Any]) -> Any:
        return self.plan(work).map_reduce(map_fn, reduce_fn)

    def simulate(self, work: Divisible, p: int, cost: CostModel, *,
                 depjoin: bool = False, seed: int = 0, speeds=None,
                 stop_predicate=None) -> SimResult:
        """Dynamic face: run this schedule on the unified virtual-time
        runtime (``depjoin=True`` → reduce-by-last-finisher, paper §3.2)."""
        policy = DepJoinPolicy() if depjoin else JoinPolicy()
        return Runtime(p, cost, policy, seed=seed, speeds=speeds,
                       stop_predicate=stop_predicate).run(work)


def schedule_join(work: Divisible, map_fn, reduce_fn, *,
                  ctx: Optional[StealContext] = None) -> Any:
    return JoinScheduler(ctx=ctx).schedule(work, map_fn, reduce_fn)


# ---------------------------------------------------------------------------
# by_blocks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BlockStats:
    """Accounting for interruptible executions (validates the paper's bound)."""

    blocks_run: int = 0
    items_run: int = 0
    items_total: int = 0
    stopped_early: bool = False
    stop_index: Optional[int] = None

    @property
    def wasted_items(self) -> int:
        """Items processed beyond the stop index (0 when not stopped)."""
        if self.stop_index is None:
            return 0
        return max(0, self.items_run - (self.stop_index + 1))

    @property
    def wasted_fraction(self) -> float:
        if self.items_run == 0:
            return 0.0
        return self.wasted_items / self.items_run


@dataclasses.dataclass
class ByBlocks:
    """Sequential outer loop over geometrically growing parallel blocks.

    ``first`` defaults to the parallelism width p (the paper: "we take the
    number of threads P for the initial size"), ``growth`` = 2.  Each block is
    handed to ``block_fn`` (typically a jitted parallel computation over that
    chunk); between blocks ``should_stop(carry)`` is consulted — that is the
    interruption point.
    """

    first: int
    growth: float = 2.0
    align: int = 1
    cap: Optional[int] = None

    def blocks(self, work: Divisible) -> Iterator[Divisible]:
        total = work.size()
        rest = work
        for (start, stop) in geometric_blocks(total, first=self.first,
                                              growth=self.growth,
                                              align=self.align, cap=self.cap):
            blk, rest = rest.divide_at(stop - start)
            yield blk

    def block_bounds(self, total: int) -> List[Tuple[int, int]]:
        return geometric_blocks(total, first=self.first, growth=self.growth,
                                align=self.align, cap=self.cap)

    def run(self, work: Divisible,
            block_fn: Callable[[Divisible, Any], Any],
            carry: Any,
            should_stop: Callable[[Any], bool] = lambda c: False,
            ) -> Tuple[Any, BlockStats]:
        """Run blocks sequentially until exhausted or ``should_stop``."""
        stats = BlockStats(items_total=work.size())
        for blk in self.blocks(work):
            carry = block_fn(blk, carry)
            stats.blocks_run += 1
            stats.items_run += blk.size()
            if should_stop(carry):
                stats.stopped_early = True
                break
        return carry, stats

    def simulate(self, work: Divisible, p: int, cost: CostModel, *,
                 inner: Optional[SchedulingPolicy] = None, seed: int = 0,
                 speeds=None, stop_predicate=None) -> SimResult:
        """Dynamic face: sequential outer loop of geometric blocks on the
        unified runtime, each block a parallel region under ``inner``
        (default join).  Composition the old engines could not express:
        pass ``inner=AdaptivePolicy()`` for interruptible adaptive blocks."""
        policy = ByBlocksPolicy(inner=inner or JoinPolicy(), first=self.first,
                                growth=self.growth, align=self.align,
                                cap=self.cap)
        return Runtime(p, cost, policy, seed=seed, speeds=speeds,
                       stop_predicate=stop_predicate).run(work)


def by_blocks(first: int, growth: float = 2.0, **kw) -> ByBlocks:
    return ByBlocks(first=first, growth=growth, **kw)


# ---------------------------------------------------------------------------
# Adaptive scheduler (static face)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdaptiveScheduler:
    """Static face of the adaptive schedule: division only on demand.

    ``demand`` is the parallelism the hardware asks for (mesh-axis width,
    idle DP replicas, grid slots).  The plan has exactly min(demand, size)
    leaves from demand−1 divisions — "tasks created = successful steals + 1".

    The *dynamic* adaptive scheduler — geometric nano-loops, interruption
    checks, steal-driven splits — is :class:`~repro.core.policies.
    AdaptivePolicy` on the unified runtime (see :meth:`simulate`) and the
    between-steps rebalancer
    (:mod:`repro.train.straggler`) where real dynamism exists at cluster scale.
    """

    demand: int

    def plan(self, work: Divisible) -> Plan:
        return demand_split(work, self.demand)

    def schedule(self, work: Divisible, map_fn, reduce_fn) -> Any:
        return self.plan(work).map_reduce(map_fn, reduce_fn)

    def simulate(self, work: Divisible, p: Optional[int], cost: CostModel, *,
                 nano0: int = 1, seed: int = 0, speeds=None,
                 stop_predicate=None) -> SimResult:
        """Dynamic face: the steal-driven nano/micro-loop behaviour on the
        unified runtime (``p`` defaults to this scheduler's demand)."""
        return Runtime(p or self.demand, cost, AdaptivePolicy(nano0=nano0),
                       seed=seed, speeds=speeds,
                       stop_predicate=stop_predicate).run(work)


def adaptive(demand: int) -> AdaptiveScheduler:
    return AdaptiveScheduler(demand=demand)


__all__ = [
    "JoinScheduler", "schedule_join", "ByBlocks", "by_blocks", "BlockStats",
    "AdaptiveScheduler", "adaptive",
]
