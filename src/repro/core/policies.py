"""Scheduling policies — the decision half of Kvik's engine/policy split.

Each policy is a small object driving the shared discrete-event engine
(:class:`~repro.core.runtime.Runtime`) through a fixed set of hooks:

========================  ===================================================
hook                      decision it owns
========================  ===================================================
``drive``                 how regions are sequenced (by_blocks overrides)
``on_region_start``       where the initial work is seeded
``select_worker``         which worker's clock advances next
``quantum``               one event-loop step for that worker
``on_task_start``         eager division before running a leaf (join family)
``on_microloop_boundary`` what happens between nano-loops (adaptive family)
``on_steal_request``      how an idle worker acquires work
``on_join_complete``      who runs a reduction (join defers to the owner;
                          depjoin runs it on the last finisher)
``on_region_end``         the region's makespan and final accounting
========================  ===================================================

The five concrete policies map to the paper as:

* :class:`JoinPolicy`        — fork-join divide/run/tree-reduce (§3.2):
  division happens eagerly up front per the (adaptor-wrapped) divisible; the
  reduction is owned by the worker that divided and runs when it next idles.
* :class:`DepJoinPolicy`     — §3.2's ``depjoin``: identical division tree,
  but the worker completing the *second* child runs the reduction
  immediately (no wait on the owner) — one overridden hook.
* :class:`AdaptivePolicy`    — §2.2/§3.6: a single initial task; the worker
  folds geometrically growing nano-loops (1, 2, 4, ...) and serves steal
  *requests* at micro-loop boundaries by dividing the remaining work in
  half; nano size resets on split.  "tasks created = successful steals + 1".
* :class:`StaticPartitionPolicy` — the OpenMP-static / "rust static"
  baseline (§4.3): pre-split into equal chunks round-robin, no stealing.
* :class:`ByBlocksPolicy`    — §3.5 as a *dynamic* policy: a sequential
  outer loop over geometrically growing blocks, each block executed by an
  arbitrary *inner* policy on the same worker pool (barrier between
  blocks); the interruption flag is checked between blocks.  This is the
  composition the four pre-refactor engines could not express — e.g.
  ``ByBlocksPolicy(inner=AdaptivePolicy(), first=p)``.

All policies compose with the :mod:`repro.core.adaptors` stack: the engine
consults ``should_divide(ctx)`` on adaptor-wrapped work, so e.g.
``cap``/``size_limit``-wrapped work under :class:`AdaptivePolicy` refuses
splits exactly as it would under :class:`JoinPolicy`.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Any, Optional

from .adaptors import Adaptor, StealContext, find_tag
from .divisible import Divisible, WorkSet
from .plan import geometric_blocks
from .runtime import CostModel, Runtime, SimResult, Task


class SchedulingPolicy:
    """Base policy: hook defaults shared by the concrete policies."""

    name = "policy"

    # -- region sequencing ---------------------------------------------------
    def drive(self, rt: Runtime, work: Divisible) -> float:
        return rt.run_region(work, self)

    def on_region_start(self, rt: Runtime, work: Divisible) -> None:
        raise NotImplementedError

    def on_region_end(self, rt: Runtime) -> float:
        return max(rt.time)

    # -- event loop ----------------------------------------------------------
    def select_worker(self, rt: Runtime) -> Optional[int]:
        raise NotImplementedError

    def quantum(self, rt: Runtime, wid: int) -> None:
        raise NotImplementedError

    # -- fine-grained decisions ----------------------------------------------
    def on_task_start(self, rt: Runtime, wid: int, task: Task) -> Task:
        return task

    def on_microloop_boundary(self, rt: Runtime, wid: int, task: Task) -> None:
        pass

    def on_steal_request(self, rt: Runtime, wid: int) -> bool:
        return rt.steal_from_random_victim(wid)

    def on_join_complete(self, rt: Runtime, node: Any, wid: int) -> bool:
        """True → the finishing worker reduces immediately (depjoin)."""
        return False

    def preempt_grant(self, rt: Runtime, wid: int, task: Task,
                      grant: int) -> int:
        """Mid-region preemption hook: a policy may shrink the next grant so
        a micro-loop boundary (the only steal-service point) arrives sooner.
        The default keeps the grant unchanged — faultless runs are
        bit-identical."""
        return grant


# ---------------------------------------------------------------------------
# join / depjoin
# ---------------------------------------------------------------------------

class JoinPolicy(SchedulingPolicy):
    """Fork-join work stealing (paper §3.2, Rayon/Kvik semantics)."""

    name = "join"

    def on_region_start(self, rt: Runtime, work: Divisible) -> None:
        w0 = rt.seed_worker()            # 0 unless the fault plan killed it
        rt.current[w0] = Task(work=work, creator=w0)
        rt.outstanding = 1

    def select_worker(self, rt: Runtime) -> Optional[int]:
        cand = [i for i in range(rt.p) if rt.alive(i)]
        if not cand:
            return None
        return min(cand, key=lambda i: rt.time[i])

    def quantum(self, rt: Runtime, wid: int) -> None:
        task = rt.current[wid]
        if task is not None:
            task = self.on_task_start(rt, wid, task)
            rt.run_leaf(wid, task)
            return
        if rt.pending_reductions[wid]:       # plain-join: owner reduces
            rt.run_deferred_reduction(wid)
            return
        if rt.deques[wid]:                   # own work first
            rt.current[wid] = rt.deques[wid].pop()
            return
        if self.on_steal_request(rt, wid):   # then steal
            return
        rt.idle_or_finish(wid)

    def on_task_start(self, rt: Runtime, wid: int, task: Task) -> Task:
        """Divide until the (adaptor-wrapped) work declines: right children
        go to the worker's own deque (stealable), continue with the left."""
        ctx = StealContext(stolen=task.stolen, worker=wid,
                           demand=rt.idle_count())
        w = task.work
        while rt.wants_division(w, ctx):
            rt.charge(wid, rt.cost.split_cost(w))
            l, r = rt.divide(w, ctx)
            node = rt.new_join_node(owner=wid, parent=task.parent)
            rt.push_task(wid, Task(work=r, parent=node, creator=wid))
            task = Task(work=l, parent=node, creator=wid, stolen=False)
            w = task.work
            ctx = StealContext(stolen=False, worker=wid,
                               demand=rt.idle_count())
        return task


class DepJoinPolicy(JoinPolicy):
    """§3.2 ``depjoin``: the worker that completes the *second* child runs
    the reduction immediately — the tree never waits on the dividing owner."""

    name = "depjoin"

    def on_join_complete(self, rt: Runtime, node: Any, wid: int) -> bool:
        return True


# ---------------------------------------------------------------------------
# adaptive (steal-driven splits + geometric nano-loops)
# ---------------------------------------------------------------------------

class AdaptivePolicy(SchedulingPolicy):
    """§2.2/§3.6: split only on demand, amortize request checks.

    One initial task; the executing worker folds in geometrically growing
    nano-loops, checking the shared steal-request queue between loops; a
    pending request splits the *remaining* work in half and hands it to the
    thief directly; nano size resets.  Reductions form a chain of
    (tasks − 1) merges charged at region end.

    ``preempt=True`` arms the mid-region preemption hook: while another
    alive worker is idle (a pending steal request, or a fault-plan death
    freed its work), the next grant is clipped to ``nano0`` so the
    steal-service boundary arrives after ~nano0 items instead of after the
    geometrically grown nano-loop.  This is what lets adaptive re-spread an
    orphaned task across survivors *inside* a region — without it, late in
    a region there are no micro-loop boundaries left and recovery never
    happens (the pinned zero-recovery roofline result).  Faultless,
    demand-free runs are unchanged: the clip only fires when demand exists.
    """

    name = "adaptive"

    def __init__(self, nano0: int = 1, nano_cap: int = 1 << 20,
                 preempt: bool = False):
        self.nano0 = nano0
        self.nano_cap = nano_cap
        self.preempt = preempt

    def on_region_start(self, rt: Runtime, work: Divisible) -> None:
        self._region_tasks = 1
        rt.stats["tasks"] += 1
        w0 = rt.seed_worker()            # 0 unless the fault plan killed it
        rt.current[w0] = Task(work=work, creator=w0, nano=self.nano0)

    def select_worker(self, rt: Runtime) -> Optional[int]:
        active = [i for i in range(rt.p) if rt.current[i] is not None]
        if not active:
            return None
        return min(active, key=lambda i: rt.time[i])

    def quantum(self, rt: Runtime, wid: int) -> None:
        task = rt.current[wid]
        w = task.work
        remaining = w.size()
        if remaining == 0 or rt.stop_flag:
            rt.retire(wid)
            return
        grant = min(task.nano, remaining)
        grant = self.preempt_grant(rt, wid, task, grant)
        hit = rt.run_grant(wid, w, grant)
        if rt.worker_died(wid):               # grant truncated by a death
            return
        if hit is not None:                   # nano-loop interruption (§4.1)
            rt.raise_stop(hit)
            rt.retire(wid)
            return
        if w.size() == 0:
            rt.retire(wid)
            return
        self.on_microloop_boundary(rt, wid, task)

    def on_microloop_boundary(self, rt: Runtime, wid: int, task: Task) -> None:
        rt.post_steal_requests()
        thief = rt.next_steal_request()
        if thief is not None and self._may_split(rt, task.work, wid, thief):
            rt.grant_steal(wid, thief, task, self.nano0)
            self._region_tasks += 1
        else:                                 # un-stolen micro-loop: grow
            task.nano = min(task.nano * 2, self.nano_cap)

    def preempt_grant(self, rt: Runtime, wid: int, task: Task,
                      grant: int) -> int:
        if self.preempt and grant > self.nano0 and rt.has_demand(wid):
            return self.nano0
        return grant

    def _may_split(self, rt: Runtime, w: Divisible, wid: int,
                   thief: int) -> bool:
        if w.size() <= 1:
            return False
        if isinstance(w, Adaptor):            # adaptor-composed adaptive
            ctx = StealContext(stolen=True, worker=wid,
                               demand=rt.idle_count())
            return w.should_divide(ctx)
        return True

    def on_region_end(self, rt: Runtime) -> float:
        red = max(0, self._region_tasks - 1)
        rt.stats["reductions"] += red
        return max(rt.time) + red * rt.cost.reduce_cost / max(rt.speeds)


# ---------------------------------------------------------------------------
# static partition (OpenMP-static / "rust static" baseline)
# ---------------------------------------------------------------------------

class StaticPartitionPolicy(SchedulingPolicy):
    """§4.3 baseline: pre-split into ``num_blocks`` equal chunks assigned
    round-robin; no stealing; all split cost paid up front."""

    name = "static"

    def __init__(self, num_blocks: Optional[int] = None):
        self.num_blocks = num_blocks

    def on_region_start(self, rt: Runtime, work: Divisible) -> None:
        nb = self.num_blocks or rt.p
        self._split_cost = sum(rt.cost.split_cost(work)
                               for _ in range(nb - 1))
        self._nb = nb
        rest = work
        chunks = []
        for i in range(nb - 1):
            sz = rest.size() // (nb - i)
            l, rest = rest.divide_at(sz)
            chunks.append(l)
        chunks.append(rest)
        rt.stats["divisions"] += nb - 1
        # round-robin over *alive* workers: with no fault plan this is the
        # identity assignment i % p (bit-identical to the pre-fault engine)
        targets = [i for i in range(rt.p) if rt.alive(i)]
        for i, ch in enumerate(chunks):
            t = targets[i % len(targets)]
            rt.push_task(t, Task(work=ch, creator=t))

    def select_worker(self, rt: Runtime) -> Optional[int]:
        cand = [i for i in range(rt.p)
                if rt.current[i] is not None or rt.deques[i]]
        if not cand:
            return None
        return min(cand, key=lambda i: rt.time[i])

    def quantum(self, rt: Runtime, wid: int) -> None:
        if rt.current[wid] is None:
            rt.current[wid] = rt.deques[wid].popleft()
            return
        rt.run_leaf(wid, rt.current[wid])

    def on_region_end(self, rt: Runtime) -> float:
        rt.stats["reductions"] += self._nb - 1
        return max(rt.time) + self._split_cost / max(rt.speeds)


# ---------------------------------------------------------------------------
# priority / deadline (multi-tenant SLO scheduling)
# ---------------------------------------------------------------------------

class PriorityPolicy(SchedulingPolicy):
    """Priority-ordered task selection over a shared relaxed k-priority pool.

    The pool holds whole submissions (a :class:`~repro.core.divisible.WorkSet`
    seeds one entry per part; any other divisible seeds a single entry),
    ordered by the :class:`~repro.core.adaptors.Tagged` metadata found in each
    part's adaptor stack — untagged work runs at priority 0.  An idle worker
    pops from the pool (charged one ``steal_latency``, the shared-structure
    access cost), eagerly divides the entry exactly like :class:`JoinPolicy`
    — right children re-enter the *pool* with the inherited tag, so high
    priority work spreads across workers — and runs the left leaf.

    ``k`` is the relaxation knob from "Data Structures for Task-based
    Priority Scheduling": a pop draws uniformly among the top ``k`` entries
    instead of the strict maximum, trading ordering fidelity for contention.
    ``k=1`` is strict and consumes **no** rng, so faultless strict runs are
    bit-identical regardless of relaxed runs interleaved on the same seed.

    Composes with ``by_blocks`` (each block's WorkSet slice becomes a fresh
    pool) and with the full adaptor stack (``cap``/``size_limit`` gate the
    eager division through the standard ``should_divide`` path).
    """

    name = "priority"

    def __init__(self, k: int = 1):
        if k < 1:
            raise ValueError(f"relaxation k must be >= 1, got {k}")
        self.k = k

    # -- pool ordering --------------------------------------------------------
    def order_key(self, w: Divisible) -> tuple:
        tag = find_tag(w)
        return (-(tag.priority if tag is not None else 0),)

    def expired(self, rt: Runtime, wid: int, w: Divisible) -> bool:
        """Deadline hook: priority scheduling never expires work."""
        return False

    def _push(self, w: Divisible) -> None:
        bisect.insort(self._pool, (self.order_key(w), self._seq, w))
        self._seq += 1

    def _pop_index(self, rt: Runtime) -> int:
        if self.k == 1 or len(self._pool) == 1:
            return 0          # strict: no rng consumed
        return rt.rng.randrange(min(self.k, len(self._pool)))

    # -- hooks ----------------------------------------------------------------
    def on_region_start(self, rt: Runtime, work: Divisible) -> None:
        self._pool: list = []
        self._seq = 0
        parts = work.parts if isinstance(work, WorkSet) else (work,)
        for part in parts:
            self._push(part)
        rt.outstanding = len(self._pool)

    def select_worker(self, rt: Runtime) -> Optional[int]:
        cand = [i for i in range(rt.p)
                if rt.current[i] is not None
                or (rt.alive(i) and self._pool)]
        if not cand:
            return None
        return min(cand, key=lambda i: rt.time[i])

    def quantum(self, rt: Runtime, wid: int) -> None:
        task = rt.current[wid]
        if task is None:
            while self._pool:
                _, _, w = self._pool.pop(self._pop_index(rt))
                rt.charge(wid, rt.cost.steal_latency)
                if self.expired(rt, wid, w):
                    rt.stats["expired"] += w.size()
                    rt.outstanding -= 1
                    if isinstance(w, Adaptor):
                        w.on_finish()
                    continue
                task = Task(work=w, creator=wid)
                break
            if task is None:
                return
            rt.current[wid] = task
        task = self.on_task_start(rt, wid, task)
        rt.run_leaf(wid, task)

    def on_task_start(self, rt: Runtime, wid: int, task: Task) -> Task:
        """Divide until the work declines; right children re-enter the shared
        pool with the inherited tag (division preserves the Tagged wrapper)."""
        ctx = StealContext(stolen=task.stolen, worker=wid,
                           demand=rt.idle_count())
        w = task.work
        while rt.wants_division(w, ctx):
            rt.charge(wid, rt.cost.split_cost(w))
            l, r = rt.divide(w, ctx)
            self._push(r)
            rt.outstanding += 1
            task = Task(work=l, creator=wid, stolen=False)
            w = task.work
            ctx = StealContext(stolen=False, worker=wid,
                               demand=rt.idle_count())
        return task


class DeadlinePolicy(PriorityPolicy):
    """Earliest-deadline-first with expiry: the pool orders by the Tagged
    absolute virtual-time ``deadline`` (untagged / undated work sorts last),
    and a pop whose deadline already passed on the popping worker's clock is
    *dropped and counted* (``SimResult.expired_items``), never run — late
    work wastes no capacity.  Conservation invariant (faultless, no early
    stop): ``items_processed + expired_items == items_total``.
    """

    name = "deadline"

    def order_key(self, w: Divisible) -> tuple:
        tag = find_tag(w)
        d = (tag.deadline if tag is not None and tag.deadline is not None
             else math.inf)
        return (d,)

    def expired(self, rt: Runtime, wid: int, w: Divisible) -> bool:
        tag = find_tag(w)
        return (tag is not None and tag.deadline is not None
                and rt.time[wid] > tag.deadline)


# ---------------------------------------------------------------------------
# by_blocks as a *dynamic* policy: sequential outer loop, any inner policy
# ---------------------------------------------------------------------------

class ByBlocksPolicy(SchedulingPolicy):
    """§3.5 dynamically: geometrically growing blocks run one after another,
    each as a parallel region under ``inner``; the interruption flag is
    checked between blocks, bounding wasted work by growth/(1+growth).

    This composes policies that previously lived in separate engines:
    ``ByBlocksPolicy(inner=AdaptivePolicy(), first=p)`` simulates an
    interruptible adaptive computation — impossible before the unification.
    """

    name = "by_blocks"

    def __init__(self, inner: SchedulingPolicy, first: int,
                 growth: float = 2.0, align: int = 1,
                 cap: Optional[int] = None,
                 wrap: Optional[Any] = None):
        self.inner = inner
        self.first = first
        self.growth = growth
        self.align = align
        self.cap = cap
        self.wrap = wrap       # per-block adaptor stack, e.g. thief_splitting
        self.blocks_run = 0

    def drive(self, rt: Runtime, work: Divisible) -> float:
        self.blocks_run = 0
        total = 0.0
        rest = work
        for (lo, hi) in geometric_blocks(work.size(), first=self.first,
                                         growth=self.growth,
                                         align=self.align, cap=self.cap):
            blk, rest = rest.divide_at(hi - lo)
            if self.wrap is not None:     # fresh adaptor state per block
                blk = self.wrap(blk)
            total += rt.run_region(blk, self.inner)
            self.blocks_run += 1
            if rt.stop_flag:
                break
        return total

    def on_join_complete(self, rt: Runtime, node: Any, wid: int) -> bool:
        return self.inner.on_join_complete(rt, node, wid)


# ---------------------------------------------------------------------------
# convenience face
# ---------------------------------------------------------------------------

def simulate(work: Divisible, policy: SchedulingPolicy, p: int,
             cost: Optional[CostModel] = None, *, seed: int = 0,
             speeds=None, stop_predicate=None, faults=None) -> SimResult:
    """One-call face: run ``work`` under ``policy`` on ``p`` virtual workers."""
    return Runtime(p, cost or CostModel(), policy, seed=seed, speeds=speeds,
                   stop_predicate=stop_predicate, faults=faults).run(work)


__all__ = [
    "SchedulingPolicy", "JoinPolicy", "DepJoinPolicy", "AdaptivePolicy",
    "StaticPartitionPolicy", "ByBlocksPolicy", "PriorityPolicy",
    "DeadlinePolicy", "simulate",
]
