"""Deterministic fault injection — failures as first-class discrete events.

The robustness claims this framework inherits from the paper (by_blocks
exists "for interruptible computations", adaptive recovers imbalance via
steal-linked splitting) are scheduling claims, so faults are modelled where
scheduling lives: as events in the unified virtual-time Runtime
(:mod:`repro.core.runtime`) and as injection points in the production wiring
(:mod:`repro.chaos`).  One :class:`FaultPlan` describes both layers:

* **virtual-time events**, consumed by the Runtime —
  :class:`WorkerDeath` (a worker stops at virtual time ``at``; its queued
  tasks and in-flight residual re-enter the steal pool, the partially
  executed grant is *lost*) and :class:`Slowdown` (a worker's speed is
  scaled by ``factor`` over ``[start, stop)``);
* **wall-clock / step-indexed events**, consumed by the chaos harness —
  :class:`CheckpointWriteFault` (the k-th checkpoint leaf/manifest write
  raises), :class:`CorruptionFault` (bytes of a saved leaf or the manifest
  are flipped), :class:`PreemptionFault` (SIGTERM delivered at train step
  k), :class:`HostDeath` (a host's devices vanish at step k — the mesh8
  kill-a-host scenario).

Determinism: a FaultPlan is pure data.  The Runtime consumes it with the
same seeded RNG discipline as victim selection, so (work, policy, p, cost,
seed, plan) → bit-identical :class:`~repro.core.runtime.SimResult`,
including death times, lost-item counts and recovery steals.
:meth:`FaultPlan.random` derives event times from its own
``random.Random(seed)`` stream so randomized chaos sweeps are replayable
from a single integer.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# virtual-time events (Runtime)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkerDeath:
    """Worker ``worker`` dies at virtual time ``at`` (absolute — measured
    from the start of :meth:`Runtime.run`, across by_blocks regions).

    Semantics (see chaos/DESIGN.md): the death takes effect at the worker's
    next event at or after ``at``; a leaf/grant in flight across ``at`` is
    truncated there — items executed before the cut are **lost** (their fold
    state died with the worker) and the task's full remaining extent
    re-enters the steal pool as an orphan."""

    worker: int
    at: float


@dataclasses.dataclass(frozen=True)
class Slowdown:
    """Worker ``worker`` runs at ``factor`` × its base speed over virtual
    time ``[start, stop)``.  Applied at event granularity: a grant charged
    entirely inside the window sees the factor; one spanning a boundary is
    charged at the speed in force when it started."""

    worker: int
    start: float
    stop: float
    factor: float


# ---------------------------------------------------------------------------
# step-indexed / IO events (chaos harness, train + serve layers)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CheckpointWriteFault:
    """The ``on_write``-th checkpoint write *attempt* (1-based, counted
    across the manager's lifetime) raises ``OSError`` — exercising the
    retry-with-backoff path in :class:`~repro.train.checkpoint.
    CheckpointManager`."""

    on_write: int


@dataclasses.dataclass(frozen=True)
class CorruptionFault:
    """Corrupt the saved checkpoint of ``step``: ``target="leaf"`` flips
    bytes of ``arr_<leaf_index>.npy``; ``target="manifest"`` truncates
    manifest.json.  Restore must fail loudly (per-leaf sha256)."""

    step: int
    target: str = "leaf"          # "leaf" | "manifest"
    leaf_index: int = 0


@dataclasses.dataclass(frozen=True)
class PreemptionFault:
    """Deliver SIGTERM to the training process at step ``at_step`` — the
    trainer's signal flag fires at the step boundary (the by_blocks
    interruption point) and the loop exits through a final checkpoint."""

    at_step: int


@dataclasses.dataclass(frozen=True)
class SlotDeath:
    """Decode slot ``slot`` of a :class:`~repro.serve.engine.ContinuousEngine`
    dies at engine step ``at_step`` — its lane state (tokens emitted so far,
    KV pages, length counters) is discarded and the in-flight request is
    requeued at the *front* of the waiting queue, to be re-served from
    scratch exactly once."""

    at_step: int
    slot: int


@dataclasses.dataclass(frozen=True)
class HostDeath:
    """Host ``host`` (a contiguous block of ``devices_per_host`` devices)
    dies at train step ``at_step`` — the in-flight step is lost, survivors
    re-mesh and resume from the last checkpoint."""

    host: int
    at_step: int
    devices_per_host: int = 4


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of faults for one run (both layers)."""

    seed: int = 0
    deaths: Tuple[WorkerDeath, ...] = ()
    slowdowns: Tuple[Slowdown, ...] = ()
    checkpoint_faults: Tuple[CheckpointWriteFault, ...] = ()
    corruptions: Tuple[CorruptionFault, ...] = ()
    preemptions: Tuple[PreemptionFault, ...] = ()
    host_deaths: Tuple[HostDeath, ...] = ()
    slot_deaths: Tuple[SlotDeath, ...] = ()

    # ---- Runtime-facing queries -------------------------------------------
    def death_time(self, worker: int) -> Optional[float]:
        """Earliest scheduled death of ``worker`` (None if it survives)."""
        times = [d.at for d in self.deaths if d.worker == worker]
        return min(times) if times else None

    def speed_factor(self, worker: int, t: float) -> float:
        """Product of slowdown factors in force for ``worker`` at time t."""
        f = 1.0
        for s in self.slowdowns:
            if s.worker == worker and s.start <= t < s.stop:
                f *= s.factor
        return f

    def has_runtime_events(self) -> bool:
        return bool(self.deaths or self.slowdowns)

    # ---- chaos-harness queries --------------------------------------------
    def checkpoint_write_fails(self, write_index: int) -> bool:
        return any(f.on_write == write_index for f in self.checkpoint_faults)

    def preempt_at(self, step: int) -> bool:
        return any(p.at_step == step for p in self.preemptions)

    def host_death_at(self, step: int) -> Optional[HostDeath]:
        for h in self.host_deaths:
            if h.at_step == step:
                return h
        return None

    def slot_deaths_at(self, step: int) -> Tuple[SlotDeath, ...]:
        return tuple(s for s in self.slot_deaths if s.at_step == step)

    # ---- constructors ------------------------------------------------------
    @classmethod
    def random(cls, seed: int, *, p: int, horizon: float,
               n_deaths: int = 1, n_slowdowns: int = 0,
               slow_factor: float = 0.5) -> "FaultPlan":
        """Seeded random plan: ``n_deaths`` distinct workers die at uniform
        times in (0.1, 0.9)·horizon; ``n_slowdowns`` further workers slow to
        ``slow_factor`` over a random sub-interval.  Same seed ⇒ same plan."""
        rng = random.Random(seed)
        victims = rng.sample(range(p), min(p - 1, n_deaths + n_slowdowns))
        deaths = tuple(
            WorkerDeath(w, rng.uniform(0.1, 0.9) * horizon)
            for w in victims[:n_deaths])
        slows = []
        for w in victims[n_deaths:]:
            a = rng.uniform(0.0, 0.5) * horizon
            b = a + rng.uniform(0.2, 0.5) * horizon
            slows.append(Slowdown(w, a, b, slow_factor))
        return cls(seed=seed, deaths=deaths, slowdowns=tuple(slows))


__all__ = [
    "FaultPlan", "WorkerDeath", "Slowdown", "CheckpointWriteFault",
    "CorruptionFault", "PreemptionFault", "HostDeath", "SlotDeath",
]
