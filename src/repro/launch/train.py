"""Sharded training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --steps 100 --global-batch 16 --seq-len 512 [--smoke]

On this host the full configs are dry-run-only; ``--smoke`` (default when
only one device is visible) swaps in the reduced same-family config so the
launcher is runnable end-to-end anywhere.  With real TPU devices the same
code path builds the production mesh, shards the state, and runs the
fault-tolerant Trainer loop.
"""

import argparse
import dataclasses

import jax

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.dist.sharding import batch_shardings, mesh_context
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import microbatch_plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--smoke", action="store_true", default=None)
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()

    n_dev = jax.device_count()
    smoke = args.smoke if args.smoke is not None else (n_dev < 256)
    cfg = get_smoke_config(args.arch) if smoke else get_config(args.arch)
    print(f"[launch.train] {cfg.name} ({cfg.param_count()/1e6:.1f}M params) "
          f"on {n_dev} device(s); smoke={smoke}")

    model = Model(cfg, max_decoder_positions=args.seq_len + 8)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(5, args.steps // 20),
                          decay_steps=args.steps,
                          moment_dtype=cfg.moment_dtype)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=0)

    mesh = None
    if n_dev >= 256:
        mesh = make_production_mesh(multi_pod=args.multipod)
    elif n_dev >= 4:
        mesh = make_host_mesh(2, 2)

    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1) if mesh else 1
    n_mb = microbatch_plan(args.global_batch, dp,
                           tokens_per_seq=args.seq_len)
    loop_cfg = LoopConfig(
        total_steps=args.steps, ckpt_every=max(10, args.steps // 4),
        ckpt_dir=args.ckpt_dir or f"checkpoints/{cfg.name}",
        log_every=max(1, args.steps // 10), num_microbatches=n_mb,
        num_replicas=dp)

    def run():
        trainer = Trainer(model, opt_cfg, data_cfg, loop_cfg)
        trainer.install_signal_handlers()
        trainer.run()

    if mesh is not None:
        with mesh_context(mesh):
            run()
    else:
        run()


if __name__ == "__main__":
    main()
