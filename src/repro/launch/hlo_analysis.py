"""Custom HLO cost analysis with while-loop trip-count handling.

``compiled.cost_analysis()`` does NOT multiply while-loop body costs by trip
count (verified empirically: a scan of 8 matmuls reports the FLOPs of one).
Every model here scans over layers and microbatches, so raw XLA numbers
undercount by ~L×.  This module walks the post-optimization HLO text,
builds a per-computation symbol table, computes

  * FLOPs        — dots: 2·|result|·|contracting dims|; elementwise/reduce:
                   1/element (noise next to matmuls, kept for honesty),
  * traffic bytes — Σ (operand + result bytes) over top-level (post-fusion)
                   ops: an upper-ish approximation of HBM traffic,
  * collective bytes — per kind, with transfer-volume conventions:
                   all-gather → result bytes; all-reduce → 2× operand;
                   reduce-scatter / all-to-all / collective-permute →
                   operand bytes,

multiplying everything inside a ``while`` by its ``known_trip_count``.

The HLO shapes are post-SPMD (per-device), so totals are per-chip.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# ops that move no real data
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota", "partition-id", "replica-id"}

# ops whose operand/result bytes count as HBM traffic in the TPU-expected
# model.  Bare elementwise chains are treated as fused (register/VMEM
# resident) and `convert`s as free — XLA:CPU materializes f32 copies of every
# bf16 dot operand, which a bf16-native MXU never does; counting those made
# the memory term ~100× pessimistic (see EXPERIMENTS.md §Roofline
# methodology).  The raw all-ops sum is still reported as `bytes_all_ops`.
_TRAFFIC_OPS = {"dot", "fusion", "reduce", "reduce-window", "scatter",
                "gather", "dynamic-slice", "dynamic-update-slice", "copy",
                "concatenate", "sort", "convolution", "rng", "pad",
                "select-and-scatter", "custom-call", "transpose"}


def shape_bytes_and_elems(shape_str: str) -> Tuple[int, int]:
    """Total bytes and element count for a (possibly tuple) shape string."""
    bytes_, elems = 0, 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return bytes_, elems


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str            # everything after the '(' of the op call


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0          # TPU-expected traffic (_TRAFFIC_OPS only)
    bytes_all: float = 0.0      # raw all-ops upper bound (diagnostic)
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_all += other.bytes_all * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + v * mult


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Op]] = {}
        self.shapes: Dict[Tuple[str, str], str] = {}  # (comp, op) -> shape
        self._parse(hlo_text)
        self._memo: Dict[str, CostTotals] = {}

    # ------------------------------------------------------------------ parse
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line or line.lstrip().startswith("//"):
                continue
            if not line.startswith(" ") and ("->" in line) and ("{" in line):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if not m:
                # computation parameters: "%p = f32[..] parameter(0)" matches
                continue
            name, shape, kind, rest = m.groups()
            self.comps[cur].append(Op(name, shape, kind, rest))
            self.shapes[(cur, name)] = shape

    # ------------------------------------------------------------- cost math
    def _dot_flops(self, comp: str, op: Op) -> float:
        out_dims = shape_dims(op.shape)
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        cm = _CONTRACT_RE.search(op.rest)
        contract = 1
        if cm:
            idxs = [int(i) for i in cm.group(1).split(",") if i]
            operands = _OPERAND_RE.findall(op.rest)
            lhs = operands[0] if operands else None
            lhs_shape = self.shapes.get((comp, lhs), "")
            dims = shape_dims(lhs_shape)
            for i in idxs:
                if i < len(dims):
                    contract *= dims[i]
        return 2.0 * out_elems * contract

    def _op_cost(self, comp: str, op: Op) -> CostTotals:
        t = CostTotals()
        res_bytes, res_elems = shape_bytes_and_elems(op.shape)
        # operand bytes: look up references (first paren group until attrs)
        operand_names = _OPERAND_RE.findall(op.rest)
        opnd_bytes = 0
        for on in operand_names[:8]:
            s = self.shapes.get((comp, on))
            if s:
                b, _ = shape_bytes_and_elems(s)
                opnd_bytes += b

        if op.kind in _FREE_OPS:
            return t

        if op.kind == "while":
            body = _BODY_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            trip = 1
            tm = _TRIP_RE.search(op.rest)
            if tm:
                trip = int(tm.group(1))
            if body:
                t.add(self.comp_cost(body.group(1)), trip)
            if cond:
                t.add(self.comp_cost(cond.group(1)), trip)
            return t

        if op.kind in ("fusion", "call", "async-start"):
            cm = _CALLS_RE.search(op.rest)
            if cm and cm.group(1) in self.comps:
                inner = self.comp_cost(cm.group(1))
                t.flops += inner.flops
                t.collective_bytes += inner.collective_bytes
                for k, v in inner.per_collective.items():
                    t.per_collective[k] = t.per_collective.get(k, 0) + v
            # windowed-operand cap: scan bodies receive full loop-stacked
            # buffers but touch one slice per step (dynamic-slice inside the
            # fusion).  Counting the full operand per iteration booked PBs of
            # phantom traffic (sLSTM: 864 TiB).  Cap each operand at
            # 8×result (or 1 MiB), keep the uncapped sum in bytes_all.
            capped = 0
            cap = max(8 * res_bytes, 1 << 20)
            for on in operand_names[:8]:
                sh = self.shapes.get((comp, on))
                if sh:
                    b, _ = shape_bytes_and_elems(sh)
                    capped += min(b, cap)
            t.bytes += res_bytes + capped
            t.bytes_all += res_bytes + opnd_bytes
            return t

        if op.kind == "conditional":
            # count the max-cost branch
            branches = [self.comp_cost(c) for c in
                        re.findall(r"branch_computations=\{([^}]*)\}",
                                   op.rest)
                        for c in re.findall(r"%?([\w\.\-]+)", c)]
            if branches:
                best = max(branches, key=lambda c: c.flops)
                t.add(best)
            t.bytes += res_bytes + opnd_bytes
            return t

        if op.kind in COLLECTIVE_KINDS or any(
                op.kind.startswith(k) for k in COLLECTIVE_KINDS):
            kind = next(k for k in COLLECTIVE_KINDS if op.kind.startswith(k))
            if kind == "all-gather":
                vol = res_bytes
            elif kind == "all-reduce":
                vol = 2 * opnd_bytes
            else:
                vol = opnd_bytes
            t.collective_bytes += vol
            t.per_collective[kind] = t.per_collective.get(kind, 0.0) + vol
            t.collective_count[kind] = t.collective_count.get(kind, 0) + 1
            t.bytes += res_bytes + opnd_bytes
            t.bytes_all += res_bytes + opnd_bytes
            return t

        if op.kind == "dot":
            t.flops += self._dot_flops(comp, op)
            t.bytes += res_bytes + opnd_bytes
            t.bytes_all += res_bytes + opnd_bytes
            return t

        if op.kind in ("convolution",):
            # rare here (convs are hand-unrolled); approximate via result ×
            # kernel elems — parse rhs operand
            rhs = operand_names[1] if len(operand_names) > 1 else None
            k_elems = 1
            if rhs:
                _, k_elems = shape_bytes_and_elems(
                    self.shapes.get((comp, rhs), ""))
            t.flops += 2.0 * res_elems * max(1, k_elems // max(1, res_elems))
            t.bytes += res_bytes + opnd_bytes
            t.bytes_all += res_bytes + opnd_bytes
            return t

        if op.kind in ("custom-call",):
            t.bytes += res_bytes + opnd_bytes
            t.bytes_all += res_bytes + opnd_bytes
            # oneDNN matmul custom-calls carry no dnums; approximate via
            # operands: flops ≈ 2 * sqrt(|lhs|*|rhs|*|out|) — not observed on
            # this backend for our models (dots stay dots), kept as fallback.
            return t

        # window ops: traffic is the window, not the whole buffer — a scan
        # dynamic-slicing a big stacked tensor reads one slice per step, and
        # in-place DUS writes only the update window (donated buffers).
        if op.kind == "dynamic-slice":
            t.bytes += 2 * res_bytes
            t.bytes_all += 2 * res_bytes
            return t
        if op.kind == "dynamic-update-slice":
            upd = operand_names[1] if len(operand_names) > 1 else None
            ub = shape_bytes_and_elems(self.shapes.get((comp, upd), ""))[0]                 if upd else res_bytes
            t.bytes += 2 * ub
            t.bytes_all += 2 * ub
            return t
        if op.kind == "gather":
            t.bytes += 2 * res_bytes
            t.bytes_all += 2 * res_bytes
            return t

        # elementwise / reduce / scatter / everything else
        if op.kind != "convert":
            t.flops += float(res_elems)
        t.bytes_all += res_bytes + opnd_bytes
        if op.kind in _TRAFFIC_OPS:
            t.bytes += res_bytes + opnd_bytes
        return t

    def comp_cost(self, comp: str) -> CostTotals:
        if comp in self._memo:
            return self._memo[comp]
        total = CostTotals()
        self._memo[comp] = total  # break cycles defensively
        for op in self.comps.get(comp, []):
            total.add(self._op_cost(comp, op))
        return total

    def entry_cost(self) -> CostTotals:
        entry = None
        for name in self.comps:
            if name.startswith("main") or ".main" in name:
                entry = name
        if entry is None:
            entry = list(self.comps)[-1]
        return self.comp_cost(entry)


def analyze_hlo(hlo_text: str) -> Dict[str, object]:
    an = HloAnalysis(hlo_text)
    c = an.entry_cost()
    return {
        "flops_per_chip": c.flops,
        "traffic_bytes_per_chip": c.bytes,
        "bytes_all_ops_per_chip": c.bytes_all,
        "collective_bytes_per_chip": c.collective_bytes,
        "per_collective_bytes": c.per_collective,
        "collective_counts": c.collective_count,
    }


__all__ = ["HloAnalysis", "analyze_hlo", "CostTotals"]
