"""repro.launch"""
