"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  Shapes fixed by the assignment:

  single-pod : (data=16, model=16)            = 256 chips (one v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips

``make_host_mesh`` builds reduced same-topology meshes for CPU tests.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) != n:   # dry-run: 512 forced host devices, use first n
        import numpy as np
        from jax.sharding import Mesh
        return Mesh(np.array(devices[:n]).reshape(shape), axes)
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over host (CPU) devices for tests; same axis names."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


__all__ = ["make_production_mesh", "make_host_mesh"]
