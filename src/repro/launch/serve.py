"""Serving launcher: batched requests through the Kvik-policy engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --requests 8 --max-new 32 [--smoke]

Chunked (by_blocks) prefill + find_first early-exit decode; per-request
wasted-work stats are printed — the serving realization of the paper's
interruptible-computation claims.
"""

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import Model
from repro.serve.engine import Engine, EngineConfig, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--eos-id", type=int, default=7)
    ap.add_argument("--smoke", action="store_true", default=None)
    args = ap.parse_args()

    smoke = args.smoke if args.smoke is not None else \
        (jax.device_count() < 256)
    cfg = get_smoke_config(args.arch) if smoke else get_config(args.arch)
    if cfg.is_encdec or cfg.family == "vlm":
        raise SystemExit(f"{args.arch}: use a text-only arch for this demo "
                         f"(modality stubs need explicit inputs)")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[launch.serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    engine = Engine(model, params,
                    EngineConfig(max_batch=args.max_batch,
                                 eos_id=args.eos_id))
    rng = np.random.RandomState(0)
    for rid in range(args.requests):
        plen = int(rng.randint(8, 48))
        engine.submit(Request(
            rid=rid, prompt=rng.randint(3, cfg.vocab_size,
                                        plen).astype(np.int32),
            max_new=args.max_new))
    served = 0
    while True:
        batch = engine.step()
        if not batch:
            break
        for r in batch:
            served += 1
            print(f"[launch.serve] req {r.rid}: {len(r.result)} tokens, "
                  f"decode-blocks={r.stats.blocks}, "
                  f"wasted={r.stats.wasted_fraction:.1%}")
    print(f"[launch.serve] served {served}/{args.requests}")


if __name__ == "__main__":
    main()
