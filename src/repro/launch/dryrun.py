import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

For each cell this lowers the real step function (train_step / prefill_step /
serve_step) against ShapeDtypeStruct inputs on the production mesh, compiles
it, and records:

  * memory_analysis()      — per-device bytes: proves the cell fits,
  * cost_analysis()        — XLA's own counters (kept for reference),
  * custom HLO analysis    — trip-count-aware FLOPs / traffic / collective
                             bytes per chip (launch/hlo_analysis.py),

writing one JSON per cell into --out (incremental: finished cells are skipped
on rerun with --skip-existing).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all --skip-existing --out results/dryrun
  python -m repro.launch.dryrun --all --multipod
"""

import argparse
import gc
import json
import time
import traceback
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.specs import cross_len, decoder_len, input_specs
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 mesh_context, params_shardings)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.train.step import (abstract_train_state, make_prefill_step,
                              make_serve_step, make_train_step,
                              microbatch_plan, train_state_shardings)


def memory_report(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        out[attr] = int(getattr(ma, attr, 0) or 0)
    out["peak_bytes_per_device"] = (out["argument_size_in_bytes"]
                                    + out["output_size_in_bytes"]
                                    + out["temp_size_in_bytes"]
                                    - out["alias_size_in_bytes"])
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               moe_strategy: str = "einsum",
               mb_tokens: Optional[int] = None):
    """Build + lower + compile one cell; returns (record, compiled)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    runnable, reason = shape_applicable(cfg, shape)
    if not runnable:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    dec_positions = shape.seq_len + 8 if cfg.is_encdec else 0
    model = Model(cfg, moe_strategy=moe_strategy,
                  max_decoder_positions=dec_positions)
    specs = input_specs(cfg, shape, model)
    t0 = time.time()

    with mesh_context(mesh) as ctx:
        if shape.kind == "train":
            opt_cfg = AdamWConfig(moment_dtype=cfg.moment_dtype)
            if mb_tokens is None:
                # 398B: half the default activation budget (hillclimb B)
                mb_tokens = 4096 if cfg.name.startswith("jamba") else 8192
            n_mb = microbatch_plan(shape.global_batch, ctx.dp,
                                   tokens_per_seq=decoder_len(cfg, shape),
                                   target_tokens_per_replica=mb_tokens)
            step = make_train_step(model, opt_cfg, num_microbatches=n_mb,
                                   accum_dtype=cfg.moment_dtype)
            astate = abstract_train_state(model, opt_cfg)
            sshard = train_state_shardings(cfg, model, opt_cfg, mesh)
            bshard = batch_shardings(mesh, specs)
            lowered = jax.jit(step, in_shardings=(sshard, bshard),
                              donate_argnums=0).lower(astate, specs)
            extra = {"num_microbatches": n_mb}
        elif shape.kind == "prefill":
            aparams = model.abstract_params()
            pshard = params_shardings(cfg, aparams, mesh)
            bshard = batch_shardings(mesh, specs)
            stepf = make_prefill_step(model)
            lowered = jax.jit(stepf, in_shardings=(pshard, bshard)).lower(
                aparams, specs)
            extra = {}
        else:  # decode
            aparams = model.abstract_params()
            pshard = params_shardings(cfg, aparams, mesh)
            cshard = cache_shardings(cfg, mesh, specs["cache"],
                                     shape.global_batch)
            tshard = batch_shardings(
                mesh, {"t": specs["tokens"]})["t"]
            stepf = make_serve_step(model)
            lowered = jax.jit(
                stepf, in_shardings=(pshard, tshard, cshard, tshard),
                donate_argnums=2,
            ).lower(aparams, specs["tokens"], specs["cache"],
                    specs["lengths"])
            extra = {}

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    custom = analyze_hlo(hlo)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # per-computation list on some jax
        ca = ca[0] if ca else {}
    # persist compressed HLO so the analyzer can be iterated w/o recompiles
    try:
        import zstandard as zstd
        hdir = Path("results/hlo")
        hdir.mkdir(parents=True, exist_ok=True)
        tag = (f"{arch}__{shape_name}__"
               f"{'mp' if multi_pod else 'sp'}.hlo.zst")
        (hdir / tag).write_bytes(
            zstd.ZstdCompressor(level=6).compress(hlo.encode()))
    except Exception:
        pass
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "status": "ok",
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": memory_report(compiled),
        "xla_cost": {k: float(ca[k]) for k in ("flops", "bytes accessed")
                     if k in ca},
        "hlo": custom,
        "hlo_chars": len(hlo),
        **extra,
    }
    return record, compiled


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--moe-strategy", default="einsum")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multipod]

    failures = []
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}__{shape_name}__{'mp' if multi_pod else 'sp'}"
                path = out / f"{tag}.json"
                if args.skip_existing and path.exists():
                    print(f"[dryrun] {tag}: exists, skipping")
                    continue
                print(f"[dryrun] {tag}: lowering...", flush=True)
                t0 = time.time()
                try:
                    record, compiled = lower_cell(
                        arch, shape_name, multi_pod,
                        moe_strategy=args.moe_strategy)
                    if compiled is not None:
                        ma = record["memory"]
                        print(f"[dryrun] {tag}: OK "
                              f"({time.time()-t0:.0f}s, "
                              f"{ma['peak_bytes_per_device']/2**30:.2f} "
                              f"GiB/dev)", flush=True)
                        del compiled
                    else:
                        print(f"[dryrun] {tag}: SKIP ({record['reason']})",
                              flush=True)
                except Exception as e:  # noqa
                    record = {"arch": arch, "shape": shape_name,
                              "mesh": "2x16x16" if multi_pod else "16x16",
                              "status": "error", "error": str(e)[:2000],
                              "traceback": traceback.format_exc()[-4000:]}
                    failures.append(tag)
                    print(f"[dryrun] {tag}: ERROR {str(e)[:200]}", flush=True)
                path.write_text(json.dumps(record, indent=1))
                gc.collect()

    print(f"[dryrun] done; {len(failures)} failures: {failures}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
