"""repro.serve"""
