"""repro.serve — serving engines built from Kvik scheduling policies.

See DESIGN.md in this directory for the continuous-batching architecture.
"""

from .early_exit import (DecodeStats, decode_until_eos, make_decode_block,
                         make_decode_tick)
from .engine import (AdmissionSimulator, ContinuousEngine, Engine,
                     EngineConfig, EngineTelemetry, Request)
from .kvcache import PageTable, alloc_cache, cache_bytes, cache_slot_insert
from .prefill import ChunkedPrefill, PrefillStats

__all__ = [
    "AdmissionSimulator", "ChunkedPrefill", "ContinuousEngine",
    "DecodeStats", "Engine", "EngineConfig", "EngineTelemetry", "PageTable",
    "PrefillStats", "Request", "alloc_cache", "cache_bytes",
    "cache_slot_insert", "decode_until_eos", "make_decode_block",
    "make_decode_tick",
]
