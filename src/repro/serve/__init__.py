"""repro.serve — serving engines built from Kvik scheduling policies.

See DESIGN.md in this directory for the continuous-batching architecture
and the SLO-class / shedding / hot-swap invariants.
"""

from .early_exit import (DecodeStats, decode_until_eos, make_decode_block,
                         make_decode_tick)
from .engine import (AdmissionSimulator, ContinuousEngine, Engine,
                     EngineConfig, EngineTelemetry, QueueFull, Request)
from .kvcache import PageTable, alloc_cache, cache_bytes, cache_slot_insert
from .prefill import ChunkedPrefill, PrefillStats
from .slo import (CLASS_RANK, SLO_CLASSES, DeadlineServePolicy,
                  FifoServePolicy, PriorityServePolicy, ServePolicy,
                  request_deadline)

__all__ = [
    "AdmissionSimulator", "ChunkedPrefill", "ContinuousEngine",
    "DecodeStats", "Engine", "EngineConfig", "EngineTelemetry", "PageTable",
    "PrefillStats", "QueueFull", "Request", "alloc_cache", "cache_bytes",
    "cache_slot_insert", "decode_until_eos", "make_decode_block",
    "make_decode_tick",
    "SLO_CLASSES", "CLASS_RANK", "request_deadline", "ServePolicy",
    "FifoServePolicy", "PriorityServePolicy", "DeadlineServePolicy",
]
