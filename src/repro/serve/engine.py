"""A batched serving engine composed from Kvik policies.

* admission: the ``cap`` adaptor bounds live requests (batch slots);
* prefill: ``ChunkedPrefill`` (by_blocks, interruptible);
* decode: ``decode_until_eos`` (find_first early exit);
* batching: requests of compatible length prefill together (divide_at cuts
  the queue — the same Divisible machinery end to end).

Synchronous reference implementation: real deployments would pipeline these
phases; the policy layer is the part this paper contributes, and it is
identical either way.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import Cap, WorkRange, cap
from ..models.model import Model
from .early_exit import DecodeStats, decode_until_eos
from .prefill import ChunkedPrefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 64
    result: Optional[np.ndarray] = None
    stats: Optional[DecodeStats] = None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    eos_id: int = 2
    pad_id: int = 0
    max_seq: int = 512


class Engine:
    def __init__(self, model: Model, params: Any, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.prefiller = ChunkedPrefill(model, first_block=32, align=32,
                                        max_block=256)
        self.queue: List[Request] = []
        self.admission = cap(WorkRange(0, 1 << 30), cfg.max_batch)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _next_batch(self) -> List[Request]:
        take = min(len(self.queue), self.cfg.max_batch)
        batch, self.queue = self.queue[:take], self.queue[take:]
        return batch

    def step(self) -> List[Request]:
        """Serve one admitted batch to completion; returns finished reqs."""
        batch = self._next_batch()
        if not batch:
            return []
        B = len(batch)
        S = max(len(r.prompt) for r in batch)
        S = max(32, 1 << (S - 1).bit_length())
        toks = np.full((B, S), self.cfg.pad_id, np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt     # left-aligned prompts
        max_new = max(r.max_new for r in batch)
        cache = self.model.init_cache(B, S + max_new)
        logits, cache, pstats = self.prefiller.run(
            self.params, jnp.asarray(toks), cache)
        lengths = jnp.asarray([S] * B, jnp.int32)
        first = jnp.argmax(
            logits[:, :self.model.cfg.vocab_size], -1).astype(jnp.int32)
        gen, cache, dstats = decode_until_eos(
            self.model, self.params, first, cache, lengths,
            eos_id=self.cfg.eos_id, max_new=max_new)
        gen_np = np.asarray(gen)
        for i, r in enumerate(batch):
            row = gen_np[i]
            row = row[row >= 0][:r.max_new]
            r.result = np.concatenate([np.asarray(first)[i:i + 1], row])
            r.stats = dstats
        return batch


__all__ = ["Engine", "EngineConfig", "Request"]
