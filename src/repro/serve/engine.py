"""A batched serving engine composed from Kvik policies.

* admission: the ``cap`` adaptor bounds live requests (batch slots); with
  ``EngineConfig.admission="simulate"`` the batch size is chosen by running
  candidate admissions on the unified virtual-time runtime
  (:class:`AdmissionSimulator`) — the same engine that validates the
  schedulers — trading padding waste against per-batch overhead;
* prefill: ``ChunkedPrefill`` (by_blocks, interruptible);
* decode: ``decode_until_eos`` (find_first early exit);
* batching: requests of compatible length prefill together (divide_at cuts
  the queue — the same Divisible machinery end to end).

Synchronous reference implementation: real deployments would pipeline these
phases; the policy layer is the part this paper contributes, and it is
identical either way.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (Cap, CostModel, Runtime, StaticPartitionPolicy,
                    WorkRange, cap)
from ..models.model import Model
from .early_exit import DecodeStats, decode_until_eos
from .prefill import ChunkedPrefill


@dataclasses.dataclass
class AdmissionSimulator:
    """Pick how many queued requests to admit by simulating the batch.

    Admitting ``k`` requests pads them to their max length ``S_k``; the
    padded batch is ``k × S_k`` token-items executed as a static partition
    (one chunk per request — SPMD lanes don't steal) over ``lanes`` virtual
    workers, plus a fixed per-batch ``batch_overhead`` (dispatch, cache
    init, compile-shape reuse).  Useful work is the sum of *true* prompt
    lengths.  The admitted k maximizes useful-tokens/virtual-second — small
    k wastes the overhead, large k wastes padding; the simulator finds the
    knee.  Deterministic: no RNG is consumed by the static policy.
    """

    lanes: int = 4
    per_token: float = 1.0
    batch_overhead: float = 256.0

    def choose(self, lengths: Sequence[int], max_batch: int) -> int:
        best_k, best_rate = 1, -1.0
        cost = CostModel(per_item=self.per_token, split_overhead=0.0)
        for k in range(1, min(len(lengths), max_batch) + 1):
            smax = max(lengths[:k])
            res = Runtime(self.lanes, cost,
                          StaticPartitionPolicy(num_blocks=k)).run(
                WorkRange(0, k * smax))
            useful = float(sum(lengths[:k]))
            rate = useful / (res.makespan + self.batch_overhead)
            if rate > best_rate:
                best_k, best_rate = k, rate
        return best_k


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 64
    result: Optional[np.ndarray] = None
    stats: Optional[DecodeStats] = None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    eos_id: int = 2
    pad_id: int = 0
    max_seq: int = 512
    admission: str = "cap"        # "cap" (FIFO up to max_batch) | "simulate"
    # preemption budget: max prefill blocks one step() may spend on a batch;
    # a straggling (long-prompt) prefill is preempted at the next by_blocks
    # boundary and its residual requeued — None disables preemption
    prefill_block_budget: Optional[int] = None


@dataclasses.dataclass
class _PrefillResidual:
    """A preempted prefill: everything needed to resume at ``pos``.  The
    cache already holds positions < pos, so the residual is exactly the
    unprocessed suffix — the overshoot beyond the preemption point is the
    one block that was in flight, bounded by growth/(1+growth)."""

    batch: List[Request]
    toks: jnp.ndarray
    cache: Any
    pos: int
    max_new: int


class Engine:
    def __init__(self, model: Model, params: Any, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.prefiller = ChunkedPrefill(model, first_block=32, align=32,
                                        max_block=256)
        self.queue: List[Request] = []
        self.admission = cap(WorkRange(0, 1 << 30), cfg.max_batch)
        self.admission_sim = AdmissionSimulator(lanes=cfg.max_batch)
        self._residual: Optional[_PrefillResidual] = None

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _next_batch(self) -> List[Request]:
        if not self.queue:
            return []
        if self.cfg.admission == "simulate":
            take = self.admission_sim.choose(
                [len(r.prompt) for r in self.queue], self.cfg.max_batch)
        else:
            take = min(len(self.queue), self.cfg.max_batch)
        batch, self.queue = self.queue[:take], self.queue[take:]
        return batch

    def step(self) -> List[Request]:
        """Serve one unit of work; returns finished reqs (possibly []).

        A preempted prefill residual has priority over new admissions: the
        batch that was preempted resumes at its stashed position before any
        new batch starts — each step() spends at most
        ``prefill_block_budget`` prefill blocks, so no single long prompt
        can monopolize the engine."""
        if self._residual is not None:
            r, self._residual = self._residual, None
            return self._prefill_and_decode(r.batch, r.toks, r.cache,
                                            r.max_new, start=r.pos)
        batch = self._next_batch()
        if not batch:
            return []
        B = len(batch)
        S = max(len(r.prompt) for r in batch)
        S = max(32, 1 << (S - 1).bit_length())
        toks = np.full((B, S), self.cfg.pad_id, np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt     # left-aligned prompts
        max_new = max(r.max_new for r in batch)
        cache = self.model.init_cache(B, S + max_new)
        return self._prefill_and_decode(batch, jnp.asarray(toks), cache,
                                        max_new, start=0)

    def _prefill_and_decode(self, batch: List[Request], toks: jnp.ndarray,
                            cache: Any, max_new: int, *, start: int
                            ) -> List[Request]:
        B, S = toks.shape
        logits, cache, pstats = self.prefiller.run(
            self.params, toks, cache, start=start,
            max_blocks=self.cfg.prefill_block_budget)
        if pstats.preempted:      # requeue the bounded residual, yield
            self._residual = _PrefillResidual(
                batch=batch, toks=toks, cache=cache,
                pos=pstats.next_start, max_new=max_new)
            return []
        lengths = jnp.asarray([S] * B, jnp.int32)
        first = jnp.argmax(
            logits[:, :self.model.cfg.vocab_size], -1).astype(jnp.int32)
        gen, cache, dstats = decode_until_eos(
            self.model, self.params, first, cache, lengths,
            eos_id=self.cfg.eos_id, max_new=max_new)
        gen_np = np.asarray(gen)
        for i, r in enumerate(batch):
            row = gen_np[i]
            row = row[row >= 0][:r.max_new]
            r.result = np.concatenate([np.asarray(first)[i:i + 1], row])
            r.stats = dstats
        return batch


__all__ = ["Engine", "EngineConfig", "Request", "AdmissionSimulator"]
