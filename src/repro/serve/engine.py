"""Serving engines composed from Kvik policies.

Two engines share the policy stack:

* :class:`Engine` — the synchronous reference: admit a batch, prefill it
  (by_blocks, interruptible), decode it to EOS (find_first early exit),
  return.  Simple, and the baseline the benchmark measures against.
* :class:`ContinuousEngine` — the continuous-batching hot loop: a persistent
  decode batch with per-slot state (true per-request lengths, per-request
  ``max_new``, per-slot EOS retirement).  Freed slots are backfilled by
  admitting queued prompts whose chunked prefill is interleaved *between*
  decode ticks via the by_blocks preemption point — decode never waits on a
  straggling prefill.  Admission is the ``cap`` adaptor driven by live
  telemetry (measured decode cost, page headroom, queue depth) instead of
  the virtual-time simulator, and the :class:`~repro.serve.kvcache.PageTable`
  actually accounts cache pages per request.

Both engines handle mixed-length batches correctly: prefill gathers each
row's last *real* logit (not the last padded position) and decode runs with
true per-row lengths.
"""

from __future__ import annotations

import dataclasses
import math
import signal
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (Cap, CostModel, Runtime, StaticPartitionPolicy,
                    WorkRange, cap)
from ..models.model import Model
from .early_exit import (DecodeStats, decode_until_eos, make_decode_block,
                         make_decode_tick, make_gated_decode_tick)
from .kvcache import PageTable, cache_slot_insert
from .prefill import ChunkedPrefill
from .slo import SLO_CLASSES, FifoServePolicy, ServePolicy


class QueueFull(RuntimeError):
    """submit() refused: the waiting queue is at ``EngineConfig.max_queue``.
    Loud by design — under sustained overload the caller must shed or
    back off; silent unbounded queue growth is the failure mode."""


@dataclasses.dataclass
class AdmissionSimulator:
    """Pick how many queued requests to admit by simulating the batch.

    Admitting ``k`` requests pads them to their max length ``S_k``; the
    padded batch is ``k × S_k`` token-items executed as a static partition
    (one chunk per request — SPMD lanes don't steal) over ``lanes`` virtual
    workers, plus a fixed per-batch ``batch_overhead`` (dispatch, cache
    init, compile-shape reuse).  Useful work is the sum of *true* prompt
    lengths.  The admitted k maximizes useful-tokens/virtual-second — small
    k wastes the overhead, large k wastes padding; the simulator finds the
    knee.  Deterministic: no RNG is consumed by the static policy.
    """

    lanes: int = 4
    per_token: float = 1.0
    batch_overhead: float = 256.0

    def choose(self, lengths: Sequence[int], max_batch: int) -> int:
        best_k, best_rate = 1, -1.0
        cost = CostModel(per_item=self.per_token, split_overhead=0.0)
        for k in range(1, min(len(lengths), max_batch) + 1):
            smax = max(lengths[:k])
            res = Runtime(self.lanes, cost,
                          StaticPartitionPolicy(num_blocks=k)).run(
                WorkRange(0, k * smax))
            useful = float(sum(lengths[:k]))
            rate = useful / (res.makespan + self.batch_overhead)
            if rate > best_rate:
                best_k, best_rate = k, rate
        return best_k


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 64
    # SLO metadata (the serving analogue of the core Tagged adaptor)
    slo: str = "batch"            # "interactive" | "batch" | "background"
    priority: int = 0             # within-class: higher = more urgent
    deadline_s: Optional[float] = None   # relative to t_submit; None = never
    tenant: str = "default"
    result: Optional[np.ndarray] = None
    stats: Optional[DecodeStats] = None
    shed: bool = False            # dropped past its deadline, never served
    requeues: int = 0             # times re-served from scratch (slot death)
    # wall-clock latency markers (set by the engines)
    t_submit: Optional[float] = None
    t_first: Optional[float] = None   # first token available
    t_done: Optional[float] = None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    eos_id: int = 2
    pad_id: int = 0
    max_seq: int = 512
    admission: str = "cap"        # "cap" (FIFO up to max_batch) | "simulate"
    # preemption budget: max prefill blocks one step() may spend on a batch;
    # a straggling (long-prompt) prefill is preempted at the next by_blocks
    # boundary and its residual requeued — None disables preemption
    prefill_block_budget: Optional[int] = None
    # continuous engine: decode steps per tick, cache page accounting
    decode_tick: int = 8
    page_size: int = 32
    num_pages: Optional[int] = None   # None → full capacity
    # overload bounds: waiting-queue depth (None = unbounded, legacy) and
    # per-SLO-class concurrency caps, e.g. {"batch": 2} (absent = uncapped)
    max_queue: Optional[int] = None
    class_caps: Optional[Dict[str, int]] = None
    # uncertainty-gated early exit (continuous engine): a lane whose
    # predictive entropy stays below ``exit_entropy`` nats for
    # ``exit_patience`` consecutive steps retires early and its slot
    # backfills.  None disables gating (the exact decode tick).
    exit_entropy: Optional[float] = None
    exit_patience: int = 2

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.prefill_block_budget is not None \
                and self.prefill_block_budget < 1:
            raise ValueError("prefill_block_budget must be >= 1 when set, "
                             f"got {self.prefill_block_budget}")
        if self.decode_tick < 1:
            raise ValueError(
                f"decode_tick must be >= 1, got {self.decode_tick}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {self.max_seq}")
        if self.max_queue is not None and self.max_queue < self.max_batch:
            raise ValueError(
                f"max_queue ({self.max_queue}) must be >= max_batch "
                f"({self.max_batch}): a full batch must be admittable")
        for c, n in (self.class_caps or {}).items():
            if c not in SLO_CLASSES:
                raise ValueError(f"unknown SLO class {c!r} in class_caps; "
                                 f"expected one of {SLO_CLASSES}")
            if n < 1:
                raise ValueError(f"class_caps[{c!r}] must be >= 1, got {n}")
        if self.exit_entropy is not None and self.exit_entropy <= 0:
            raise ValueError(
                f"exit_entropy must be > 0 nats, got {self.exit_entropy}")
        if self.exit_patience < 1:
            raise ValueError(
                f"exit_patience must be >= 1, got {self.exit_patience}")


@dataclasses.dataclass
class EngineTelemetry:
    """Live measurements the admission cap consults (EWMA-smoothed)."""

    decode_s_per_token: float = 0.0
    prefill_s_per_block: float = 0.0
    prefill_s_per_token: float = 0.0
    pages_per_request: float = 0.0
    ticks: int = 0
    decode_steps: int = 0
    useful_decoded: int = 0
    admissions: int = 0
    prefill_preemptions: int = 0
    deferred_pages: int = 0       # admissions deferred on page exhaustion
    retired: int = 0
    cap_divides: int = 0
    cap_finishes: int = 0
    cap_live_peak: int = 0
    # SLO / overload accounting
    queue_rejections: int = 0     # submit() refused at max_queue
    shed: int = 0                 # queue entries dropped past their deadline
    shed_by_tenant: Dict[str, int] = dataclasses.field(default_factory=dict)
    shed_by_class: Dict[str, int] = dataclasses.field(default_factory=dict)
    class_preemptions: int = 0    # batch prefill parked for interactive work
    policy_swaps: int = 0         # live set_policy() calls
    slot_deaths: int = 0          # decode lanes killed (chaos) and requeued
    early_exits: int = 0          # lanes retired by the entropy gate
    ewma: float = 0.25
    # EWMA fields already seeded by a first observation.  A plain
    # ``old == 0.0`` sentinel misreads a genuine ~0.0 first sample and,
    # worse, mixes every *first* observation with the zero init when the
    # default changes — the cold-start skew the admission limit inherited.
    _seeded: Set[str] = dataclasses.field(default_factory=set, repr=False)

    def _mix(self, field: str, new: float) -> float:
        if field not in self._seeded:
            self._seeded.add(field)
            return new            # first observation seeds the EWMA directly
        old = getattr(self, field)
        return (1 - self.ewma) * old + self.ewma * new

    def observe_decode(self, useful: int, seconds: float, steps: int) -> None:
        self.ticks += 1
        self.decode_steps += steps
        self.useful_decoded += useful
        self.decode_s_per_token = self._mix("decode_s_per_token",
                                            seconds / max(1, useful))

    def observe_prefill(self, blocks: int, tokens: int,
                        seconds: float) -> None:
        if blocks:
            self.prefill_s_per_block = self._mix("prefill_s_per_block",
                                                 seconds / blocks)
        if tokens:
            self.prefill_s_per_token = self._mix("prefill_s_per_token",
                                                 seconds / tokens)

    def observe_admission(self, pages: int) -> None:
        self.admissions += 1
        self.pages_per_request = self._mix("pages_per_request", float(pages))

    def observe_shed(self, req: "Request") -> None:
        self.shed += 1
        self.shed_by_tenant[req.tenant] = \
            self.shed_by_tenant.get(req.tenant, 0) + 1
        self.shed_by_class[req.slo] = self.shed_by_class.get(req.slo, 0) + 1

    def on_cap_event(self, kind: str, live: int) -> None:
        if kind == "divide":
            self.cap_divides += 1
        else:
            self.cap_finishes += 1
        self.cap_live_peak = max(self.cap_live_peak, live)

    def snapshot(self) -> Dict[str, float]:
        return {
            "decode_s_per_token": self.decode_s_per_token,
            "prefill_s_per_block": self.prefill_s_per_block,
            "prefill_s_per_token": self.prefill_s_per_token,
            "pages_per_request": self.pages_per_request,
            "ticks": self.ticks,
            "decode_steps": self.decode_steps,
            "useful_decoded": self.useful_decoded,
            "admissions": self.admissions,
            "prefill_preemptions": self.prefill_preemptions,
            "deferred_pages": self.deferred_pages,
            "retired": self.retired,
            "cap_divides": self.cap_divides,
            "cap_finishes": self.cap_finishes,
            "cap_live_peak": self.cap_live_peak,
            "queue_rejections": self.queue_rejections,
            "shed": self.shed,
            "class_preemptions": self.class_preemptions,
            "policy_swaps": self.policy_swaps,
            "slot_deaths": self.slot_deaths,
            "early_exits": self.early_exits,
        }


@dataclasses.dataclass
class _PrefillResidual:
    """A preempted prefill: everything needed to resume at ``pos``.  The
    cache already holds positions < pos, so the residual is exactly the
    unprocessed suffix — the overshoot beyond the preemption point is the
    one block that was in flight, bounded by growth/(1+growth)."""

    batch: List[Request]
    toks: jnp.ndarray
    cache: Any
    pos: int
    max_new: int
    row_lengths: List[int]
    gathered: Optional[jnp.ndarray]   # per-row last-real logits so far


class Engine:
    def __init__(self, model: Model, params: Any, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.prefiller = ChunkedPrefill(model, first_block=32, align=32,
                                        max_block=256)
        self._blockfn = make_decode_block(model, cfg.eos_id)
        self.queue: List[Request] = []
        self.telemetry = EngineTelemetry()
        self.admission = cap(WorkRange(0, 1 << 30), cfg.max_batch)
        self.admission_sim = AdmissionSimulator(lanes=cfg.max_batch)
        self._residual: Optional[_PrefillResidual] = None

    def submit(self, req: Request) -> None:
        if self.cfg.max_queue is not None \
                and len(self.queue) >= self.cfg.max_queue:
            self.telemetry.queue_rejections += 1
            raise QueueFull(
                f"request {req.rid}: queue is at max_queue="
                f"{self.cfg.max_queue}; shed load or retry later")
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _next_batch(self) -> List[Request]:
        if not self.queue:
            return []
        if self.cfg.admission == "simulate":
            take = self.admission_sim.choose(
                [len(r.prompt) for r in self.queue], self.cfg.max_batch)
        else:
            take = min(len(self.queue), self.cfg.max_batch)
        batch, self.queue = self.queue[:take], self.queue[take:]
        return batch

    def step(self) -> List[Request]:
        """Serve one unit of work; returns finished reqs (possibly []).

        A preempted prefill residual has priority over new admissions: the
        batch that was preempted resumes at its stashed position before any
        new batch starts — each step() spends at most
        ``prefill_block_budget`` prefill blocks, so no single long prompt
        can monopolize the engine."""
        if self._residual is not None:
            r, self._residual = self._residual, None
            return self._prefill_and_decode(
                r.batch, r.toks, r.cache, r.max_new, r.row_lengths,
                start=r.pos, gathered=r.gathered)
        batch = self._next_batch()
        if not batch:
            return []
        B = len(batch)
        row_lengths = [len(r.prompt) for r in batch]
        S = max(row_lengths)
        S = max(32, 1 << (S - 1).bit_length())
        max_new = max(r.max_new for r in batch)
        if S + max_new > self.cfg.max_seq:
            raise ValueError(
                f"batch needs {S} (padded prompt) + {max_new} (max_new) = "
                f"{S + max_new} cache positions but EngineConfig.max_seq is "
                f"{self.cfg.max_seq}; raise max_seq or shrink the request")
        toks = np.full((B, S), self.cfg.pad_id, np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt     # left-aligned prompts
        cache = self.model.init_cache(B, S + max_new)
        return self._prefill_and_decode(batch, jnp.asarray(toks), cache,
                                        max_new, row_lengths, start=0)

    def _prefill_and_decode(self, batch: List[Request], toks: jnp.ndarray,
                            cache: Any, max_new: int,
                            row_lengths: List[int], *, start: int,
                            gathered: Optional[jnp.ndarray] = None
                            ) -> List[Request]:
        B, S = toks.shape
        logits, cache, pstats = self.prefiller.run(
            self.params, toks, cache, start=start,
            max_blocks=self.cfg.prefill_block_budget,
            row_lengths=row_lengths, gathered=gathered)
        if pstats.preempted:      # requeue the bounded residual, yield
            self._residual = _PrefillResidual(
                batch=batch, toks=toks, cache=cache,
                pos=pstats.next_start, max_new=max_new,
                row_lengths=row_lengths, gathered=logits)
            return []
        lengths = jnp.asarray(row_lengths, jnp.int32)
        first = jnp.argmax(
            logits[:, :self.model.cfg.vocab_size], -1).astype(jnp.int32)
        first_np = np.asarray(first)
        now = time.perf_counter()
        for r in batch:
            r.t_first = now
        if max_new > 1:           # `first` already counts toward max_new
            gen, cache, dstats = decode_until_eos(
                self.model, self.params, first, cache, lengths,
                eos_id=self.cfg.eos_id, max_new=max_new - 1,
                blockfn=self._blockfn)
            gen_np = np.asarray(gen)
        else:
            gen_np = np.full((B, 0), -1, np.int32)
            dstats = DecodeStats(all_finished=True)
        now = time.perf_counter()
        for i, r in enumerate(batch):
            row = gen_np[i]
            row = row[row >= 0][:max(0, r.max_new - 1)]
            r.result = np.concatenate(
                [first_np[i:i + 1], row.astype(np.int32)])
            useful = len(r.result)
            r.stats = DecodeStats(
                blocks=dstats.blocks, steps_run=dstats.steps_run,
                useful_tokens=useful,
                wasted_tokens=dstats.steps_run - (useful - 1),
                all_finished=bool((r.result == self.cfg.eos_id).any()))
            r.t_done = now
        return batch


@dataclasses.dataclass
class _Slot:
    """One occupied decode-batch lane."""

    req: Request
    first: int                    # first token (from prefill logits)
    lease: Cap                    # admission-cap clone; on_finish() retires
    class_lease: Optional[Cap] = None   # per-SLO-class cap clone
    emitted: List[int] = dataclasses.field(default_factory=list)
    eos_hit: bool = False
    steps: int = 0                # decode steps run while occupied
    wasted: int = 0               # post-finish steps inside ticks
    early_exit: bool = False      # retired by the entropy gate


@dataclasses.dataclass
class _PrefillJob:
    """The (single) in-flight chunked prefill, resumable across steps.

    ``done_logits`` holds the completed prefill's gathered logits when no
    decode slot was free at completion (possible only after a class
    preemption parked this job while another admission proceeded); the job
    installs at the next step with a free lane."""

    req: Request
    lease: Cap
    toks: jnp.ndarray             # (1, S_pad)
    cache: Any                    # batch=1 scratch cache, width max_seq
    pos: int = 0
    gathered: Optional[jnp.ndarray] = None
    class_lease: Optional[Cap] = None
    done_logits: Optional[jnp.ndarray] = None


class ContinuousEngine:
    """Continuous batching: persistent slots, interleaved chunked prefill,
    telemetry-driven admission.  Call :meth:`step` in a loop; each step
    (1) tries to admit one queued request (cap + page gate),
    (2) runs at most a budget of prefill blocks on the in-flight prompt,
    (3) runs one decode tick over the live slots,
    (4) retires finished slots and returns their requests.
    """

    def __init__(self, model: Model, params: Any, cfg: EngineConfig,
                 policy: Optional[ServePolicy] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.prefiller = ChunkedPrefill(model, first_block=32, align=32,
                                        max_block=256)
        self.queue: List[Request] = []
        self.telemetry = EngineTelemetry()
        B = cfg.max_batch
        per_slot = -(-cfg.max_seq // cfg.page_size)
        self.pages = PageTable(cfg.page_size,
                               cfg.num_pages or B * per_slot)
        # The admission cap: the shared counter starts at 1 (the root task
        # itself), so a threshold of max_batch+1 admits max_batch leases.
        self._admission: Cap = Cap(
            WorkRange(0, 1 << 30), B + 1,
            threshold_fn=self._admission_limit,
            on_event=self.telemetry.on_cap_event)
        # Per-SLO-class concurrency caps: the same adaptor, one per class
        # named in cfg.class_caps (absent classes stay uncapped).
        self._class_caps: Dict[str, Cap] = {
            c: Cap(WorkRange(0, 1 << 30), n + 1)
            for c, n in (cfg.class_caps or {}).items()}
        self.cache = model.init_cache(B, cfg.max_seq)
        self.lengths = jnp.zeros((B,), jnp.int32)
        self.tokens = jnp.zeros((B,), jnp.int32)
        self.finished = jnp.ones((B,), bool)      # empty lanes are finished
        self.remaining = jnp.zeros((B,), jnp.int32)
        self.streak = jnp.zeros((B,), jnp.int32)  # entropy-gate streaks
        self.slots: List[Optional[_Slot]] = [None] * B
        self._job: Optional[_PrefillJob] = None
        self._parked: Optional[_PrefillJob] = None   # class-preempted prefill
        # recurrence-only models hold O(1) decode state per request — pages
        # become fixed-size *state slots* instead of seq-length KV spans
        self._state_slots = model.recurrent_only
        if cfg.exit_entropy is not None:
            self._tick = make_gated_decode_tick(
                model, cfg.eos_id, tau=cfg.exit_entropy,
                patience=cfg.exit_patience)
        else:
            self._tick = make_decode_tick(model, cfg.eos_id)
        self._policy: ServePolicy = policy or FifoServePolicy()
        self.preempted = False    # SIGTERM drain flag

    # ---------------------------------------------------------------- policy
    @property
    def policy(self) -> ServePolicy:
        return self._policy

    def set_policy(self, policy: ServePolicy) -> None:
        """Hot-swap the scheduling policy on the live engine.  In-flight
        slots and the in-flight prefill are untouched (they drain under
        whatever ordering admitted them); only future admissions consult
        the new policy — so per-request token streams are exactness-
        preserved across the swap by construction."""
        self._policy = policy
        self.telemetry.policy_swaps += 1

    # ---------------------------------------------------------------- admit
    def _slot_span(self, req: Request) -> int:
        """Worst-case cache positions the request can touch: the padded
        prefill width or true length + budget, whichever is larger.

        Recurrence-only models (pure Mamba/xLSTM stacks) are the exception:
        their decode state is O(1) — a conv tail plus a fixed-size carry —
        so a request's footprint is one page regardless of prompt length or
        budget.  That is the SSM *state slot*: page accounting never defers
        an admission for sequence length, only for lane exhaustion."""
        if self._state_slots:
            return self.cfg.page_size
        pad = max(32, -(-len(req.prompt) // 32) * 32)
        return max(pad, len(req.prompt) + req.max_new)

    def submit(self, req: Request) -> None:
        span = self._slot_span(req)
        if span > self.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} needs {span} cache positions but "
                f"EngineConfig.max_seq is {self.cfg.max_seq}")
        if req.slo not in SLO_CLASSES:
            raise ValueError(f"request {req.rid}: unknown SLO class "
                             f"{req.slo!r}; expected one of {SLO_CLASSES}")
        if self.cfg.max_queue is not None \
                and len(self.queue) >= self.cfg.max_queue:
            self.telemetry.queue_rejections += 1
            raise QueueFull(
                f"request {req.rid}: queue is at max_queue="
                f"{self.cfg.max_queue}; shed load or retry later")
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _admission_limit(self) -> int:
        """Telemetry-driven cap: active requests + how many more the page
        headroom can hold at the measured per-request footprint.  +1 for
        the root the shared counter starts with."""
        active = sum(s is not None for s in self.slots)
        active += 1 if self._job is not None else 0
        active += 1 if self._parked is not None else 0
        ppr = self.telemetry.pages_per_request
        est = (max(1, int(math.ceil(ppr))) if ppr > 0
               else max(1, self.pages.pages_needed(self.cfg.max_seq // 4)))
        headroom = len(self.pages.free) // est
        return active + headroom + 1

    def _class_cap_ok(self, slo: str) -> bool:
        c = self._class_caps.get(slo)
        return c is None or c.should_be_divided()

    def _take_class_lease(self, slo: str) -> Optional[Cap]:
        c = self._class_caps.get(slo)
        if c is None:
            return None
        lease, rest = c.divide_at(1)
        self._class_caps[slo] = rest
        return lease

    # -------------------------------------------------------------- shedding
    def _shed_expired(self) -> List[Request]:
        """Drop queue entries already past their deadline — loudly.  A shed
        request is returned from step() like a retired one (empty result,
        ``shed=True``) so callers account for every submission exactly
        once; per-tenant and per-class counters make the drop visible."""
        if not self.queue:
            return []
        now = time.perf_counter()
        shed: List[Request] = []
        kept: List[Request] = []
        for r in self.queue:
            if r.deadline_s is not None and r.t_submit is not None \
                    and now > r.t_submit + r.deadline_s:
                r.shed = True
                r.result = np.zeros((0,), np.int32)
                r.stats = DecodeStats(all_finished=False)
                r.t_done = now
                self.telemetry.observe_shed(r)
                shed.append(r)
            else:
                kept.append(r)
        self.queue = kept
        return shed

    def _try_admit(self) -> None:
        if self._job is not None or not self.queue:
            return
        # a parked prefill needs a decode lane too: keep one in reserve
        free_slots = sum(s is None for s in self.slots)
        if free_slots <= (1 if self._parked is not None else 0):
            return
        if not self._admission.should_be_divided():
            return
        req = None
        for qi in self._policy.order(self.queue, time.perf_counter()):
            if self._class_cap_ok(self.queue[qi].slo):
                req = self.queue[qi]
                break
        if req is None:           # every waiting class is at its cap
            return
        pages = self.pages.allocate(req.rid, self._slot_span(req))
        if pages is None:         # page exhaustion → defer admission
            self.telemetry.deferred_pages += 1
            return
        self.queue.remove(req)
        lease, rest = self._admission.divide_at(1)
        self._admission = rest
        class_lease = self._take_class_lease(req.slo)
        self.telemetry.observe_admission(len(pages))
        S_pad = max(32, -(-len(req.prompt) // 32) * 32)
        toks = np.full((1, S_pad), self.cfg.pad_id, np.int32)
        toks[0, :len(req.prompt)] = req.prompt
        self._job = _PrefillJob(
            req=req, lease=lease, toks=jnp.asarray(toks),
            cache=self.model.init_cache(1, self.cfg.max_seq),
            class_lease=class_lease)

    # ---------------------------------------------------- class preemption
    def _maybe_park_prefill(self) -> None:
        """Park a lower-class in-flight prefill at its by_blocks boundary
        when interactive work is waiting and admittable.  The parked job's
        cache and position are already consistent (the chunked prefill is
        resumable by construction), so parking loses nothing; the job
        resumes as soon as no interactive admission can proceed."""
        job = self._job
        if (not self._policy.preempt_classes or job is None
                or self._parked is not None or job.done_logits is not None
                or job.req.slo == "interactive"):
            return
        if not any(r.slo == "interactive" for r in self.queue):
            return
        free_slots = sum(s is None for s in self.slots)
        if free_slots < 2:        # one lane for the parked job, one for the
            return                # interactive admission — else don't park
        if not self._admission.should_be_divided() \
                or not self._class_cap_ok("interactive"):
            return
        self._parked, self._job = job, None
        self.telemetry.class_preemptions += 1

    # -------------------------------------------------------------- prefill
    def _prefill_budget(self) -> Optional[int]:
        """Blocks of prefill one step may spend: the configured budget,
        tightened so prefill work stays comparable to one decode tick's
        wall time (decode ticks never wait on a straggling prefill)."""
        budget = self.cfg.prefill_block_budget
        t = self.telemetry
        if t.decode_s_per_token > 0 and t.prefill_s_per_block > 0:
            tick_wall = t.decode_s_per_token * self.cfg.decode_tick
            balanced = max(1, int(tick_wall / t.prefill_s_per_block))
            budget = balanced if budget is None else min(budget, balanced)
        return budget

    def _run_prefill(self) -> None:
        job = self._job
        if job is None:
            return
        if job.done_logits is not None:   # completed earlier, lane-starved
            self._install_job(job, job.done_logits)
            return
        t0 = time.perf_counter()
        logits, cache, pstats = self.prefiller.run(
            self.params, job.toks, job.cache, start=job.pos,
            max_blocks=self._prefill_budget(),
            row_lengths=[len(job.req.prompt)], gathered=job.gathered)
        self.telemetry.observe_prefill(pstats.blocks, pstats.tokens,
                                       time.perf_counter() - t0)
        if pstats.preempted:
            job.cache, job.pos, job.gathered = cache, pstats.next_start, \
                logits
            self.telemetry.prefill_preemptions += 1
            return
        job.cache = cache
        self._install_job(job, logits)

    def _install_job(self, job: _PrefillJob, logits: jnp.ndarray) -> None:
        """Install a completed prefill into a free decode lane (or stash
        its logits until one frees up)."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            job.done_logits = logits
            return
        slot = free[0]
        req = job.req
        self.cache = cache_slot_insert(self.cache, job.cache, slot)
        first = int(np.asarray(
            jnp.argmax(logits[0, :self.model.cfg.vocab_size])))
        req.t_first = time.perf_counter()
        done = (first == self.cfg.eos_id) or (req.max_new <= 1)
        L = len(req.prompt)
        self.lengths = self.lengths.at[slot].set(L)
        self.tokens = self.tokens.at[slot].set(first)
        self.finished = self.finished.at[slot].set(done)
        self.remaining = self.remaining.at[slot].set(req.max_new - 1)
        self.streak = self.streak.at[slot].set(0)
        self.slots[slot] = _Slot(req=req, first=first, lease=job.lease,
                                 class_lease=job.class_lease,
                                 eos_hit=(first == self.cfg.eos_id))
        self._job = None

    # --------------------------------------------------------------- decode
    def _decode_tick(self) -> None:
        occupied = [i for i, s in enumerate(self.slots) if s is not None]
        if not occupied:
            return
        fin = np.asarray(self.finished)
        if all(fin[i] for i in occupied):
            return
        n = self.cfg.decode_tick
        t0 = time.perf_counter()
        if self.cfg.exit_entropy is not None:
            (self.tokens, self.cache, self.lengths, self.finished,
             self.remaining, self.streak, gated, out, wasted) = self._tick(
                self.params, self.tokens, self.cache, self.lengths,
                self.finished, self.remaining, self.streak, n)
            gated_np = np.asarray(gated)
        else:
            (self.tokens, self.cache, self.lengths, self.finished,
             self.remaining, out, wasted) = self._tick(
                self.params, self.tokens, self.cache, self.lengths,
                self.finished, self.remaining, n)
            gated_np = None
        out_np = np.asarray(out)          # blocks until the tick is done
        self.telemetry.observe_decode(int((out_np >= 0).sum()),
                                      time.perf_counter() - t0, n)
        wasted_np = np.asarray(wasted)
        for i in occupied:
            s = self.slots[i]
            valid = out_np[i][out_np[i] >= 0]
            s.emitted.extend(int(t) for t in valid)
            s.steps += n
            s.wasted += int(wasted_np[i])
            if (valid == self.cfg.eos_id).any():
                s.eos_hit = True
            if gated_np is not None and bool(gated_np[i]):
                s.early_exit = True

    # --------------------------------------------------------------- retire
    def _retire(self) -> List[Request]:
        fin = np.asarray(self.finished)
        done: List[Request] = []
        now = time.perf_counter()
        for i, s in enumerate(self.slots):
            if s is None or not fin[i]:
                continue
            r = s.req
            toks = [s.first] + s.emitted
            r.result = np.asarray(toks[:r.max_new], np.int32)
            r.stats = DecodeStats(
                blocks=-(-s.steps // max(1, self.cfg.decode_tick)),
                steps_run=s.steps,
                useful_tokens=len(r.result),
                wasted_tokens=s.steps - (len(r.result) - 1),
                all_finished=s.eos_hit,
                early_exit=s.early_exit)
            if s.early_exit:
                self.telemetry.early_exits += 1
            r.t_done = now
            self.pages.release(r.rid)
            s.lease.on_finish()
            if s.class_lease is not None:
                s.class_lease.on_finish()
            self.slots[i] = None
            self.telemetry.retired += 1
            done.append(r)
        return done

    # ----------------------------------------------------------------- chaos
    def kill_slot(self, i: int) -> bool:
        """Chaos hook: decode lane ``i`` dies mid-decode.  Its emitted
        tokens, pages and leases are discarded and the request is requeued
        at the *front* of the waiting queue to be re-served from scratch —
        greedy decode is deterministic, so the re-serve emits the exact
        tokens the undisturbed run would have.  Returns False for an
        empty or out-of-range lane (fault plans are written against step
        indices, not live lane assignments)."""
        s = self.slots[i] if 0 <= i < len(self.slots) else None
        if s is None:
            return False
        r = s.req
        self.pages.release(r.rid)
        s.lease.on_finish()
        if s.class_lease is not None:
            s.class_lease.on_finish()
        self.slots[i] = None
        self.finished = self.finished.at[i].set(True)
        self.remaining = self.remaining.at[i].set(0)
        self.lengths = self.lengths.at[i].set(0)
        self.streak = self.streak.at[i].set(0)
        r.requeues += 1
        r.t_first = None
        self.queue.insert(0, r)
        self.telemetry.slot_deaths += 1
        return True

    # -------------------------------------------------------------- preempt
    def install_signal_handlers(self, signals=(signal.SIGTERM,)) -> Dict:
        """Route SIGTERM to a graceful drain: the flag flips at the next
        step() boundary — in-flight slots and the in-flight prefill run to
        completion, the waiting queue is frozen for :meth:`handoff`.
        Returns the previous handlers so tests can restore them."""
        return {s: signal.signal(s, self._on_signal) for s in signals}

    def _on_signal(self, signum, frame) -> None:
        self.preempted = True

    def handoff(self) -> List[Request]:
        """Detach the waiting queue (for resubmission on a fresh engine
        after a drain).  Queued requests were never prefix-cached, so
        resubmission is exact by construction."""
        q, self.queue = self.queue, []
        return q

    # ----------------------------------------------------------------- loop
    @property
    def pending(self) -> bool:
        in_flight = (self._job is not None or self._parked is not None
                     or any(s is not None for s in self.slots))
        if self.preempted:
            return in_flight      # drain mode: the queue waits for handoff
        return bool(self.queue) or in_flight

    def step(self) -> List[Request]:
        shed: List[Request] = []
        if not self.preempted:
            shed = self._shed_expired()
            self._maybe_park_prefill()
            self._try_admit()
        if self._job is None and self._parked is not None:
            # nothing (more) to admit ahead of it: resume the parked prefill
            self._job, self._parked = self._parked, None
        self._run_prefill()
        self._decode_tick()
        return self._retire() + shed


__all__ = ["Engine", "ContinuousEngine", "EngineConfig", "EngineTelemetry",
           "Request", "AdmissionSimulator", "QueueFull"]
