"""Early-exit decoding — ``find_first`` (paper §4.1) as EOS detection.

Finding the EOS position of a batch of generations IS find_first: apply
``decode`` to positions until the predicate (tok == eos) fires.  The naive
schedule decodes every sequence to max_new_tokens (up to (P−1)/P of the work
wasted, in the paper's terms).  The by_blocks schedule decodes in
geometrically growing blocks, checking between blocks — total wasted work
bounded by half (growth=2), with O(log n) host synchronizations.

``decode_block`` runs n steps inside one jit (a ``work_loop`` grant);
finished sequences keep stepping until their block ends — exactly the
"tasks already started cannot be cancelled" semantics of classical
schedulers that the paper measures; the waste is *counted* and reported.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import geometric_blocks
from ..models.model import Model


@dataclasses.dataclass
class DecodeStats:
    blocks: int = 0
    steps_run: int = 0            # decode steps executed (per sequence)
    useful_tokens: int = 0        # tokens up to & including EOS
    wasted_tokens: int = 0        # tokens decoded past EOS
    all_finished: bool = False
    early_exit: bool = False      # retired by the entropy gate, not EOS

    @property
    def wasted_fraction(self) -> float:
        total = self.useful_tokens + self.wasted_tokens
        return self.wasted_tokens / total if total else 0.0


def make_decode_block(model: Model, eos_id: int):
    """Returns jit'd fn(params, tokens, cache, lengths, finished, n) →
    (tokens, cache, lengths, finished, out_block (B,n), wasted (B,))."""

    def block(params, tokens, cache, lengths, finished, *, n: int):
        B = tokens.shape[0]

        def body(i, carry):
            tokens, cache, lengths, finished, out, wasted = carry
            logits, cache = model.decode_step(params, tokens, cache, lengths)
            nxt = jnp.argmax(logits[:, :model.cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            wasted = wasted + finished.astype(jnp.int32)
            out = out.at[:, i].set(jnp.where(finished, -1, nxt))
            finished = finished | (nxt == eos_id)
            lengths = lengths + 1
            return (nxt, cache, lengths, finished, out, wasted)

        out0 = jnp.full((B, n), -1, jnp.int32)
        wasted0 = jnp.zeros((B,), jnp.int32)
        return jax.lax.fori_loop(
            0, n, body, (tokens, cache, lengths, finished, out0, wasted0))

    jits: Dict[int, Callable] = {}

    def dispatch(params, tokens, cache, lengths, finished, n: int):
        if n not in jits:
            jits[n] = jax.jit(partial(block, n=n), donate_argnums=2)
        return jits[n](params, tokens, cache, lengths, finished)

    return dispatch


def make_decode_tick(model: Model, eos_id: int):
    """Decode tick for the continuous-batching engine: like
    ``make_decode_block`` but each slot also carries ``remaining`` — its
    per-request ``max_new`` budget — so rows retire independently on EOS
    *or* budget exhaustion while the rest of the batch keeps stepping.

    Returns fn(params, tokens, cache, lengths, finished, remaining, n) →
    (tokens, cache, lengths, finished, remaining, out (B, n), wasted (B,)).
    Emitted tokens for rows that were already finished (or empty slots) are
    -1; ``lengths`` only advances for live rows, so slot KV stays aligned.
    """

    def tick(params, tokens, cache, lengths, finished, remaining, *, n: int):
        B = tokens.shape[0]

        def body(i, carry):
            tokens, cache, lengths, finished, remaining, out, wasted = carry
            live = ~finished
            logits, cache = model.decode_step(params, tokens, cache, lengths)
            nxt = jnp.argmax(logits[:, :model.cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            wasted = wasted + finished.astype(jnp.int32)
            out = out.at[:, i].set(jnp.where(finished, -1, nxt))
            remaining = remaining - live.astype(jnp.int32)
            finished = finished | (nxt == eos_id) | (remaining <= 0)
            lengths = lengths + live.astype(jnp.int32)
            tokens = jnp.where(live, nxt, tokens)
            return (tokens, cache, lengths, finished, remaining, out, wasted)

        out0 = jnp.full((B, n), -1, jnp.int32)
        wasted0 = jnp.zeros((B,), jnp.int32)
        return jax.lax.fori_loop(
            0, n, body,
            (tokens, cache, lengths, finished, remaining, out0, wasted0))

    jits: Dict[int, Callable] = {}

    def dispatch(params, tokens, cache, lengths, finished, remaining, n: int):
        if n not in jits:
            jits[n] = jax.jit(partial(tick, n=n), donate_argnums=2)
        return jits[n](params, tokens, cache, lengths, finished, remaining)

    return dispatch


def make_gated_decode_tick(model: Model, eos_id: int, *, tau: float,
                           patience: int = 2):
    """Uncertainty-gated decode tick: EOS retirement plus an entropy gate.

    A lane whose predictive entropy stays below ``tau`` nats for
    ``patience`` consecutive live steps is *confident* — the model has
    committed to a low-uncertainty continuation — and retires early, so
    its decode lane (and its state slot) backfills from the queue.  The
    per-slot ``streak`` counter is threaded through the tick alongside the
    other slot state; ``gated`` reports which lanes the gate (not EOS /
    budget) retired this tick.

    Exactness property: gating only *stops* emission — every token emitted
    before the gate fires is the same greedy token the ungated tick
    produces, so a gated stream is an exact prefix of the ungated stream
    (pinned in tests/test_ssm_scan.py and BENCH_scan_ssm.json).

    Returns fn(params, tokens, cache, lengths, finished, remaining, streak,
    n) → (tokens, cache, lengths, finished, remaining, streak, gated,
    out (B, n), wasted (B,)).
    """

    def tick(params, tokens, cache, lengths, finished, remaining, streak,
             *, n: int):
        B = tokens.shape[0]
        V = model.cfg.vocab_size

        def body(i, carry):
            (tokens, cache, lengths, finished, remaining, streak, gated,
             out, wasted) = carry
            live = ~finished
            logits, cache = model.decode_step(params, tokens, cache, lengths)
            lg = logits[:, :V]
            p = jax.nn.softmax(lg, axis=-1)
            ent = -jnp.sum(p * jnp.log(p + 1e-9), axis=-1)    # (B,) nats
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            wasted = wasted + finished.astype(jnp.int32)
            out = out.at[:, i].set(jnp.where(finished, -1, nxt))
            remaining = remaining - live.astype(jnp.int32)
            streak = jnp.where(live & (ent < tau), streak + 1, 0)
            gate = live & (streak >= patience)
            finished = finished | (nxt == eos_id) | (remaining <= 0) | gate
            gated = gated | gate
            lengths = lengths + live.astype(jnp.int32)
            tokens = jnp.where(live, nxt, tokens)
            return (tokens, cache, lengths, finished, remaining, streak,
                    gated, out, wasted)

        out0 = jnp.full((B, n), -1, jnp.int32)
        wasted0 = jnp.zeros((B,), jnp.int32)
        gated0 = jnp.zeros((B,), bool)
        return jax.lax.fori_loop(
            0, n, body,
            (tokens, cache, lengths, finished, remaining, streak, gated0,
             out0, wasted0))

    jits: Dict[int, Callable] = {}

    def dispatch(params, tokens, cache, lengths, finished, remaining,
                 streak, n: int):
        if n not in jits:
            jits[n] = jax.jit(partial(tick, n=n), donate_argnums=2)
        return jits[n](params, tokens, cache, lengths, finished, remaining,
                       streak)

    return dispatch


def decode_until_eos(model: Model, params: Any, first_tokens: jnp.ndarray,
                     cache: Any, lengths: jnp.ndarray, *, eos_id: int,
                     max_new: int = 256, use_blocks: bool = True,
                     first_block: Optional[int] = None,
                     growth: float = 2.0, blockfn: Optional[Callable] = None
                     ) -> Tuple[jnp.ndarray, Any, DecodeStats]:
    """Greedy-decode until every sequence hits EOS (or max_new).

    use_blocks=False is the naive schedule (one block of max_new) — the
    paper's "without blocks" baseline, kept for the benchmark.

    Callers that decode repeatedly should build ``blockfn`` once with
    :func:`make_decode_block` and pass it in — the per-block jits live in
    the blockfn's cache, so a fresh one per call recompiles every block.
    """
    B = first_tokens.shape[0]
    stats = DecodeStats()
    if blockfn is None:
        blockfn = make_decode_block(model, eos_id)
    tokens = first_tokens
    finished = tokens == eos_id
    outs = []
    bounds = (geometric_blocks(max_new, first=first_block or max(8, B // 4),
                               growth=growth)
              if use_blocks else [(0, max_new)])
    wasted_total = 0
    for (lo, hi) in bounds:
        n = hi - lo
        tokens, cache, lengths, finished, out, wasted = blockfn(
            params, tokens, cache, lengths, finished, n)
        outs.append(out)
        stats.blocks += 1
        stats.steps_run += n
        wasted_total += int(wasted.sum())
        if bool(finished.all()):
            stats.all_finished = True
            break
    gen = jnp.concatenate(outs, axis=1)
    useful = int((gen >= 0).sum())
    stats.useful_tokens = useful
    # The kernel's per-block waste counter is the ground truth; it equals
    # steps_run·B − useful by construction (each step emits either a useful
    # token or a −1 for an already-finished row) — tested in test_serve.
    stats.wasted_tokens = wasted_total
    assert wasted_total == stats.steps_run * B - useful, \
        (wasted_total, stats.steps_run, B, useful)
    return gen, cache, stats


__all__ = ["decode_until_eos", "make_decode_block", "make_decode_tick",
           "make_gated_decode_tick", "DecodeStats"]
