"""Early-exit decoding — ``find_first`` (paper §4.1) as EOS detection.

Finding the EOS position of a batch of generations IS find_first: apply
``decode`` to positions until the predicate (tok == eos) fires.  The naive
schedule decodes every sequence to max_new_tokens (up to (P−1)/P of the work
wasted, in the paper's terms).  The by_blocks schedule decodes in
geometrically growing blocks, checking between blocks — total wasted work
bounded by half (growth=2), with O(log n) host synchronizations.

``decode_block`` runs n steps inside one jit (a ``work_loop`` grant);
finished sequences keep stepping until their block ends — exactly the
"tasks already started cannot be cancelled" semantics of classical
schedulers that the paper measures; the waste is *counted* and reported.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import geometric_blocks
from ..models.model import Model


@dataclasses.dataclass
class DecodeStats:
    blocks: int = 0
    steps_run: int = 0            # decode steps executed (per sequence)
    useful_tokens: int = 0        # tokens up to & including EOS
    wasted_tokens: int = 0        # tokens decoded past EOS
    all_finished: bool = False

    @property
    def wasted_fraction(self) -> float:
        total = self.useful_tokens + self.wasted_tokens
        return self.wasted_tokens / total if total else 0.0


def make_decode_block(model: Model, eos_id: int):
    """Returns jit'd fn(params, tokens, cache, lengths, finished, n) →
    (tokens, cache, lengths, finished, out_block (B,n), wasted (B,))."""

    def block(params, tokens, cache, lengths, finished, *, n: int):
        B = tokens.shape[0]

        def body(i, carry):
            tokens, cache, lengths, finished, out, wasted = carry
            logits, cache = model.decode_step(params, tokens, cache, lengths)
            nxt = jnp.argmax(logits[:, :model.cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            wasted = wasted + finished.astype(jnp.int32)
            out = out.at[:, i].set(jnp.where(finished, -1, nxt))
            finished = finished | (nxt == eos_id)
            lengths = lengths + 1
            return (nxt, cache, lengths, finished, out, wasted)

        out0 = jnp.full((B, n), -1, jnp.int32)
        wasted0 = jnp.zeros((B,), jnp.int32)
        return jax.lax.fori_loop(
            0, n, body, (tokens, cache, lengths, finished, out0, wasted0))

    jits: Dict[int, Callable] = {}

    def dispatch(params, tokens, cache, lengths, finished, n: int):
        if n not in jits:
            jits[n] = jax.jit(partial(block, n=n), donate_argnums=2)
        return jits[n](params, tokens, cache, lengths, finished)

    return dispatch


def decode_until_eos(model: Model, params: Any, first_tokens: jnp.ndarray,
                     cache: Any, lengths: jnp.ndarray, *, eos_id: int,
                     max_new: int = 256, use_blocks: bool = True,
                     first_block: Optional[int] = None,
                     growth: float = 2.0
                     ) -> Tuple[jnp.ndarray, Any, DecodeStats]:
    """Greedy-decode until every sequence hits EOS (or max_new).

    use_blocks=False is the naive schedule (one block of max_new) — the
    paper's "without blocks" baseline, kept for the benchmark.
    """
    B = first_tokens.shape[0]
    stats = DecodeStats()
    blockfn = make_decode_block(model, eos_id)
    tokens = first_tokens
    finished = tokens == eos_id
    outs = []
    bounds = (geometric_blocks(max_new, first=first_block or max(8, B // 4),
                               growth=growth)
              if use_blocks else [(0, max_new)])
    wasted_total = 0
    for (lo, hi) in bounds:
        n = hi - lo
        tokens, cache, lengths, finished, out, wasted = blockfn(
            params, tokens, cache, lengths, finished, n)
        outs.append(out)
        stats.blocks += 1
        stats.steps_run += n
        wasted_total += int(wasted.sum())
        if bool(finished.all()):
            stats.all_finished = True
            break
    gen = jnp.concatenate(outs, axis=1)
    useful = int((gen >= 0).sum())
    stats.useful_tokens = useful
    stats.wasted_tokens = stats.steps_run * B - useful
    return gen, cache, stats


__all__ = ["decode_until_eos", "make_decode_block", "DecodeStats"]
