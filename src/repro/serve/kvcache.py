"""KV-cache utilities: sharded allocation, sizing, block-table helpers.

``Model.init_cache`` owns the per-architecture state layout; this module adds
the deployment-side concerns: sharded device allocation on a mesh, byte
accounting (admission control needs it), and a simple paged block-table for
the engine (pages are SeqWork-aligned — the same Divisible the prefill
chunker cuts, so page size and chunk size compose).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..configs.base import ModelConfig
from ..dist.sharding import cache_shardings
from ..models.model import Model


def cache_bytes(model: Model, batch: int, max_seq: int, *,
                cross_len: int = 0) -> int:
    """Total cache bytes for (batch, max_seq) — admission-control arithmetic."""
    abstract = model.abstract_cache(batch, max_seq, cross_len=cross_len)
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(abstract))


def alloc_cache(model: Model, batch: int, max_seq: int, *, mesh=None,
                cross_len: int = 0) -> Any:
    """Zero cache, placed with the decode sharding layout when a mesh is
    given (batch over data, seq over model; long-context: seq over all)."""
    cache = model.init_cache(batch, max_seq, cross_len=cross_len)
    if mesh is None:
        return cache
    sh = cache_shardings(model.cfg, mesh, cache, batch)
    return jax.tree.map(jax.device_put, cache, sh)


@dataclasses.dataclass
class PageTable:
    """Fixed-size page accounting for cache reuse across requests.

    Pages are aligned to the prefill chunk alignment so a by_blocks chunk
    never straddles an unallocated page.
    """

    page_size: int
    num_pages: int

    def __post_init__(self):
        self.free: List[int] = list(range(self.num_pages))
        self.owner: Dict[int, List[int]] = {}

    def pages_needed(self, seq_len: int) -> int:
        return -(-seq_len // self.page_size)

    def allocate(self, rid: int, seq_len: int) -> Optional[List[int]]:
        n = self.pages_needed(seq_len)
        if len(self.free) < n:
            return None
        pages = [self.free.pop() for _ in range(n)]
        self.owner[rid] = pages
        return pages

    def extend(self, rid: int, new_seq_len: int) -> bool:
        have = len(self.owner.get(rid, []))
        need = self.pages_needed(new_seq_len)
        while have < need:
            if not self.free:
                return False
            self.owner[rid].append(self.free.pop())
            have += 1
        return True

    def release(self, rid: int) -> None:
        self.free.extend(self.owner.pop(rid, []))

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.num_pages


def cache_slot_insert(big: Any, small: Any, slot: int) -> Any:
    """Write a batch=1 cache pytree into row ``slot`` of a batched cache.

    Both caches must share the Model.init_cache layout and max_seq width:
    'prefix' leaves carry batch on axis 0, 'stage' leaves (stacked over
    repeats) carry batch on axis 1.  The whole slot row is overwritten —
    including positions past the new request's prefix — so any stale state
    a previous occupant (or an idle tick) left behind is erased.
    """
    out: Dict[str, Any] = {}
    if "prefix" in big:
        out["prefix"] = [
            {k: b.at[slot].set(s[k][0]) for k, b in layer.items()}
            for layer, s in zip(big["prefix"], small["prefix"])]
    out["stage"] = [
        {k: b.at[:, slot].set(s[k][:, 0]) for k, b in layer.items()}
        for layer, s in zip(big["stage"], small["stage"])]
    return out


__all__ = ["cache_bytes", "alloc_cache", "PageTable", "cache_slot_insert"]
