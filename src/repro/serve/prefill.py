"""Chunked prefill — the by_blocks scheduler (paper §3.5) on the serving path.

A long prompt is processed as a *sequence of parallel blocks* of geometrically
growing size: every block saturates the mesh; between blocks the host regains
control — the interruption point for request cancellation, preemption, or
batch reshuffling.  Exactly the paper's schedule: O(log S) blocks, wasted
work on interruption bounded by growth/(1+growth).

Block sizes are aligned (``align``) so each distinct chunk length compiles
once; the geometric sequence means at most O(log S) compilations.  The block
start position is a *traced* scalar — compilation is keyed on chunk length
(and the all-logits flag) only, never on position, so the jit cache stays
bounded across arbitrarily many prompts at arbitrary resume offsets.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import ByBlocks, SeqWork
from ..models.model import Model


@dataclasses.dataclass
class PrefillStats:
    blocks: int = 0
    tokens: int = 0
    cancelled: bool = False
    preempted: bool = False       # budget exhausted at a block boundary
    next_start: int = 0           # resume offset (valid when preempted)
    last_block: int = 0           # size of the last block that ran


class ChunkedPrefill:
    def __init__(self, model: Model, *, first_block: int = 128,
                 growth: float = 2.0, align: int = 128,
                 max_block: Optional[int] = 4096):
        self.model = model
        self.policy = ByBlocks(first=first_block, growth=growth, align=align,
                               cap=max_block)
        self._jits: Dict[Tuple[int, bool], Callable] = {}
        self.trace_count = 0      # one trace per distinct (chunk len, mode)

    def _chunk_fn(self, c: int, all_logits: bool) -> Callable:
        key = (c, all_logits)
        if key not in self._jits:
            def chunk(params, toks, cache, pos0, *, _al=all_logits):
                self.trace_count += 1   # runs at trace time only
                return self.model.prefill_chunk(params, toks, cache, pos0,
                                                all_logits=_al)
            self._jits[key] = jax.jit(chunk, donate_argnums=2)
        return self._jits[key]

    def run(self, params: Any, tokens: jnp.ndarray, cache: Any, *,
            batch: Optional[Dict[str, jnp.ndarray]] = None,
            should_cancel: Callable[[], bool] = lambda: False,
            start: int = 0, max_blocks: Optional[int] = None,
            row_lengths: Optional[Any] = None,
            gathered: Optional[jnp.ndarray] = None
            ) -> Tuple[Optional[jnp.ndarray], Any, PrefillStats]:
        """tokens: (B, S).  Returns (logits | None-if-cancelled, cache,
        stats).  ``batch`` carries modality stubs for cross-attn models.

        Without ``row_lengths`` the returned logits are the last *padded*
        position's (B, V) — correct only for uniform-length batches.  With
        ``row_lengths`` (true per-row prompt lengths), each chunk computes
        all-position logits and the row's last *real* position is gathered
        as it streams past, so mixed-length batches get the right
        next-token distribution per row.  ``gathered`` carries partial
        gathers across a preemption (pass back the logits this method
        returned with ``stats.preempted``).

        ``start`` resumes a previously preempted prefill at that position
        (the cache must already hold positions < start — i.e. the cache this
        method returned when it set ``stats.preempted``).  ``max_blocks``
        bounds how many blocks run in this call: when the budget is spent at
        a block boundary the remaining work is the caller's to requeue
        (``stats.next_start``) — the by_blocks preemption point, with the
        block just run (``stats.last_block``) the only non-useful overshoot,
        bounded by growth/(1+growth) of the processed prefix."""
        B, S = tokens.shape
        if batch is not None and start == 0:
            cache = self.model.encode_to_cache(params, batch, cache)
        stats = PrefillStats()
        logits = gathered
        sel = None
        if row_lengths is not None:
            sel = jnp.asarray(row_lengths, jnp.int32) - 1     # (B,)
        for blk in self.policy.blocks(SeqWork(start, S)):
            c = blk.size()
            fn = self._chunk_fn(c, row_lengths is not None)
            out, cache = fn(params, tokens[:, blk.start:blk.stop], cache,
                            jnp.int32(blk.start))
            if sel is None:
                logits = out
            else:
                local = jnp.clip(sel - blk.start, 0, c - 1)
                hit = ((sel >= blk.start) & (sel < blk.stop))[:, None]
                rows = out[jnp.arange(B), local]              # (B, V)
                prev = jnp.zeros_like(rows) if logits is None else logits
                logits = jnp.where(hit, rows, prev)
            stats.blocks += 1
            stats.tokens += c
            stats.last_block = c
            if should_cancel():
                stats.cancelled = True
                return None, cache, stats
            if (max_blocks is not None and stats.blocks >= max_blocks
                    and blk.stop < S):
                stats.preempted = True
                stats.next_start = blk.stop
                return logits, cache, stats
        return logits, cache, stats


__all__ = ["ChunkedPrefill", "PrefillStats"]
