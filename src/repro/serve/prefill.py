"""Chunked prefill — the by_blocks scheduler (paper §3.5) on the serving path.

A long prompt is processed as a *sequence of parallel blocks* of geometrically
growing size: every block saturates the mesh; between blocks the host regains
control — the interruption point for request cancellation, preemption, or
batch reshuffling.  Exactly the paper's schedule: O(log S) blocks, wasted
work on interruption bounded by growth/(1+growth).

Block sizes are aligned (``align``) so each distinct chunk length compiles
once; the geometric sequence means at most O(log S) compilations.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import ByBlocks, SeqWork
from ..models.model import Model


@dataclasses.dataclass
class PrefillStats:
    blocks: int = 0
    tokens: int = 0
    cancelled: bool = False


class ChunkedPrefill:
    def __init__(self, model: Model, *, first_block: int = 128,
                 growth: float = 2.0, align: int = 128,
                 max_block: Optional[int] = 4096):
        self.model = model
        self.policy = ByBlocks(first=first_block, growth=growth, align=align,
                               cap=max_block)
        self._jits: Dict[Tuple[int, int], Callable] = {}

    def _chunk_fn(self, c: int, pos0: int) -> Callable:
        key = (c, pos0)
        if key not in self._jits:
            self._jits[key] = jax.jit(
                partial(self.model.prefill_chunk, pos0=pos0),
                donate_argnums=2)
        return self._jits[key]

    def run(self, params: Any, tokens: jnp.ndarray, cache: Any, *,
            batch: Optional[Dict[str, jnp.ndarray]] = None,
            should_cancel: Callable[[], bool] = lambda: False
            ) -> Tuple[Optional[jnp.ndarray], Any, PrefillStats]:
        """tokens: (B, S).  Returns (last logits | None-if-cancelled, cache,
        stats).  ``batch`` carries modality stubs for cross-attn models."""
        B, S = tokens.shape
        if batch is not None:
            cache = self.model.encode_to_cache(params, batch, cache)
        stats = PrefillStats()
        logits = None
        for blk in self.policy.blocks(SeqWork(0, S)):
            c = blk.size()
            fn = self._chunk_fn(c, blk.start)
            logits, cache = fn(params, tokens[:, blk.start:blk.stop], cache)
            stats.blocks += 1
            stats.tokens += c
            if should_cancel():
                stats.cancelled = True
                return None, cache, stats
        return logits, cache, stats


__all__ = ["ChunkedPrefill", "PrefillStats"]
