"""SLO classes and serve-side scheduling policies.

Serving maps the core policy layer onto wall-clock traffic: an SLO *class*
(``interactive`` / ``batch`` / ``background``) is the serving analogue of a
:class:`~repro.core.adaptors.Tagged` priority band, and a serve policy is
the queue-ordering half of :class:`~repro.core.policies.PriorityPolicy` /
:class:`~repro.core.policies.DeadlinePolicy` — it decides which waiting
request the engine's admission path considers first.  The *mechanism*
(per-class ``cap`` adaptors, page accounting, the single in-flight prefill)
stays in :class:`~repro.serve.engine.ContinuousEngine`; a policy is pure
decision, so it can be hot-swapped on a live engine (:meth:`ContinuousEngine.
set_policy`): in-flight slots drain under the old ordering, new admissions
follow the new one, and per-request token streams are untouched either way.

``preempt_classes`` additionally arms the engine's batch-prefill preemption:
when an interactive request is waiting and the in-flight chunked prefill
belongs to a lower class, the job is parked at the next by_blocks block
boundary (its cache and position are already consistent — the residual is
exactly the unprocessed suffix) and resumed after the interactive admission.
"""

from __future__ import annotations

import math
from typing import List, Sequence

SLO_CLASSES = ("interactive", "batch", "background")
CLASS_RANK = {c: i for i, c in enumerate(SLO_CLASSES)}


def request_deadline(req, default: float = math.inf) -> float:
    """Absolute wall-clock deadline of a request (inf if undated)."""
    if req.deadline_s is None or req.t_submit is None:
        return default
    return req.t_submit + req.deadline_s


class ServePolicy:
    """Queue-ordering policy: ``order`` returns candidate queue indices in
    the order the engine should try to admit them.  FIFO base class."""

    name = "fifo"
    preempt_classes = False       # park batch-class prefill for interactive?

    def order(self, queue: Sequence, now: float) -> List[int]:
        return list(range(len(queue)))


class FifoServePolicy(ServePolicy):
    """Strict arrival order — the PR 8 behavior, and the shedding baseline:
    every class waits behind every other class."""


class PriorityServePolicy(ServePolicy):
    """Class-ranked admission: interactive before batch before background;
    within a class higher ``priority`` first, then earliest deadline, then
    arrival order.  Arms batch-prefill preemption."""

    name = "priority"
    preempt_classes = True

    def order(self, queue: Sequence, now: float) -> List[int]:
        def key(i):
            r = queue[i]
            return (CLASS_RANK.get(r.slo, len(SLO_CLASSES)), -r.priority,
                    request_deadline(r), i)
        return sorted(range(len(queue)), key=key)


class DeadlineServePolicy(ServePolicy):
    """Pure EDF across classes: earliest absolute deadline first, undated
    work last, arrival order as the tiebreak."""

    name = "deadline"

    def order(self, queue: Sequence, now: float) -> List[int]:
        return sorted(range(len(queue)),
                      key=lambda i: (request_deadline(queue[i]), i))


__all__ = [
    "SLO_CLASSES", "CLASS_RANK", "request_deadline", "ServePolicy",
    "FifoServePolicy", "PriorityServePolicy", "DeadlineServePolicy",
]
