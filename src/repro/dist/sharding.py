"""Sharding rules: mesh context, the ``param_pspec`` rule table, and the
derived sharding trees for params / optimizer moments / batches / KV caches.

Philosophy (mirrors the paper's policy/mechanism split): every sharding
decision is a small *rule* — a pure function from (config, tensor name,
rank) to a ``PartitionSpec`` — and the mechanism that applies rules is
shared: ``sanitize_spec`` guards divisibility, ``zero1_spec`` layers the
optimizer-state data sharding on top, and the ``*_shardings`` builders walk
pytrees turning rules into ``NamedSharding`` leaves.  Policies stay
swappable because nothing below this module hard-codes an axis.

Mesh axes (fixed by ``launch/mesh.py``):

  ``pod``   multi-pod replica axis (optional outermost)
  ``data``  data parallelism; ZeRO-1 moments shard here
  ``model`` tensor parallelism: vocab, heads, ffn hidden, experts

``mesh_context`` installs a context consulted by ``constrain``/``dp`` — the
model code is written once and becomes sharded the moment a context is
active, exactly like Kvik code is written once and scheduled by whichever
policy wraps it.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, List, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

# axes that carry the data-parallel batch dimension, outermost first
_DP_AXES = ("pod", "data")


# ---------------------------------------------------------------------------
# mesh context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Active mesh plus the two sizes every consumer asks for."""

    mesh: Mesh

    @property
    def dp(self) -> int:
        """Data-parallel world size (pod × data)."""
        n = 1
        for a in _DP_AXES:
            n *= self.mesh.shape.get(a, 1)
        return n

    @property
    def tp(self) -> int:
        """Tensor-parallel (model axis) size."""
        return self.mesh.shape.get("model", 1)


_CTX_STACK: List[MeshCtx] = []


def current_ctx() -> Optional[MeshCtx]:
    """The innermost active ``mesh_context``, or None outside one."""
    return _CTX_STACK[-1] if _CTX_STACK else None


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    """Install ``mesh`` as the ambient sharding context.

    Model code calls ``constrain``/``dp``/``current_ctx`` unconditionally;
    those are no-ops (or defaults) until a context is entered, so the same
    trace serves single-device smoke tests and the 512-chip dry-run.
    """
    ctx = MeshCtx(mesh)
    _CTX_STACK.append(ctx)
    try:
        with mesh:
            yield ctx
    finally:
        _CTX_STACK.pop()


def _dp_entry(mesh) -> Any:
    """The PartitionSpec entry for the batch dimension on ``mesh``:
    ``"data"``, ``("pod", "data")``, or None if the mesh has neither."""
    axes = tuple(a for a in _DP_AXES if a in mesh.shape)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def dp() -> Any:
    """Batch-axis spec entry under the active context (``"data"`` default).

    Always safe to call at trace time: without a context the returned entry
    only ever reaches ``constrain``, which is then a no-op.
    """
    ctx = current_ctx()
    return _dp_entry(ctx.mesh) if ctx is not None else "data"


def constrain(x, spec: P):
    """``with_sharding_constraint`` under the active mesh context; identity
    outside one.  Non-dividing axes are dropped (``sanitize_spec``) so the
    same constraint serves smoke shapes and production shapes."""
    ctx = current_ctx()
    if ctx is None:
        return x
    safe = sanitize_spec(ctx.mesh, spec, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, safe))


# ---------------------------------------------------------------------------
# spec algebra: divisibility guard + ZeRO-1
# ---------------------------------------------------------------------------

def _axis_size(mesh, entry: Any) -> Optional[int]:
    """Mesh-axis product of a spec entry, or None if the mesh lacks an
    axis the entry names (such an entry is inexpressible, not size-1)."""
    if entry is None:
        return 1
    names = entry if isinstance(entry, (tuple, list)) else (entry,)
    n = 1
    for a in names:
        if a not in mesh.shape:
            return None
        n *= mesh.shape[a]
    return n


def _entries(spec: P, ndim: int) -> List[Any]:
    got = list(spec)
    return got + [None] * (ndim - len(got))


def sanitize_spec(mesh, spec: P, shape: Sequence[int]) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim.

    GSPMD would pad uneven shards silently; padding changes reduction
    numerics and memory accounting, so the rule table opts for *replicating*
    any axis it cannot split exactly.  ``mesh`` only needs a ``.shape``
    mapping — axis sizes are the whole story.
    """
    out = []
    for dim, entry in zip(shape, _entries(spec, len(shape))):
        n = _axis_size(mesh, entry)
        out.append(entry if n is not None and dim % n == 0 else None)
    return P(*out)


def zero1_spec(mesh, spec: P, shape: Sequence[int]) -> P:
    """Layer ZeRO-1 on a param spec: shard the first free dividing dim over
    the data axes.  Already data-sharded specs pass through unchanged."""
    dpe = _dp_entry(mesh)
    if dpe is None:
        return sanitize_spec(mesh, spec, shape)
    dp_axes = set(dpe) if isinstance(dpe, tuple) else {dpe}
    entries = _entries(spec, len(shape))
    used = set()
    for e in entries:
        used.update(e if isinstance(e, (tuple, list)) else [e])
    if used & dp_axes:
        return P(*entries)
    for i, (dim, entry) in enumerate(zip(shape, entries)):
        if entry is None and dim % _axis_size(mesh, dpe) == 0 and dim > 1:
            entries[i] = dpe
            break
    return P(*entries)


# ---------------------------------------------------------------------------
# the param rule table
# ---------------------------------------------------------------------------

# column-parallel (output features live on the last axis → shard it):
_COL = {"wq", "wk", "wv", "gate", "up", "wkv_down", "wk_rope", "wkv_up",
        "in_proj", "x_proj", "dt_proj", "w", "wi", "wf"}
# row-parallel (contracting features on the second-to-last axis → shard it,
# the following all-reduce is the layer's single collective):
_ROW = {"wo", "down", "out_proj"}


def param_pspec(cfg: ModelConfig, name: str, ndim: int) -> P:
    """The rule table: (config, ``/``-joined tree path, rank) → spec.

    Stacked period parameters carry a leading repeat axis, so the same leaf
    name appears at two ranks; rules index from the *trailing* axes to stay
    rank-agnostic.  Pure function of static data — golden-pinned per config
    in tests/test_pspec_golden.py.
    """
    parts = name.split("/")
    leaf = parts[-1]

    # expert banks: experts over 'model'; the expert hidden dim additionally
    # over 'data' iff the config opts into 2-D MoE sharding (Jamba-398B —
    # stationary weights, no per-scan all-gather of a 796 GB bank)
    if len(parts) >= 2 and parts[-2] == "moe":
        f_ax = "data" if cfg.moe_2d_shard else None
        if leaf in ("gate", "up") and ndim >= 3:     # (..., E, D, F)
            return P(*([None] * (ndim - 3) + ["model", None, f_ax]))
        if leaf == "down" and ndim >= 3:             # (..., E, F, D)
            return P(*([None] * (ndim - 3) + ["model", f_ax, None]))
        return P(*([None] * ndim))                   # router: replicated

    if leaf == "table" and ndim == 2:                # embed / lm head
        return P("model", None)

    # xLSTM block-diagonal per-head mixers: (..., H, dh, dh) — shard heads
    if leaf in ("wq", "wk", "wv") and ndim == 4:
        return P(None, "model", None, None)

    if leaf in _COL and ndim >= 2:
        return P(*([None] * (ndim - 1) + ["model"]))
    if leaf in _ROW and ndim >= 2:
        return P(*([None] * (ndim - 2) + ["model", None]))
    # norms, biases, gates, rotary tables, positions: replicated
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:  # pragma: no cover - defensive
            parts.append(str(k))
    return "/".join(parts)


def params_shardings(cfg: ModelConfig, params: Any, mesh: Mesh) -> Any:
    """NamedSharding tree for a param pytree, via the rule table.

    ``cfg.fsdp`` additionally ZeRO-shards the params themselves over data.
    """
    def rule(path, leaf):
        spec = param_pspec(cfg, _path_str(path), leaf.ndim)
        spec = sanitize_spec(mesh, spec, leaf.shape)
        if cfg.fsdp:
            spec = zero1_spec(mesh, spec, leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, params)


def moments_shardings(cfg: ModelConfig, params: Any, mesh: Mesh) -> Any:
    """AdamW m/v shardings: the param spec plus ZeRO-1 over data."""
    def rule(path, leaf):
        spec = param_pspec(cfg, _path_str(path), leaf.ndim)
        spec = sanitize_spec(mesh, spec, leaf.shape)
        spec = zero1_spec(mesh, spec, leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, params)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch: Any) -> Any:
    """Batch-dim-0 over the data axes, everything else replicated."""
    dpe = _dp_entry(mesh)

    def rule(leaf):
        ndim = len(leaf.shape)
        if ndim == 0:
            return NamedSharding(mesh, P())
        spec = P(*([dpe] + [None] * (ndim - 1)))
        return NamedSharding(mesh, sanitize_spec(mesh, spec, leaf.shape))

    return jax.tree.map(rule, batch)


# cache leaves with a sequence axis right after the batch axis
_SEQ_LEAVES = {"k", "v", "ck", "cv", "latent"}
# recurrent-state leaves: (B, feature, ...) — shard the feature axis at this
# offset past batch over 'model' (conv buffers keep channels last)
_STATE_FEATURE_OFFSET = {"ssm": 1, "C": 1, "n": 1, "m": 1, "c": 1, "h": 1,
                         "conv": 2}


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache: Any,
                    batch: int) -> Any:
    """Decode-cache layout: batch over data, KV sequence over model.

    Long-context (batch == 1) flips to sequence-over-everything — the only
    way a single 500K-token sequence occupies the whole mesh.  Stacked
    period caches carry a leading repeat axis (detected from the ``stage``
    path), recurrent SSM states shard their feature dim over model.  All
    entries pass the divisibility guard.
    """
    dpe = _dp_entry(mesh)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)

    def rule(path, leaf):
        parts = _path_str(path).split("/")
        name = parts[-1]
        off = 1 if parts and parts[0] == "stage" else 0
        ndim = len(leaf.shape)
        entries: List[Any] = [None] * ndim
        if name in _SEQ_LEAVES:
            if batch == 1:
                entries[off + 1] = all_axes
            else:
                entries[off] = dpe
                entries[off + 1] = "model"
        else:
            entries[off] = dpe
            fa = off + _STATE_FEATURE_OFFSET.get(name, 1)
            if fa < ndim:
                entries[fa] = "model"
        spec = sanitize_spec(mesh, P(*entries), leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, cache)


__all__ = [
    "MeshCtx", "mesh_context", "current_ctx", "constrain", "dp",
    "sanitize_spec", "zero1_spec", "param_pspec", "params_shardings",
    "moments_shardings", "batch_shardings", "cache_shardings",
]
