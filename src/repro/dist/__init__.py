"""``repro.dist`` — the GSPMD mechanism layer under Kvik's policy layer.

The paper's thesis is that scheduling *policy* composes over a shared
*mechanism*.  On the jax/pallas target the mechanism at scale is GSPMD
sharding plus pipeline/collective schedules; this package holds it:

* :mod:`~repro.dist.sharding` — mesh context, the ``param_pspec`` rule
  table, and the derived params/moments/batch/cache sharding trees,
* :mod:`~repro.dist.pipeline` — fill–drain microbatch schedules whose
  tick order comes from a ``core.plan`` division tree, and a
  ``shard_map`` pipeline executor,
* :mod:`~repro.dist.collective` — latency-hiding collective matmuls
  (all-gather × matmul, matmul × reduce-scatter),
* :mod:`~repro.dist.expert` — ``moe_shard_map`` expert-parallel MoE
  dispatch built on the paper's stable sort.

See ``DESIGN.md`` in this directory for the rule-table philosophy.
"""

from .collective import allgather_matmul, matmul_reducescatter
from .expert import moe_shard_map
from .pipeline import (bubble_fraction, microbatch_order, pipeline_forward,
                       schedule_ticks)
from .sharding import (batch_shardings, cache_shardings, constrain,
                       current_ctx, dp, mesh_context, moments_shardings,
                       param_pspec, params_shardings, sanitize_spec,
                       zero1_spec)

__all__ = [
    "allgather_matmul", "matmul_reducescatter", "moe_shard_map",
    "bubble_fraction", "microbatch_order", "pipeline_forward",
    "schedule_ticks", "batch_shardings", "cache_shardings", "constrain",
    "current_ctx", "dp", "mesh_context", "moments_shardings", "param_pspec",
    "params_shardings", "sanitize_spec", "zero1_spec",
]
