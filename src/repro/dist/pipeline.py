"""Pipeline-parallel microbatch schedules, driven by ``core.plan``.

The tick order of a pipeline is a *scheduling policy decision*, so it comes
from the same machinery as every other schedule in this repo: a microbatch
order is the leaf order of a ``build_plan(bound_depth(WorkRange(0, n)))``
division tree — the static join-scheduler divide phase — not an ad-hoc
``range(n)``.  ``schedule_ticks`` turns that order into the classic
fill–drain tick table (for forward-only execution the 1F1B and GPipe
schedules coincide: every tick is a forward micro-step), ``bubble_fraction``
is its analytic idle share, and ``pipeline_forward`` executes the table over
a real device mesh with ``shard_map`` + ``ppermute``.
"""

from __future__ import annotations

import math
from typing import Callable, List

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core import WorkRange, bound_depth, build_plan


def microbatch_order(num_microbatches: int) -> List[int]:
    """Microbatch injection order = leaf order of a Kvik division tree.

    ``bound_depth`` to ``ceil(log2 n)`` divides the microbatch range into
    singletons; the plan's left-to-right leaf traversal is the order the
    join scheduler would execute them in.
    """
    if num_microbatches < 1:
        raise ValueError(f"num_microbatches={num_microbatches} must be >= 1")
    n = num_microbatches
    depth = math.ceil(math.log2(n)) if n > 1 else 0
    plan = build_plan(bound_depth(WorkRange(0, n), depth))
    return [i for w in plan.leaves() for i in range(w.start, w.stop)]


def schedule_ticks(stages: int, num_microbatches: int) -> List[List[str]]:
    """Fill–drain tick table: ``table[t][s]`` is the microbatch id stage
    ``s`` processes at tick ``t`` (``"-"`` = bubble).  ``num_microbatches +
    stages - 1`` ticks; stage ``s`` starts at tick ``s``."""
    if stages < 1:
        raise ValueError(f"stages={stages} must be >= 1")
    order = microbatch_order(num_microbatches)
    n = len(order)
    table = []
    for t in range(n + stages - 1):
        row = []
        for s in range(stages):
            i = t - s
            row.append(str(order[i]) if 0 <= i < n else "-")
        table.append(row)
    return table


def bubble_fraction(stages: int, num_microbatches: int) -> float:
    """Idle share of the fill–drain schedule: ``(p-1) / (n + p - 1)``.

    Matches a brute-force count of ``"-"`` cells in ``schedule_ticks``
    (property-pinned in tests/test_dist_properties.py); driving microbatch
    count up is the only lever that amortizes the fixed fill+drain cost.
    """
    if stages < 1:
        raise ValueError(f"stages={stages} must be >= 1")
    if num_microbatches < 1:
        raise ValueError(f"num_microbatches={num_microbatches} must be >= 1")
    return (stages - 1) / (num_microbatches + stages - 1)


def pipeline_forward(stage_fn: Callable, ws, xs, mesh: Mesh, *,
                     axis: str = "pipe"):
    """Run ``xs`` through ``stages`` pipeline stages laid out on ``axis``.

    ``stage_fn(x_mb, w) -> y_mb`` is one stage; ``ws`` stacks per-stage
    weights on axis 0 (sharded one-per-device over ``axis``); ``xs`` has
    shape ``(num_microbatches, mb_batch, ...)``.  Each tick every device
    runs one forward micro-step and hands its activation to the right
    neighbor via ``ppermute`` — the tick sequence is exactly
    ``schedule_ticks``'s table, whose microbatch order came from the plan.
    Returns outputs in the original microbatch order, replicated.
    """
    stages = mesh.shape[axis]
    n_mb = xs.shape[0]
    if ws.shape[0] != stages:
        raise ValueError(f"ws carries {ws.shape[0]} stages for a "
                         f"{stages}-wide '{axis}' mesh axis")
    order = microbatch_order(n_mb)
    shift = [(i, i + 1) for i in range(stages - 1)]

    def spmd(w_blk, xs_all):
        idx = jax.lax.axis_index(axis)
        w = w_blk[0]
        state = jnp.zeros_like(xs_all[0])
        outs = jnp.zeros_like(xs_all)
        for t in range(n_mb + stages - 1):
            # receive last tick's activation from the left neighbor
            recv = jax.lax.ppermute(state, axis, perm=shift) \
                if stages > 1 else state
            feed = order[t] if t < n_mb else order[-1]
            inp = jnp.where(idx == 0, xs_all[feed], recv)
            out = stage_fn(inp, w)
            emit = t - (stages - 1)
            if 0 <= emit < n_mb:     # drain window of the last stage
                outs = jnp.where(idx == stages - 1,
                                 outs.at[order[emit]].set(out), outs)
            state = out
        # replicate the last stage's buffer so out_specs can be unsharded
        return jax.lax.psum(
            jnp.where(idx == stages - 1, outs, jnp.zeros_like(outs)), axis)

    nd = xs.ndim
    return shard_map(
        spmd, mesh=mesh,
        in_specs=(P(axis, *([None] * (ws.ndim - 1))), P(*([None] * nd))),
        out_specs=P(*([None] * nd)), check_rep=False)(ws, xs)


__all__ = ["microbatch_order", "schedule_ticks", "bubble_fraction",
           "pipeline_forward"]
