"""Expert-parallel MoE dispatch over ``shard_map`` — the paper's stable
sort as the distribution mechanism.

``moe_shard_map`` is the sort-based (dropless) MoE layer of
``repro.models.moe`` pushed onto a mesh: tokens are stably sorted by expert
id (§3.7 — intra-expert token order is preserved, so the combine stays a
cheap scatter-add), the token rows shard over ``data``, and the expert bank
shards over ``model``.  Each device computes the contribution of *its*
experts to every routed row via a one-hot segment mask (out-of-range ids
one-hot to zero rows, so masking is free) and a single ``psum`` over the
expert axis folds the partials — no all-to-all materialization of
per-expert buffers, no capacity drops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models.layers import Params
from ..models.moe import sort_combine, sort_route


def moe_shard_map(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                  mesh: Mesh, *, axis: str = "model",
                  token_axis: str = "data", sort_fn=None):
    """Expert-parallel dropless MoE.  x: (B, S, D) → (out, aux_loss).

    Matches ``moe_sort_dispatch`` exactly — the shared ``sort_route`` /
    ``sort_combine`` prelude/epilogue with the expert GEMMs partitioned
    over the expert axis; ``sort_fn`` as in that function (default stable
    argsort, pass the Pallas merge sort to make dispatch literally §3.7).
    """
    E = cfg.num_experts
    n = mesh.shape[axis]
    if E % n:
        raise ValueError(f"'{axis}' size {n} must divide num_experts={E}")
    B, S, _ = x.shape
    xd, sorted_e, sorted_tok, sorted_p, aux = sort_route(params, cfg, x,
                                                         sort_fn)
    rows = B * S * cfg.top_k
    dpn = mesh.shape.get(token_axis, 1)
    tok = token_axis if (token_axis in mesh.shape and rows % dpn == 0) \
        else None
    e_per = E // n

    def spmd(gate_blk, up_blk, down_blk, xd_blk, e_blk):
        idx = jax.lax.axis_index(axis)
        # local expert ids; out-of-range one-hots to an all-zero row
        seg = jax.nn.one_hot(e_blk - idx * e_per, e_per, dtype=xd_blk.dtype)
        h = jnp.einsum("td,edf,te->tf", xd_blk, gate_blk, seg)
        u = jnp.einsum("td,edf,te->tf", xd_blk, up_blk, seg)
        y = jnp.einsum("tf,efd,te->td", jax.nn.silu(h) * u, down_blk, seg)
        return jax.lax.psum(y, axis)

    y = shard_map(
        spmd, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None),
                  P(axis, None, None), P(tok, None), P(tok)),
        out_specs=P(tok, None), check_rep=False)(
        params["gate"], params["up"], params["down"], xd, sorted_e)

    return sort_combine(params, cfg, x, y, sorted_tok, sorted_p), aux


__all__ = ["moe_shard_map"]
