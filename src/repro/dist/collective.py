"""Latency-hiding collective matmuls via ``shard_map``.

The two decompositions every tensor-parallel transformer layer reduces to
(cf. "Overlap communication with computation", Wang et al.'s collective
matmul — and on our side: each is a reduction tree over per-shard tasks,
i.e. a Kvik plan executed by GSPMD):

* ``allgather_matmul`` — column-parallel projection.  Activations arrive
  row-sharded; instead of one blocking all-gather followed by the matmul,
  each device multiplies the row block it currently holds and ring-shifts
  (``ppermute``) the block, overlapping transfer with compute.
* ``matmul_reducescatter`` — row-parallel projection.  Each device holds a
  contraction slice, computes a full-size partial product, and the partials
  ring-accumulate so every step's transfer overlaps the next chunk's add;
  rows end up scattered over the axis.

Both return the mathematically exact ``x @ w`` (pinned in tests/test_dist).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def allgather_matmul(x: jnp.ndarray, w: jnp.ndarray, mesh: Mesh, *,
                     axis: str = "model") -> jnp.ndarray:
    """``x @ w`` with x row-sharded and w column-sharded over ``axis``.

    Per device: n_axis steps of (local block matmul, ring-shift block) —
    the all-gather is decomposed into the steps so compute hides it.
    """
    n = mesh.shape[axis]
    M, K = x.shape
    N = w.shape[1]
    if M % n or N % n:
        raise ValueError(f"allgather_matmul: axis '{axis}' size {n} must "
                         f"divide M={M} and N={N}")
    ring = [(i, (i + 1) % n) for i in range(n)]

    def spmd(x_blk, w_blk):
        idx = jax.lax.axis_index(axis)
        mb = x_blk.shape[0]
        y = jnp.zeros((M, w_blk.shape[1]), x_blk.dtype)
        blk = x_blk
        for step in range(n):
            src = (idx - step) % n       # original owner of `blk`
            y = jax.lax.dynamic_update_slice(y, blk @ w_blk, (src * mb, 0))
            if step < n - 1:
                blk = jax.lax.ppermute(blk, axis, perm=ring)
        return y

    return shard_map(spmd, mesh=mesh,
                     in_specs=(P(axis, None), P(None, axis)),
                     out_specs=P(None, axis), check_rep=False)(x, w)


def matmul_reducescatter(x: jnp.ndarray, w: jnp.ndarray, mesh: Mesh, *,
                         axis: str = "model") -> jnp.ndarray:
    """``x @ w`` with the contraction dim K sharded over ``axis``.

    Each device computes its K-slice partial, then the partials
    ring-accumulate row-chunk by row-chunk (a hand-rolled reduce-scatter:
    every step's ``ppermute`` overlaps the next local add), leaving device
    ``d`` with the finished rows ``[d·M/n, (d+1)·M/n)``.
    """
    n = mesh.shape[axis]
    M, K = x.shape
    if M % n or K % n:
        raise ValueError(f"matmul_reducescatter: axis '{axis}' size {n} "
                         f"must divide M={M} and K={K}")
    mb = M // n
    ring = [(i, (i + 1) % n) for i in range(n)]

    def spmd(x_blk, w_blk):
        idx = jax.lax.axis_index(axis)
        partial = x_blk @ w_blk                      # (M, N) partial sums
        if n == 1:
            return partial

        def chunk(d):                                # rows destined for d
            return jax.lax.dynamic_slice_in_dim(partial, d * mb, mb, 0)

        # ring reduce-scatter: the packet destined for row-chunk c starts at
        # device c+1 and travels forward; device d adds chunk (d-k-1) at hop
        # k, so after n-1 hops it holds its own chunk, fully reduced.
        acc = chunk((idx - 1) % n)
        for k in range(1, n):
            acc = jax.lax.ppermute(acc, axis, perm=ring) \
                + chunk((idx - k - 1) % n)
        return acc

    return shard_map(spmd, mesh=mesh,
                     in_specs=(P(None, axis), P(axis, None)),
                     out_specs=P(axis, None), check_rep=False)(x, w)


__all__ = ["allgather_matmul", "matmul_reducescatter"]
