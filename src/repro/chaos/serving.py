"""Serving chaos: overload bursts, deadline storms and slot death.

The train-side injectors (harness.py) key off train-step indices; serving
chaos keys off *engine*-step indices and wall-clock arrivals.  Three pieces:

* :class:`SlotDeathInjector` — ``on_step`` hook for :func:`replay`: kills
  the planned decode lanes (:class:`~repro.core.faults.SlotDeath`) via the
  engine's ``kill_slot`` chaos hook.  The killed request is requeued at the
  queue front and re-served from scratch; greedy decode is deterministic,
  so its final tokens must match the undisturbed run exactly (pinned by
  tests/test_chaos.py).
* trace generators — :func:`slo_mix_trace` builds a deterministic
  multi-tenant arrival trace (per-class counts, deadlines, priorities;
  arrival offsets from a seeded RNG).  Scaling ``span_s`` down is the
  overload knob: the same work in a third of the span is a 3× burst.
* :func:`replay` — wall-clock replay of a trace against a live engine:
  submit when due, step while pending, account every request exactly once
  (served / shed / rejected).  ``on_step(step, engine)`` is the chaos
  injection point — the same shape as the trainer's ``on_step`` hook.

Determinism caveat: arrivals and prompts are seed-deterministic, but the
interleaving of admissions with decode ticks is wall-clock dependent — so
serving invariants are *conservation* and *class* properties (every rid
accounted once, shed work 100% batch/background, exact per-request tokens),
never step-exact schedules.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.faults import FaultPlan
from ..serve.engine import QueueFull, Request

# chaos traces avoid token ids colliding with pad (0) / the bench EOS
_PROMPT_LO = 8


@dataclasses.dataclass(frozen=True)
class TraceItem:
    """One planned arrival (pure data; the Request is built at replay)."""

    rid: int
    arrival: float                # seconds from trace start
    prompt_len: int
    max_new: int
    slo: str = "batch"
    priority: int = 0
    deadline_s: Optional[float] = None
    tenant: str = "default"


def make_request(item: TraceItem, vocab: int, seed: int = 0) -> Request:
    """Deterministic request for a trace item (prompt from rid+seed)."""
    rng = np.random.default_rng(1_000_003 * item.rid + seed)
    prompt = rng.integers(_PROMPT_LO, vocab,
                          size=item.prompt_len).astype(np.int32)
    return Request(rid=item.rid, prompt=prompt, max_new=item.max_new,
                   slo=item.slo, priority=item.priority,
                   deadline_s=item.deadline_s, tenant=item.tenant)


def slo_mix_trace(seed: int, *, span_s: float,
                  classes: Dict[str, Dict], start_rid: int = 0
                  ) -> Tuple[TraceItem, ...]:
    """A deterministic multi-tenant trace: ``classes`` maps an SLO class to
    ``dict(n=..., prompt_len=..., max_new=..., deadline_s=..., priority=...,
    tenants=(...))``; each class's ``n`` arrivals land uniformly at random
    (seeded) in ``[0, span_s)`` and tenants round-robin.  Returned sorted
    by arrival — shrink ``span_s`` to turn the same offered work into an
    overload burst."""
    rng = np.random.default_rng(seed)
    items: List[TraceItem] = []
    rid = start_rid
    for slo in sorted(classes):
        spec = classes[slo]
        tenants = spec.get("tenants", ("default",))
        for k in range(spec["n"]):
            items.append(TraceItem(
                rid=rid, arrival=float(rng.uniform(0.0, span_s)),
                prompt_len=spec["prompt_len"], max_new=spec["max_new"],
                slo=slo, priority=spec.get("priority", 0),
                deadline_s=spec.get("deadline_s"),
                tenant=tenants[k % len(tenants)]))
            rid += 1
    return tuple(sorted(items, key=lambda it: (it.arrival, it.rid)))


@dataclasses.dataclass
class ReplayResult:
    served: List[Request]
    shed: List[Request]
    rejected: List[Request]

    @property
    def all_requests(self) -> List[Request]:
        return self.served + self.shed + self.rejected

    def conserved(self, trace: Sequence[TraceItem]) -> bool:
        """Every trace rid accounted for exactly once, nothing invented."""
        seen = [r.rid for r in self.all_requests]
        return sorted(seen) == sorted(it.rid for it in trace) \
            and len(set(seen)) == len(seen)

    def latencies(self, slo: Optional[str] = None) -> List[float]:
        """Submit→done wall seconds (served + shed; a shed request's
        latency is its time-to-drop — the user-visible wait)."""
        return [r.t_done - r.t_submit for r in self.served + self.shed
                if (slo is None or r.slo == slo) and r.t_done is not None]


def replay(engine, trace: Sequence[TraceItem], *, vocab: int,
           seed: int = 0,
           on_step: Optional[Callable[[int, object], None]] = None,
           max_wall_s: float = 300.0) -> ReplayResult:
    """Replay a trace against a live engine in wall-clock time: submit each
    item once its arrival passes, step while the engine has work, inject
    chaos via ``on_step``.  Every submission ends up in exactly one of
    served / shed / rejected."""
    items = sorted(trace, key=lambda it: (it.arrival, it.rid))
    served: List[Request] = []
    shed: List[Request] = []
    rejected: List[Request] = []
    i, step = 0, 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while i < len(items) and items[i].arrival <= now:
            r = make_request(items[i], vocab, seed)
            i += 1
            try:
                engine.submit(r)
            except QueueFull:
                rejected.append(r)
        if engine.pending:
            for r in engine.step():
                (shed if r.shed else served).append(r)
            if on_step is not None:
                on_step(step, engine)
            step += 1
        elif i < len(items):
            time.sleep(min(0.0005, max(0.0, items[i].arrival - now)))
        else:
            break
        if now > max_wall_s:
            raise TimeoutError(
                f"replay exceeded {max_wall_s}s with {len(items) - i} "
                f"arrivals outstanding")
    return ReplayResult(served=served, shed=shed, rejected=rejected)


class SlotDeathInjector:
    """``on_step`` hook for :func:`replay`: kill the planned decode lanes.

    A planned death whose lane is empty at the step fires as a no-op (the
    plan is index-driven, the lane assignment is wall-clock dependent);
    ``killed`` records the (step, slot) pairs that actually hit."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.killed: List[Tuple[int, int]] = []

    def __call__(self, step: int, engine) -> None:
        for sd in self.plan.slot_deaths_at(step):
            if engine.kill_slot(sd.slot):
                self.killed.append((step, sd.slot))


__all__ = [
    "TraceItem", "ReplayResult", "SlotDeathInjector", "make_request",
    "slo_mix_trace", "replay",
]
