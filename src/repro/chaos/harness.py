"""Injectors wiring a FaultPlan's wall-clock events into train/serve hooks.

Each injector is a small callable matching one existing hook, so production
code carries no chaos-awareness beyond the hooks themselves:

* :class:`CheckpointIOFaults` → ``CheckpointManager.io_check`` — fails the
  k-th write *attempt* with ``OSError`` (the manager's retry-with-backoff
  then either absorbs it or surfaces it);
* :func:`corrupt_checkpoint`   → flips bytes of a saved ``arr_*.npy`` leaf
  or truncates ``manifest.json`` (restore must fail loudly via the per-leaf
  sha256 / JSON parse);
* :class:`SigtermInjector`     → ``Trainer.run(on_step=...)`` — delivers a
  real SIGTERM to this process at step k; the trainer's handler flips the
  preemption flag, honoured at the next step boundary;
* :class:`HostDeathInjector`   → ``Trainer.run(on_step=...)`` — raises
  :class:`HostLost` at step k, modelling a host vanishing with the step
  in flight: no final checkpoint runs, recovery must come from the last
  completed checkpoint + elastic re-mesh (see tests/test_chaos.py).

Determinism: every injector is driven by the plan's step/write indices —
no wall-clock, no RNG — so a chaos run is replayable from the plan alone.
"""

from __future__ import annotations

import os
import signal
from pathlib import Path
from typing import Optional

from ..core.faults import FaultPlan, HostDeath


class ChaosError(RuntimeError):
    """Base class for injected failures."""


class HostLost(ChaosError):
    """A host (block of devices) vanished mid-step."""

    def __init__(self, host: int, step: int, devices_per_host: int):
        super().__init__(f"host {host} lost at step {step}")
        self.host = host
        self.step = step
        self.devices_per_host = devices_per_host


class CheckpointIOFaults:
    """``io_check`` hook: raise OSError on the plan's k-th write attempt.

    Attempts are counted 1-based across this injector's lifetime, matching
    :class:`~repro.core.faults.CheckpointWriteFault.on_write`.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.attempts = 0

    def __call__(self) -> None:
        self.attempts += 1
        if self.plan.checkpoint_write_fails(self.attempts):
            raise OSError(
                f"injected checkpoint I/O fault on write attempt "
                f"{self.attempts}")


def corrupt_checkpoint(directory: str, step: int, *, target: str = "leaf",
                       leaf_index: int = 0) -> Path:
    """Corrupt a completed checkpoint in place; returns the damaged file.

    ``target="leaf"`` XOR-flips a byte in the middle of the leaf's data
    payload (header left intact so ``np.load`` succeeds and the sha256
    check is what catches it); ``target="manifest"`` truncates
    manifest.json to half (JSON parse fails)."""
    d = Path(directory) / f"step_{step:08d}"
    if target == "manifest":
        f = d / "manifest.json"
        txt = f.read_text()
        f.write_text(txt[:len(txt) // 2])
        return f
    f = d / f"arr_{leaf_index:05d}.npy"
    raw = bytearray(f.read_bytes())
    pos = max(128, len(raw) // 2)       # past the .npy header
    if pos >= len(raw):
        pos = len(raw) - 1
    raw[pos] ^= 0xFF
    f.write_bytes(bytes(raw))
    return f


class SigtermInjector:
    """``on_step`` hook: deliver SIGTERM to this process at planned steps."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.delivered: list = []

    def __call__(self, step: int, state=None) -> None:
        if self.plan.preempt_at(step):
            self.delivered.append(step)
            os.kill(os.getpid(), signal.SIGTERM)


class HostDeathInjector:
    """``on_step`` hook: raise :class:`HostLost` at the planned step."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def __call__(self, step: int, state=None) -> None:
        h: Optional[HostDeath] = self.plan.host_death_at(step)
        if h is not None:
            raise HostLost(h.host, step, h.devices_per_host)


__all__ = [
    "ChaosError", "CheckpointIOFaults", "HostDeathInjector", "HostLost",
    "SigtermInjector", "corrupt_checkpoint",
]
