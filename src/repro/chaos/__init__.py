"""repro.chaos — deterministic fault injection for the production layers.

The virtual-time half of a :class:`~repro.core.faults.FaultPlan` (worker
deaths, slowdowns) is consumed directly by the core Runtime; this package
consumes the wall-clock half: checkpoint I/O failures, on-disk corruption,
SIGTERM preemption and host death, injected into the train/serve layers
through their public hooks (``CheckpointManager.io_check``,
``Trainer.run(on_step=...)``).  See DESIGN.md for the fault model and
determinism guarantees.
"""

from .harness import (ChaosError, CheckpointIOFaults, HostDeathInjector,
                      HostLost, SigtermInjector, corrupt_checkpoint)
from .serving import (ReplayResult, SlotDeathInjector, TraceItem,
                      make_request, replay, slo_mix_trace)

__all__ = [
    "ChaosError", "CheckpointIOFaults", "HostDeathInjector", "HostLost",
    "SigtermInjector", "corrupt_checkpoint",
    "TraceItem", "ReplayResult", "SlotDeathInjector", "make_request",
    "slo_mix_trace", "replay",
]
