"""Train / serve step construction with full sharding specifications.

Gradient accumulation is scheduled by the paper's policy layer: the global
batch is a ``BatchWork`` divisible; a ``thief_splitting`` (or ``bound_depth``)
adaptor decides the microbatch tree; the plan's leaf count becomes the scan
length.  The reduction over microbatch gradients is the plan's symmetric
reduction tree, fused by XLA into the scan's accumulator — the static
equivalent of Kvik's join-scheduler reduce phase.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..core import BatchWork, bound_depth, build_plan, thief_splitting
from ..dist.sharding import (batch_shardings, cache_shardings, mesh_context,
                             moments_shardings, params_shardings)
from ..models.model import Model
from ..optim.adamw import (AdamWConfig, AdamWState, apply_updates, init_state)


# ---------------------------------------------------------------------------
# Microbatch planning (the Kvik hook)
# ---------------------------------------------------------------------------

def microbatch_plan(global_batch: int, dp: int, *,
                    tokens_per_seq: int,
                    target_tokens_per_replica: int = 8192,
                    policy: str = "thief") -> int:
    """Number of microbatches per step, from a Kvik plan.

    Work = BatchWork(0, global_batch).  The policy divides until a leaf's
    per-replica token count is ≈ target.  Returns the leaf count (power of
    two by construction, so the scan reshape is exact).
    """
    per_replica = max(1, global_batch // dp)
    want_leaves = max(1, math.ceil(
        per_replica * tokens_per_seq / target_tokens_per_replica))
    depth = max(0, math.ceil(math.log2(want_leaves)))
    depth = min(depth, int(math.log2(per_replica)) if per_replica > 1 else 0)
    if policy == "thief":
        work = thief_splitting(BatchWork(0, global_batch, min_size=dp),
                               p=1 << depth if depth else 1, init=depth)
    else:
        work = bound_depth(BatchWork(0, global_batch, min_size=dp), depth)
    plan = build_plan(work)
    n = plan.num_tasks()
    # leaves must evenly tile the batch for the scan reshape
    while global_batch % n != 0 or (global_batch // n) % dp != 0:
        n //= 2
    return max(1, n)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState

    def tree_flatten(self):  # pragma: no cover - registered below
        return ((self.params, self.opt), None)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, kids: TrainState(params=kids[0], opt=kids[1]))


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    num_microbatches: int = 1,
                    accum_dtype: str = "float32") -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_dtype``: gradient-accumulator dtype.  fp32 default; bf16 for
    parameterizations where the fp32 accumulator alone would blow the HBM
    budget (Jamba-398B: 1.5B params/chip → 6 GB fp32 accumulator)."""

    def loss_fn(params, mb):
        loss, metrics = model.loss_fn(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params = state.params

        if num_microbatches > 1:
            def split_mb(x):
                b = x.shape[0]
                mb = b // num_microbatches
                return x.reshape((num_microbatches, mb) + x.shape[1:])
            mbs = jax.tree.map(split_mb, batch)

            def body(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            adt = jnp.dtype(accum_dtype)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
            loss = lsum / num_microbatches
        else:
            (loss, _), grads = grad_fn(params, batch)

        new_params, new_opt, om = apply_updates(opt_cfg, params, grads,
                                                state.opt)
        metrics = {"loss": loss, **om}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


# ---------------------------------------------------------------------------
# Sharded step builders (used by launch/dryrun.py and launch/train.py)
# ---------------------------------------------------------------------------

def abstract_train_state(model: Model, opt_cfg: AdamWConfig):
    aparams = model.abstract_params()
    aopt = jax.eval_shape(partial(init_state, opt_cfg), aparams)
    return TrainState(params=aparams, opt=aopt)


def train_state_shardings(cfg: ModelConfig, model: Model,
                          opt_cfg: AdamWConfig, mesh: Mesh) -> TrainState:
    aparams = model.abstract_params()
    ps = params_shardings(cfg, aparams, mesh)
    ms = moments_shardings(cfg, aparams, mesh)
    opt = AdamWState(step=NamedSharding(mesh, P()), m=ms, v=ms)
    return TrainState(params=ps, opt=opt)


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, tokens, cache, lengths) → (next_tokens, new_cache).
    Greedy decode; the engine layer swaps in samplers."""

    def serve_step(params, tokens, cache, lengths):
        logits, new_cache = model.decode_step(params, tokens, cache, lengths)
        nxt = jnp.argmax(
            logits[:, :model.cfg.vocab_size], axis=-1).astype(jnp.int32)
        return nxt, new_cache

    return serve_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch)
        nxt = jnp.argmax(
            logits[:, :model.cfg.vocab_size], axis=-1).astype(jnp.int32)
        return nxt, cache
    return prefill_step


__all__ = [
    "TrainState", "microbatch_plan", "make_train_step",
    "abstract_train_state", "train_state_shardings", "make_serve_step",
    "make_prefill_step",
]
