"""repro.train"""
