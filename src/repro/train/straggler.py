"""Straggler mitigation — the paper's adaptive scheduler at cluster level.

In a synchronous SPMD step every replica computes identical shapes, so the
*device* work cannot be re-split mid-step.  What IS dynamic at 1000+ nodes:

1. **host-side work** (data fetch/augment/prefetch): re-split between steps
   with ``divide_at`` proportional to measured throughput — division happens
   only when a steal condition fires, and the amount moved halves the
   measured gap (the paper's "divide remaining work in two" rule);
2. **persistent stragglers**: detected by EWMA step-time deviation → the
   replica is marked for eviction and the elastic layer re-meshes without it
   (checkpoint → smaller mesh → resume);
3. **telemetry windows** grow geometrically between rebalances (the paper's
   nano-loop: amortize the cost of checking).

The policy's scheduling behaviour (steals, division counts, makespan) is
validated against the unified virtual-time runtime (``repro.core.runtime``)
in tests and the fannkuch benchmark; this module is the production wiring.
:func:`predicted_rebalance_gain` closes the loop: it asks that same runtime
— adaptive policy vs static partition, with per-replica speeds taken from
live telemetry — how much makespan a rebalance is expected to recover, so
eviction/rebalance decisions can be justified by the simulated policy
rather than a hand-tuned threshold alone.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import (AdaptivePolicy, BatchWork, CostModel, Runtime,
                    StaticPartitionPolicy)


def predicted_rebalance_gain(step_times: List[float], *,
                             items: int = 100_000, seed: int = 0) -> float:
    """Expected makespan ratio static/adaptive for the measured speeds.

    ``step_times`` are per-replica step times (e.g. the telemetry EWMA);
    speeds are their reciprocals, normalized to the fastest replica.  A
    return of 1.3 means the steal-driven policy is predicted to finish the
    same work 1.3× sooner than the current static equal shares — i.e. the
    imbalance is worth a rebalance.  Both simulations run on the unified
    Runtime, so the comparison is engine-for-engine fair.
    """
    t = np.asarray(step_times, dtype=float)
    p = len(t)
    if p == 0 or float(t.min()) <= 0:   # zero/negative = telemetry not ready
        return 1.0
    speeds = [float(s) for s in (t.min() / np.maximum(t, 1e-12))]
    cost = CostModel(per_item=1.0)
    work = lambda: BatchWork(0, items)
    static = Runtime(p, cost, StaticPartitionPolicy(num_blocks=p),
                     speeds=speeds).run(work())
    # cap the nano size so micro-loop boundaries (steal-service points) keep
    # occurring late in the run — late steals are exactly what absorbs a
    # straggler that telemetry only reveals mid-flight
    adapt = Runtime(p, cost, AdaptivePolicy(nano_cap=max(1, items // (8 * p))),
                    seed=seed, speeds=speeds).run(work())
    if adapt.makespan <= 0:
        return 1.0
    return static.makespan / adapt.makespan


@dataclasses.dataclass
class TelemetryBuffer:
    """Per-replica EWMA of step times (seconds)."""

    num_replicas: int
    alpha: float = 0.25

    def __post_init__(self):
        self.ewma = np.zeros(self.num_replicas)
        self.count = np.zeros(self.num_replicas, dtype=int)

    def record(self, replica: int, step_time: float) -> None:
        if self.count[replica] == 0:
            self.ewma[replica] = step_time
        else:
            self.ewma[replica] = (self.alpha * step_time
                                  + (1 - self.alpha) * self.ewma[replica])
        self.count[replica] += 1

    def record_all(self, times: List[float]) -> None:
        for i, t in enumerate(times):
            self.record(i, t)

    @property
    def ready(self) -> bool:
        return bool((self.count > 0).all())


@dataclasses.dataclass
class AdaptiveRebalancer:
    """Steal-driven re-splitting of host-side work shares.

    ``maybe_rebalance`` fires only when the slowest replica exceeds
    ``threshold`` × median (the steal condition) AND the geometric check
    window has elapsed (the nano-loop).  On firing, the share delta moved is
    half the measured imbalance — the adaptive scheduler's divide-in-two.
    """

    num_replicas: int
    threshold: float = 1.3
    first_window: int = 4
    window_growth: float = 2.0
    max_window: int = 256

    def __post_init__(self):
        self.shares = np.ones(self.num_replicas) / self.num_replicas
        self.window = self.first_window
        self.steps_since = 0
        self.rebalances = 0
        self.steals = 0

    def maybe_rebalance(self, telemetry: TelemetryBuffer
                        ) -> Optional[List[float]]:
        self.steps_since += 1
        if self.steps_since < self.window or not telemetry.ready:
            return None
        self.steps_since = 0
        t = telemetry.ewma
        med = float(np.median(t))
        worst = int(np.argmax(t))
        if t[worst] <= self.threshold * med or med <= 0:
            # no steal request: grow the check window (un-stolen micro-loop)
            self.window = min(int(self.window * self.window_growth),
                              self.max_window)
            return None
        # steal: move half the overload from the slowest to the fastest
        best = int(np.argmin(t))
        overload = (t[worst] - med) / max(t[worst], 1e-9)
        delta = 0.5 * overload * self.shares[worst]
        self.shares[worst] -= delta
        self.shares[best] += delta
        self.shares = np.maximum(self.shares, 1e-3)
        self.shares /= self.shares.sum()
        self.window = self.first_window          # reset (nano-loop reset)
        self.rebalances += 1
        self.steals += 1
        return list(self.shares)

    def predicted_gain(self, telemetry: TelemetryBuffer, *,
                       items: int = 100_000, seed: int = 0) -> float:
        """Virtual-time estimate of what rebalancing is worth right now
        (static/adaptive makespan ratio for the current telemetry)."""
        if not telemetry.ready:
            return 1.0
        return predicted_rebalance_gain(list(telemetry.ewma), items=items,
                                        seed=seed)


@dataclasses.dataclass
class StragglerDetector:
    """Persistent-straggler detection → elastic eviction decision."""

    threshold: float = 1.8
    patience: int = 3

    def __post_init__(self):
        self.strikes: Dict[int, int] = {}

    def check(self, telemetry: TelemetryBuffer) -> Optional[int]:
        """Returns a replica id to evict, or None."""
        if not telemetry.ready:
            return None
        t = telemetry.ewma
        med = float(np.median(t))
        for r in range(len(t)):
            if t[r] > self.threshold * med:
                self.strikes[r] = self.strikes.get(r, 0) + 1
                if self.strikes[r] >= self.patience:
                    return r
            else:
                self.strikes[r] = 0
        return None


__all__ = ["TelemetryBuffer", "AdaptiveRebalancer", "StragglerDetector",
           "predicted_rebalance_gain"]
