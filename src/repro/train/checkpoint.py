"""Fault-tolerant checkpointing: atomic, async, reshardable.

Layout::

    <dir>/step_000042.tmp-<nonce>/   (write)
        manifest.json                (tree structure, shapes, dtypes, meta)
        arr_00000.npy ...            (leaves, host order)
    <dir>/step_000042/               (atomic rename once complete)

Guarantees:
* **atomicity** — a checkpoint either exists completely or not at all
  (rename is atomic on POSIX); interrupted saves leave only .tmp dirs which
  are garbage-collected on restart,
* **async** — the device→host copy happens synchronously (cheap), the disk
  write on a worker thread; ``wait()`` joins before the next save or exit,
* **resharding restore** — leaves are restored with ``jax.device_put`` onto
  whatever shardings the *current* mesh prescribes, so restore works across
  mesh changes (elastic re-meshing, pod count changes),
* **integrity** — manifest carries per-leaf byte sizes, a per-leaf sha256 of
  the saved bytes and a config fingerprint; corrupted ``arr_*.npy`` bytes or
  a mismatched config fail loudly at restore,
* **retry** — transient I/O failures (``OSError``) during a save are retried
  ``retries`` times with exponential backoff; the ``io_check`` hook lets a
  fault plan inject failures deterministically (see :mod:`repro.chaos`).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bfloat16/float8 natively — save as a uint view and
# restore through the manifest's dtype string.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _to_savable(a: np.ndarray) -> np.ndarray:
    name = a.dtype.name
    if name in _EXOTIC:
        return a.view(_EXOTIC[name][1])
    return a


def _from_saved(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return a.view(_EXOTIC[dtype_name][0])
    return a


def _tree_paths(tree: Any) -> List[str]:
    paths = []
    for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(p))
    return paths


def config_fingerprint(obj: Any) -> str:
    s = json.dumps(obj, sort_keys=True, default=str)
    return hashlib.sha256(s.encode()).hexdigest()[:16]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    fingerprint: str = ""
    retries: int = 0                # extra attempts after a failed write
    backoff_s: float = 0.0          # base sleep between attempts (doubles)
    # fault-injection / health hook: called once per write attempt; raising
    # OSError fails that attempt (and consumes a retry)
    io_check: Optional[Callable[[], None]] = None

    def __post_init__(self):
        self.dir = Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.gc_incomplete()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, *, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        leaves, treedef = jax.tree_util.tree_flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        manifest = {
            "step": step,
            "fingerprint": self.fingerprint,
            "time": time.time(),
            "extra": extra or {},
            "leaves": [{"path": p, "shape": list(a.shape),
                        "dtype": str(a.dtype), "bytes": int(a.nbytes),
                        "sha256": hashlib.sha256(
                            _to_savable(a).tobytes()).hexdigest()}
                       for p, a in zip(_tree_paths(state), host_leaves)],
        }

        def write_once():
            if self.io_check is not None:
                self.io_check()
            tmp = self.dir / f"step_{step:08d}.tmp-{os.getpid()}"
            tmp.mkdir(parents=True, exist_ok=True)
            for i, a in enumerate(host_leaves):
                np.save(tmp / f"arr_{i:05d}.npy", _to_savable(a))
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        def write():
            try:
                for attempt in range(self.retries + 1):
                    try:
                        write_once()
                        return
                    except OSError:
                        if attempt >= self.retries:
                            raise
                        if self.backoff_s:
                            time.sleep(self.backoff_s * (2 ** attempt))
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {e!r}") from e

    # --------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".json") or ".tmp-" in p.name:
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, abstract_state: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        if self.fingerprint and manifest["fingerprint"] and \
                manifest["fingerprint"] != self.fingerprint:
            raise ValueError(
                f"checkpoint fingerprint {manifest['fingerprint']} != "
                f"current config {self.fingerprint}")
        leaves, treedef = jax.tree_util.tree_flatten(abstract_state)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        if len(manifest["leaves"]) != len(leaves):
            raise ValueError(
                f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs "
                f"abstract {len(leaves)}")
        out = []
        for i, (ab, sh, meta) in enumerate(
                zip(leaves, shard_leaves, manifest["leaves"])):
            raw = np.load(d / f"arr_{i:05d}.npy")
            want = meta.get("sha256")   # absent in pre-sha256 checkpoints
            if want:
                got = hashlib.sha256(raw.tobytes()).hexdigest()
                if got != want:
                    raise ValueError(
                        f"checkpoint corruption: leaf {i} ({meta['path']}) "
                        f"sha256 {got[:12]}... != manifest {want[:12]}... "
                        f"in {d}")
            a = _from_saved(raw, meta["dtype"])
            if tuple(a.shape) != tuple(ab.shape):
                raise ValueError(f"shape mismatch at leaf {i} "
                                 f"({meta['path']}): {a.shape} vs {ab.shape}")
            a = a.astype(ab.dtype)
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.device_put(a))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def gc_incomplete(self) -> None:
        for p in self.dir.glob("*.tmp-*"):
            shutil.rmtree(p, ignore_errors=True)


__all__ = ["CheckpointManager", "config_fingerprint"]
