"""The training driver: data → jit'd step → checkpoints → fault handling.

Wiring of every fault-tolerance feature:
* atomic/async checkpoints every ``ckpt_every`` steps + at exit,
* preemption: SIGTERM/SIGINT set a flag checked at step boundaries (the
  by_blocks interruption point) → final checkpoint → clean exit,
* straggler telemetry: per-step times feed the AdaptiveRebalancer (host-side
  shares) and the StragglerDetector (elastic eviction escalations),
* resumability: pipeline state (a counter) rides in the checkpoint extras.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, DataPipeline, host_batch_to_device
from ..models.model import Model
from ..optim.adamw import AdamWConfig, init_state
from .checkpoint import CheckpointManager, config_fingerprint
from .step import TrainState, make_train_step
from .straggler import AdaptiveRebalancer, StragglerDetector, TelemetryBuffer


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    num_microbatches: int = 1
    num_replicas: int = 1          # telemetry granularity (DP replicas)
    ckpt_retries: int = 2          # transient-I/O retries per checkpoint
    ckpt_backoff_s: float = 0.0    # base retry backoff (doubles per attempt)


class Trainer:
    def __init__(self, model: Model, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, loop_cfg: LoopConfig, *,
                 step_fn: Optional[Callable] = None,
                 batch_shardings: Any = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.loop_cfg = loop_cfg
        self.pipeline = DataPipeline(data_cfg)
        self.batch_shardings = batch_shardings
        self.step_fn = jax.jit(
            step_fn or make_train_step(
                model, opt_cfg,
                num_microbatches=loop_cfg.num_microbatches),
            donate_argnums=0)
        fp = config_fingerprint({
            "model": dataclasses.asdict(model.cfg),
            "opt": dataclasses.asdict(opt_cfg)})
        self.ckpt = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep,
                                      fingerprint=fp,
                                      retries=loop_cfg.ckpt_retries,
                                      backoff_s=loop_cfg.ckpt_backoff_s)
        self.telemetry = TelemetryBuffer(loop_cfg.num_replicas)
        self.rebalancer = AdaptiveRebalancer(loop_cfg.num_replicas)
        self.detector = StragglerDetector()
        self._preempted = False
        self.metrics_log: list = []

    # ----------------------------------------------------------- lifecycle
    def install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def init_or_restore(self) -> TrainState:
        params = self.model.init(jax.random.PRNGKey(0))
        state = TrainState(params=params,
                           opt=init_state(self.opt_cfg, params))
        latest = self.ckpt.latest_step()
        if latest is not None:
            abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, extra = self.ckpt.restore(abstract)
            self.pipeline.state.step = int(extra.get("data_step", 0))
            self.start_step = latest
        else:
            self.start_step = 0
        return state

    def save(self, step: int, state: TrainState, blocking=False):
        self.ckpt.save(step, state,
                       extra={"data_step": self.pipeline.state.step},
                       blocking=blocking)

    # ----------------------------------------------------------------- run
    def run(self, state: Optional[TrainState] = None, *,
            on_step: Optional[Callable[[int, TrainState], None]] = None
            ) -> TrainState:
        """Run the loop.  ``on_step(step, state)`` fires after every completed
        step, before checkpointing — the chaos harness injects faults (SIGTERM,
        host death) there; anything it raises or signals is then handled at
        the step boundary, the by_blocks interruption point."""
        lc = self.loop_cfg
        if state is None:
            state = self.init_or_restore()
        step = getattr(self, "start_step", 0)
        while step < lc.total_steps and not self._preempted:
            batch = host_batch_to_device(self.pipeline.next_batch(),
                                         self.batch_shardings)
            t0 = time.time()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            step += 1
            if on_step is not None:
                on_step(step, state)
            self.telemetry.record(step % lc.num_replicas, dt)
            shares = self.rebalancer.maybe_rebalance(self.telemetry)
            evict = self.detector.check(self.telemetry)
            if step % lc.log_every == 0 or step == lc.total_steps:
                row = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "lr": float(metrics["lr"]),
                       "step_time_s": round(dt, 4)}
                if shares is not None:
                    row["rebalanced_shares"] = [round(s, 3) for s in shares]
                if evict is not None:
                    row["evict_candidate"] = evict
                self.metrics_log.append(row)
                print(f"[train] {row}", flush=True)
            if step % lc.ckpt_every == 0:
                self.save(step, state)
        # final (or preemption) checkpoint
        self.save(step, state, blocking=True)
        if self._preempted:
            print(f"[train] preempted at step {step}; checkpoint saved.",
                  flush=True)
        return state


__all__ = ["Trainer", "LoopConfig"]
