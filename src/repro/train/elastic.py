"""Elastic scaling: re-mesh + reshard on device-count changes.

On node failure / preemption / capacity change:
  1. checkpoint (or use the last atomic one),
  2. build the best mesh over the surviving devices,
  3. restore with the NEW mesh's shardings (checkpoint.py restores through
     host memory, so any (old mesh → new mesh) transition works),
  4. resume — the data pipeline is counter-based, so no samples are lost or
     repeated.

Mesh choice: keep the model axis as large as parallelism rules allow (params
must still fit), give the rest to data.  ``choose_mesh`` is deliberately
simple and fully tested at host scale (4 → 2 devices in tests).
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from ..configs.base import ModelConfig


def _factor_pairs(n: int) -> List[Tuple[int, int]]:
    out = []
    f = 1
    while f * f <= n:
        if n % f == 0:
            out.append((n // f, f))
            out.append((f, n // f))
        f += 1
    return sorted(set(out))


def choose_mesh(num_devices: int, *, prefer_model: int = 16,
                devices: Optional[list] = None) -> Mesh:
    """Largest model axis ≤ prefer_model that divides the device count."""
    best = (num_devices, 1)
    for data, model in _factor_pairs(num_devices):
        if model <= prefer_model and model > best[1]:
            best = (data, model)
    data, model = best
    devs = devices if devices is not None else jax.devices()
    devs = devs[:data * model]
    return Mesh(np.array(devs).reshape(data, model), ("data", "model"))


@dataclasses.dataclass
class ElasticController:
    """Orchestrates checkpoint → re-mesh → restore cycles."""

    prefer_model: int = 16

    def remesh(self, surviving_devices: list) -> Mesh:
        return choose_mesh(len(surviving_devices),
                           prefer_model=self.prefer_model,
                           devices=surviving_devices)

    def reshard_state(self, ckpt_mgr, abstract_state, new_shardings):
        """Restore the latest checkpoint under new-mesh shardings."""
        state, extra = ckpt_mgr.restore(abstract_state,
                                        shardings=new_shardings)
        return state, extra


__all__ = ["choose_mesh", "ElasticController"]
