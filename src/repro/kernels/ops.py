"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False when a real
TPU backend is present — the kernels are the TPU TARGET; interpret mode
executes the kernel bodies in Python for correctness validation.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash_attention
from .flash_decode import flash_decode as _flash_decode
from .merge_sort import argsort as _argsort


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _flash_attention(q, k, v, causal=causal, block_q=block_q,
                            block_k=block_k,
                            interpret=_default_interpret())


@partial(jax.jit, static_argnames=("block_k",))
def flash_decode(q, k_cache, v_cache, lengths, *, block_k: int = 512):
    return _flash_decode(q, k_cache, v_cache, lengths, block_k=block_k,
                         interpret=_default_interpret())


@partial(jax.jit, static_argnames=("num_key_bits", "tile"))
def stable_argsort(keys, *, num_key_bits: int = 12, tile: int = 1024):
    return _argsort(keys, num_key_bits=num_key_bits, tile=tile,
                    interpret=_default_interpret())


__all__ = ["flash_attention", "flash_decode", "stable_argsort"]
