"""Pallas TPU flash attention (GQA, causal) — the train/prefill hot spot.

Schedule = the Kvik tile plan from ``repro.models.attention.attn_chunk_sizes``
realized on hardware: grid (batch, q-heads, q-blocks, kv-blocks); the kv-block
axis is the innermost (sequential on TPU) so the running-softmax state lives
in VMEM scratch across kv steps.  BlockSpecs stage (bq, hd) / (bk, hd) tiles
HBM→VMEM; MXU dims (bq, bk, hd) are multiples of 128 by construction.

GQA is handled in the index map: the kv-head for q-head h is ``h // G`` — no
repeated-KV materialization, matching the jnp reference.

Validated in interpret mode against ``ref.attention_reference`` over shape ×
dtype sweeps (tests/test_kernels.py).  On real TPUs the causal upper-triangle
blocks would be pruned from the grid (q-dependent kv extent); in this
container the mask branch keeps correctness (the compiled dry-run uses the
jnp blockwise path, which does prune — see DESIGN.md).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                # (bk, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (bq, bk)
    if causal:
        iq = pl.program_id(2)
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + \
        jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, Sq, H, hd)  k,v: (B, Sk, KV, hd) → (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "seq must tile evenly"
    nq, nk = Sq // bq, Sk // bk

    qt = q.transpose(0, 2, 1, 3)   # (B, H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)   # (B, KV, Sk, hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


__all__ = ["flash_attention"]
