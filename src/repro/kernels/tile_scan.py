"""Single-launch tile scan with cross-tile carry — the shared machinery.

A scan of ``n`` elements on a launch-per-node tree costs ``log n`` kernel
launches; on TPU the grid of one ``pallas_call`` already executes
*sequentially*, so a carry held in VMEM scratch turns the whole scan into
ONE launch: each grid step loads its block, combines the incoming carry
with a block-local scan, writes the block's result, and folds the block
total into the carry for the next step.  This is the "tile-local scan +
cross-tile carry" pattern the multi-tile radix sort uses to turn the
``(num_tiles, R)`` digit-histogram matrix into global base offsets
(``radix_sort.py``), and the same machinery a chunked associative scan for
the SSM recurrence needs (ROADMAP item 5) — hence the generic ``combine``
/ ``unit`` monoid interface rather than a hard-coded sum.

Restrictions: ``combine`` must be associative with identity ``unit`` (the
scan is a left fold of carries, so commutativity is NOT required), and the
carry must have the same dtype/shape as one element.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .launch_trace import record

Combine = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _scan_kernel(x_ref, o_ref, carry_ref, *, combine, unit, inclusive):
    """One block of the scan.  ``carry_ref`` (VMEM scratch, shape (1, 1))
    persists across the sequential grid steps and holds the fold of every
    earlier block."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _():
        carry_ref[...] = jnp.full_like(carry_ref, unit)

    x = x_ref[...]                                  # (1, block)
    incl = jax.lax.associative_scan(combine, x, axis=1)
    carry = carry_ref[0, 0]
    if inclusive:
        local = incl
    else:
        # exclusive = inclusive shifted right with the identity in front
        local = jnp.concatenate(
            [jnp.full((1, 1), unit, x.dtype), incl[:, :-1]], axis=1)
    o_ref[...] = combine(jnp.full_like(local, carry), local)
    carry_ref[0, 0] = combine(carry, incl[0, -1])


def tile_scan(x: jnp.ndarray, *, block: int = 256,
              combine: Optional[Combine] = None, unit=0,
              inclusive: bool = False, interpret: bool = True) -> jnp.ndarray:
    """Exclusive (default) or inclusive scan of a 1-D array in ONE launch.

    ``combine``/``unit`` default to ``(+, 0)``.  The grid iterates blocks in
    order; the cross-block carry lives in a (1, 1) VMEM scratch cell, so the
    launch count is 1 regardless of ``n`` — the property the multi-tile
    radix sort (and every bench row pinned on launch counts) relies on.
    """
    if combine is None:
        combine = jnp.add
    n = x.shape[0]
    if n == 0:
        return x
    block = max(1, min(block, n))
    n_pad = -(-n // block) * block
    if n_pad != n:
        # identity padding: the tail never affects carries ahead of it and
        # padded outputs are sliced off
        x = jnp.concatenate([x, jnp.full((n_pad - n,), unit, x.dtype)])
    nb = n_pad // block
    kernel = functools.partial(_scan_kernel, combine=combine, unit=unit,
                               inclusive=inclusive)
    record("tile_scan", (nb,), [(1, block)])
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, 1), x.dtype)],
        interpret=interpret,
    )(x.reshape(nb, block))
    return out.reshape(n_pad)[:n]


def histogram_offsets(hist: jnp.ndarray, *, block: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """Global base offsets from a ``(num_tiles, R)`` digit-histogram matrix.

    ``offsets[t, d]`` = #(elements with digit < d anywhere) + #(elements
    with digit d in tiles before ``t``) — the destination of tile ``t``'s
    first digit-``d`` element in a stable multi-tile counting pass.  That
    is exactly the exclusive scan of the histogram flattened digit-major
    (transpose → scan → transpose back), one ``tile_scan`` launch.
    """
    nt, r = hist.shape
    flat = hist.T.reshape(nt * r)
    scanned = tile_scan(flat, block=block, interpret=interpret)
    return scanned.reshape(r, nt).T


# ---------------------------------------------------------------------------
# generalized monoid scans: pytree elements, matrix/elementwise combines
# ---------------------------------------------------------------------------
#
# ``tile_scan`` handles scalar monoids (one 1-D array, scalar carry).  The
# SSM recurrences need more: Mamba's selective scan folds *pairs*
# ``(dA, dBx)`` under an affine combine, and the mLSTM carry is a 4-tuple
# ``(log_decay, max_state, C, n)`` whose combine rescales matrix leaves.
# Both are still monoids, so the single-launch carry pattern is unchanged —
# only the carry is now a pytree of VMEM scratch buffers, one per leaf,
# and the block-local scan is ``lax.associative_scan`` over the pytree.
#
# Two layouts share one kernel:
# * ``tree_scan``      — leaves (L, *feat_i), feat shapes may differ per
#   leaf (matrix monoids).  Blocks span the full feature extent; only the
#   scan axis is tiled, so ``combine`` sees leaves shaped (block, *feat_i).
# * ``batched_scan``   — leaves (B, L, *feat), identical shapes, combine
#   strictly elementwise.  Features are flattened and tiled by ``fblock``
#   (columns are independent under an elementwise combine), grid
#   (B, nf, nb) with nb fastest, carry reset at each block-row start.


def _tree_scan_kernel(*refs, nleaves, treedef, feat_shapes, combine, units,
                      inclusive, block):
    """One (grid-step) block of the pytree scan.  ``refs`` is
    ``x_refs + carry0_refs + out_refs + carry_scratch_refs`` in leaf order;
    the scratch pytree persists across the sequential grid and holds the
    fold of every earlier block along the scan axis."""
    n = nleaves
    x_refs, c0_refs = refs[:n], refs[n:2 * n]
    o_refs, carry_refs = refs[2 * n:3 * n], refs[3 * n:]
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _():
        # entering a fresh (batch, feature-tile) row: seed from carry0
        for cr, c0 in zip(carry_refs, c0_refs):
            cr[...] = c0[0]

    def load_x(ref, fs):
        v = ref[0]                                  # (block, fbl)
        return v.reshape((block,) + fs) if fs is not None else v

    xs = treedef.unflatten(
        [load_x(r, fs) for r, fs in zip(x_refs, feat_shapes)])
    incl = jax.lax.associative_scan(combine, xs, axis=0)

    carry = treedef.unflatten(
        [cr[...].reshape(fs) if fs is not None else cr[0]
         for cr, fs in zip(carry_refs, feat_shapes)])
    carry_b = jax.tree.map(
        lambda c: jnp.broadcast_to(c[None], (block,) + c.shape), carry)

    if inclusive:
        local = incl
    else:
        # exclusive = inclusive shifted right with the identity in front
        local = jax.tree.map(
            lambda t, u: jnp.concatenate(
                [jnp.full_like(t[:1], u), t[:-1]], axis=0), incl, units)
    out = combine(carry_b, local)
    for o_ref, leaf in zip(o_refs, jax.tree.leaves(out)):
        o_ref[0] = leaf.reshape(block, -1)

    new_carry = combine(carry, jax.tree.map(lambda t: t[-1], incl))
    for cr, leaf in zip(carry_refs, jax.tree.leaves(new_carry)):
        cr[...] = leaf.reshape(1, -1)


def _tree_scan_call(leaves, c0_leaves, fbls, feat_shapes, treedef, combine,
                    units, inclusive, block, interpret, kind):
    """Shared pallas_call: leaves are (G, L_pad, F_pad_i) with
    F_pad_i = nf * fbls[i] for a common nf; carry0 leaves (G, 1, F_pad_i)."""
    G, L_pad, _ = leaves[0].shape
    nb = L_pad // block
    nf = leaves[0].shape[2] // fbls[0]
    grid = (G, nf, nb)
    record(kind, grid, [(1, block, f) for f in fbls])
    kernel = functools.partial(
        _tree_scan_kernel, nleaves=len(leaves), treedef=treedef,
        feat_shapes=feat_shapes, combine=combine, units=units,
        inclusive=inclusive, block=block)
    in_specs = (
        [pl.BlockSpec((1, block, f), lambda g, fi, b: (g, b, fi))
         for f in fbls]
        + [pl.BlockSpec((1, 1, f), lambda g, fi, b: (g, 0, fi))
           for f in fbls])
    out_specs = [pl.BlockSpec((1, block, f), lambda g, fi, b: (g, b, fi))
                 for f in fbls]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves],
        scratch_shapes=[pltpu.VMEM((1, f), l.dtype)
                        for f, l in zip(fbls, leaves)],
        interpret=interpret,
    )(*leaves, *c0_leaves)


def _check_units(units, treedef) -> list:
    u_leaves, u_def = jax.tree.flatten(units)
    if u_def != treedef:
        raise ValueError(f"units structure {u_def} != elements {treedef}")
    return u_leaves


def tree_scan(xs: Any, *, combine: Callable[[Any, Any], Any], units: Any,
              carry0: Optional[Any] = None, inclusive: bool = True,
              block: int = 128, interpret: bool = True,
              kind: str = "tree_scan") -> Any:
    """Associative scan over axis 0 of a pytree of (L, *feat_i) arrays in
    ONE launch.  Matrix monoids welcome: ``combine`` sees leaves shaped
    (block, *feat_i) and may rescale/contract trailing dims freely.

    ``units`` is a pytree of scalars (the identity element); ``carry0``
    optionally seeds the scan with a pytree of (*feat_i) leaves, so the
    inclusive output is ``carry0 ∘ e_0 ∘ … ∘ e_t`` and the exclusive output
    at t is the state *entering* element t.
    """
    leaves, treedef = jax.tree.flatten(xs)
    u_leaves = _check_units(units, treedef)
    L = leaves[0].shape[0]
    feat_shapes = [l.shape[1:] for l in leaves]
    fbls = [max(1, math.prod(fs)) for fs in feat_shapes]
    block = max(1, min(block, L))
    L_pad = -(-L // block) * block

    def prep(l, u):
        flat = l.reshape(L, -1)
        if L_pad != L:   # identity padding: the tail only affects padded rows
            flat = jnp.concatenate(
                [flat, jnp.full((L_pad - L, flat.shape[1]), u, l.dtype)], 0)
        return flat[None]                            # (1, L_pad, F)

    leaves3 = [prep(l, u) for l, u in zip(leaves, u_leaves)]
    if carry0 is None:
        c0_leaves = [jnp.full((1, 1, f), u, l.dtype)
                     for f, u, l in zip(fbls, u_leaves, leaves)]
    else:
        c0_flat, c0_def = jax.tree.flatten(carry0)
        if c0_def != treedef:
            raise ValueError(f"carry0 structure {c0_def} != {treedef}")
        c0_leaves = [jnp.asarray(c).astype(l.dtype).reshape(1, 1, -1)
                     for c, l in zip(c0_flat, leaves)]
    outs = _tree_scan_call(leaves3, c0_leaves, fbls, feat_shapes, treedef,
                           combine, units, inclusive, block, interpret, kind)
    return treedef.unflatten(
        [o[0, :L].reshape((L,) + fs) for o, fs in zip(outs, feat_shapes)])


def batched_scan(xs: Any, *, combine: Callable[[Any, Any], Any], units: Any,
                 carry0: Optional[Any] = None, inclusive: bool = True,
                 block: int = 128, fblock: int = 2048,
                 interpret: bool = True, kind: str = "tree_scan") -> Any:
    """Elementwise-monoid scan over axis 1 of a pytree of (B, L, *feat)
    arrays (identical shapes) in ONE launch.  Features are flattened and
    tiled by ``fblock`` — legal exactly because an elementwise combine
    never mixes feature columns — so VMEM holds (block, fblock) tiles
    regardless of the feature extent.  ``carry0`` leaves are (B, *feat)."""
    leaves, treedef = jax.tree.flatten(xs)
    u_leaves = _check_units(units, treedef)
    shape = leaves[0].shape
    if any(l.shape != shape for l in leaves):
        raise ValueError("batched_scan needs identically-shaped leaves; "
                         "use tree_scan for matrix monoids")
    B, L = shape[:2]
    feat = shape[2:]
    F = max(1, math.prod(feat))
    block = max(1, min(block, L))
    L_pad = -(-L // block) * block
    fblock = max(1, min(fblock, F))
    F_pad = -(-F // fblock) * fblock

    def prep(l, u, with_L):
        flat = l.reshape((B, -1, F))
        n_l = L_pad - flat.shape[1] if with_L else 0
        if n_l:
            flat = jnp.concatenate(
                [flat, jnp.full((B, n_l, F), u, l.dtype)], axis=1)
        if F_pad != F:   # unit-fill is arbitrary here; columns never mix
            flat = jnp.concatenate(
                [flat, jnp.full((B, flat.shape[1], F_pad - F), u, l.dtype)],
                axis=2)
        return flat

    leaves3 = [prep(l, u, True) for l, u in zip(leaves, u_leaves)]
    if carry0 is None:
        c0_leaves = [jnp.full((B, 1, F_pad), u, l.dtype)
                     for u, l in zip(u_leaves, leaves)]
    else:
        c0_flat, c0_def = jax.tree.flatten(carry0)
        if c0_def != treedef:
            raise ValueError(f"carry0 structure {c0_def} != {treedef}")
        c0_leaves = [prep(c.reshape(B, 1, F).astype(l.dtype), u, False)
                     for c, u, l in zip(c0_flat, u_leaves, leaves)]
    fbls = [fblock] * len(leaves)
    feat_shapes = [None] * len(leaves)   # keep tiles flat: combine is
    outs = _tree_scan_call(              # elementwise, shape-agnostic
        leaves3, c0_leaves, fbls, feat_shapes, treedef, combine, units,
        inclusive, block, interpret, kind)
    return treedef.unflatten(
        [o[:, :L, :F].reshape((B, L) + feat) for o in outs])


__all__ = ["tile_scan", "tree_scan", "batched_scan", "histogram_offsets"]
