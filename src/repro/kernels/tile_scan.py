"""Single-launch tile scan with cross-tile carry — the shared machinery.

A scan of ``n`` elements on a launch-per-node tree costs ``log n`` kernel
launches; on TPU the grid of one ``pallas_call`` already executes
*sequentially*, so a carry held in VMEM scratch turns the whole scan into
ONE launch: each grid step loads its block, combines the incoming carry
with a block-local scan, writes the block's result, and folds the block
total into the carry for the next step.  This is the "tile-local scan +
cross-tile carry" pattern the multi-tile radix sort uses to turn the
``(num_tiles, R)`` digit-histogram matrix into global base offsets
(``radix_sort.py``), and the same machinery a chunked associative scan for
the SSM recurrence needs (ROADMAP item 5) — hence the generic ``combine``
/ ``unit`` monoid interface rather than a hard-coded sum.

Restrictions: ``combine`` must be associative with identity ``unit`` (the
scan is a left fold of carries, so commutativity is NOT required), and the
carry must have the same dtype/shape as one element.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .launch_trace import record

Combine = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _scan_kernel(x_ref, o_ref, carry_ref, *, combine, unit, inclusive):
    """One block of the scan.  ``carry_ref`` (VMEM scratch, shape (1, 1))
    persists across the sequential grid steps and holds the fold of every
    earlier block."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _():
        carry_ref[...] = jnp.full_like(carry_ref, unit)

    x = x_ref[...]                                  # (1, block)
    incl = jax.lax.associative_scan(combine, x, axis=1)
    carry = carry_ref[0, 0]
    if inclusive:
        local = incl
    else:
        # exclusive = inclusive shifted right with the identity in front
        local = jnp.concatenate(
            [jnp.full((1, 1), unit, x.dtype), incl[:, :-1]], axis=1)
    o_ref[...] = combine(jnp.full_like(local, carry), local)
    carry_ref[0, 0] = combine(carry, incl[0, -1])


def tile_scan(x: jnp.ndarray, *, block: int = 256,
              combine: Optional[Combine] = None, unit=0,
              inclusive: bool = False, interpret: bool = True) -> jnp.ndarray:
    """Exclusive (default) or inclusive scan of a 1-D array in ONE launch.

    ``combine``/``unit`` default to ``(+, 0)``.  The grid iterates blocks in
    order; the cross-block carry lives in a (1, 1) VMEM scratch cell, so the
    launch count is 1 regardless of ``n`` — the property the multi-tile
    radix sort (and every bench row pinned on launch counts) relies on.
    """
    if combine is None:
        combine = jnp.add
    n = x.shape[0]
    if n == 0:
        return x
    block = max(1, min(block, n))
    n_pad = -(-n // block) * block
    if n_pad != n:
        # identity padding: the tail never affects carries ahead of it and
        # padded outputs are sliced off
        x = jnp.concatenate([x, jnp.full((n_pad - n,), unit, x.dtype)])
    nb = n_pad // block
    kernel = functools.partial(_scan_kernel, combine=combine, unit=unit,
                               inclusive=inclusive)
    record("tile_scan", (nb,), [(1, block)])
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, 1), x.dtype)],
        interpret=interpret,
    )(x.reshape(nb, block))
    return out.reshape(n_pad)[:n]


def histogram_offsets(hist: jnp.ndarray, *, block: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """Global base offsets from a ``(num_tiles, R)`` digit-histogram matrix.

    ``offsets[t, d]`` = #(elements with digit < d anywhere) + #(elements
    with digit d in tiles before ``t``) — the destination of tile ``t``'s
    first digit-``d`` element in a stable multi-tile counting pass.  That
    is exactly the exclusive scan of the histogram flattened digit-major
    (transpose → scan → transpose back), one ``tile_scan`` launch.
    """
    nt, r = hist.shape
    flat = hist.T.reshape(nt * r)
    scanned = tile_scan(flat, block=block, interpret=interpret)
    return scanned.reshape(r, nt).T


__all__ = ["tile_scan", "histogram_offsets"]
