"""Chunked associative scans for the SSM recurrences (ROADMAP item 4).

A linear recurrence ``h_t = a_t · h_{t-1} + b_t`` is the composition of
affine maps, and affine maps form a monoid::

    (a1, b1) ∘ (a2, b2) = (a1·a2,  b2 + a2·b1)      unit (1, 0)

so the whole recurrence is ONE associative scan — the paper's
"sequence of parallel operations" shape.  On a launch-per-node tree that
scan costs ``log n`` launches; here it reuses the ``tile_scan`` carry
pattern (tile-local ``lax.associative_scan`` + a cross-tile carry pytree in
VMEM scratch, the same machinery ``histogram_offsets`` uses), so the launch
count is 1 regardless of sequence length.  Equivalence guarantee: for any
monoid the output equals ``jax.lax.associative_scan(combine, xs)`` seeded
with ``carry0`` — pinned by tests/test_ssm_scan.py and the
``BENCH_scan_ssm.json`` equivalence rows.

Two monoids ship here (see src/repro/models/DESIGN.md for derivations):

* ``affine_combine`` — Mamba's selective scan.  Elements are the
  discretized pairs ``(dA_t, dBx_t)``; seeding the carry with
  ``(1, h0)`` makes the scanned second component *be* the hidden states.
  Strictly elementwise, so ``batched_scan`` tiles the (Di·N) feature axis.
* ``logspace_affine_combine`` — the mLSTM chunk carry.  Elements
  ``(la, m, Ĉ, n̂)`` represent the stabilized affine map
  ``X ↦ exp(la)·X + exp(m)·(Ĉ, n̂)`` on the matrix memory; the combine
  max-rebases ``m`` so nothing ever overflows (unit uses ``LOG_ZERO``,
  not −inf: ``-inf − -inf = nan`` inside ``exp`` would poison the unit).
  Matrix leaves with different shapes → ``tree_scan`` (whole-feature
  blocks, only the chunk axis is tiled).

The public wrappers are jit-cached on shape so the serving hot loop never
retraces; ``*_ref`` twins (pure ``lax.scan`` / ``lax.associative_scan``)
are the benchmark baselines and the test oracles.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .tile_scan import batched_scan, tree_scan

LOG_ZERO = -1e30   # the repo-wide "log of zero" that survives exp/arith


# ---------------------------------------------------------------------------
# monoids
# ---------------------------------------------------------------------------

def affine_combine(a: Tuple[jnp.ndarray, jnp.ndarray],
                   b: Tuple[jnp.ndarray, jnp.ndarray]):
    """(gain, offset) pair monoid of ``h ↦ gain·h + offset`` maps."""
    a1, b1 = a
    a2, b2 = b
    return (a1 * a2, b2 + a2 * b1)


AFFINE_UNITS = (1.0, 0.0)


def logspace_affine_combine(a, b):
    """Stabilized log-space affine monoid for the mLSTM matrix memory.

    Elements ``(la, m, C, n)`` denote ``X ↦ exp(la)·X + exp(m)·(C, n)``
    with ``(C, n)`` stored at scale ``exp(m)`` — i.e. the true update is
    ``exp(m)·C``.  The combine rebases both terms onto
    ``m' = max(m1 + la2, m2)``, so every exponent is ≤ 0: no overflow for
    any gate magnitudes.  ``la`` never enters an exp by itself.
    """
    la1, m1, C1, n1 = a
    la2, m2, C2, n2 = b
    m = jnp.maximum(m1 + la2, m2)
    s1 = jnp.exp(m1 + la2 - m)
    s2 = jnp.exp(m2 - m)
    C = s1[..., None, None] * C1 + s2[..., None, None] * C2
    n = s1[..., None] * n1 + s2[..., None] * n2
    return (la1 + la2, m, C, n)


LOGSPACE_UNITS = (0.0, LOG_ZERO, 0.0, 0.0)


# ---------------------------------------------------------------------------
# jit-cached fixed-shape entry points
# ---------------------------------------------------------------------------

_JITS: Dict[Any, Callable] = {}


def _cached(key, build) -> Callable:
    fn = _JITS.get(key)
    if fn is None:
        fn = _JITS[key] = jax.jit(build())
    return fn


def mamba_assoc_scan(dA: jnp.ndarray, dBx: jnp.ndarray, h0: jnp.ndarray, *,
                     block: int = 64, fblock: int = 2048,
                     interpret: bool = True) -> jnp.ndarray:
    """Chunked selective scan: ``h_t = dA_t · h_{t-1} + dBx_t`` over axis 1.

    dA, dBx: (B, c, Di, N) fp32;  h0: (B, Di, N) → states (B, c, Di, N),
    ONE pallas launch for any ``c``.
    """
    key = ("mamba", dA.shape, str(dA.dtype), block, fblock, interpret)

    def build():
        def run(dA, dBx, h0):
            _, states = batched_scan(
                (dA, dBx), combine=affine_combine, units=AFFINE_UNITS,
                carry0=(jnp.ones_like(h0), h0), inclusive=True,
                block=block, fblock=fblock, interpret=interpret,
                kind="ssm_scan")
            return states
        return run

    return _cached(key, build)(dA, dBx, h0)


def mamba_assoc_scan_ref(dA: jnp.ndarray, dBx: jnp.ndarray,
                         h0: jnp.ndarray) -> jnp.ndarray:
    """lax.associative_scan oracle (the pre-Pallas model path)."""
    prefA, within = jax.lax.associative_scan(affine_combine, (dA, dBx),
                                             axis=1)
    return within + prefA * h0[:, None]


def mamba_seq_scan_ref(dA: jnp.ndarray, dBx: jnp.ndarray,
                       h0: jnp.ndarray) -> jnp.ndarray:
    """Honest per-step lax.scan — the launch-per-step benchmark baseline."""
    def body(h, ab):
        a, b = ab
        h2 = a * h + b
        return h2, h2

    _, states = jax.lax.scan(
        body, h0, (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3)))
    return states.transpose(1, 0, 2, 3)


def mlstm_carry_scan(la: jnp.ndarray, mS: jnp.ndarray, Chat: jnp.ndarray,
                     nhat: jnp.ndarray, carry0, *, block: int = 32,
                     interpret: bool = True):
    """Exclusive monoid scan over the chunk axis → state ENTERING each chunk.

    la, mS: (nc, B, H);  Chat: (nc, B, H, dh, dh);  nhat: (nc, B, H, dh) —
    per-chunk summaries.  ``carry0 = (m0, C0, n0)`` is the state entering
    chunk 0.  Returns (la_ent, m_ent, C_ent, n_ent) with
    ``ent[k] = carry0 ∘ e_0 ∘ … ∘ e_{k-1}`` — one pallas launch.
    """
    m0, C0, n0 = carry0
    key = ("mlstm", la.shape, Chat.shape, str(la.dtype), block, interpret)

    def build():
        def run(la, mS, Chat, nhat, m0, C0, n0):
            return tree_scan(
                (la, mS, Chat, nhat), combine=logspace_affine_combine,
                units=LOGSPACE_UNITS,
                carry0=(jnp.zeros_like(m0), m0, C0, n0),
                inclusive=False, block=block, interpret=interpret,
                kind="ssm_scan")
        return run

    return _cached(key, build)(la, mS, Chat, nhat, m0, C0, n0)


def mlstm_carry_scan_ref(la, mS, Chat, nhat, carry0):
    """Sequential-fold oracle for the exclusive carry scan."""
    m0, C0, n0 = carry0
    c = (jnp.zeros_like(m0), m0, C0, n0)

    def body(c, e):
        return logspace_affine_combine(c, e), c

    _, ent = jax.lax.scan(body, c, (la, mS, Chat, nhat))
    return ent


__all__ = [
    "LOG_ZERO", "affine_combine", "AFFINE_UNITS",
    "logspace_affine_combine", "LOGSPACE_UNITS",
    "mamba_assoc_scan", "mamba_assoc_scan_ref", "mamba_seq_scan_ref",
    "mlstm_carry_scan", "mlstm_carry_scan_ref",
]
