"""Shared launch accounting for the sort kernels.

``trace_launches`` records every ``pallas_call`` the sort modules issue
while the context is open (it counts *traced* calls — open the context
around the first call of a jitted entry point, or around an un-jitted
one).  Both ``merge_sort`` and ``radix_sort`` report through
:func:`record`, so a fused pipeline's end-to-end launch count — the
per-task overhead the perf trajectory tracks — is visible from one place.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass
class LaunchRecord:
    kind: str                 # "tile_sort" | "merge_level" | "pack" | "unpack"
    grid: tuple
    max_block_elems: int      # largest single in/out block, in elements


_TRACE: Optional[List[LaunchRecord]] = None


@contextlib.contextmanager
def trace_launches():
    """Record every sort-kernel ``pallas_call`` issued while open."""
    global _TRACE
    prev, _TRACE = _TRACE, []
    try:
        yield _TRACE
    finally:
        _TRACE = prev


def record(kind: str, grid: Sequence[int],
           block_shapes: Sequence[Tuple[int, ...]]) -> None:
    """Append one launch record if a trace is open (no-op otherwise)."""
    if _TRACE is not None:
        _TRACE.append(LaunchRecord(
            kind=kind, grid=tuple(grid),
            max_block_elems=max(math.prod(b) for b in block_shapes)))


__all__ = ["LaunchRecord", "trace_launches", "record"]
