"""Pallas stable merge sort — the paper's §3.7 showcase, deployed for MoE
token dispatch.

Structure mirrors Kvik's sort, batched level-by-level for a compiled target
(full design note: ``src/repro/kernels/DESIGN.md``):

  1. the input is divided into tiles by a Kvik plan
     (``even_levels(bound_depth(...))``), whose
     :meth:`~repro.core.plan.Plan.sort_schedule` also carries the radix
     digit-pass metadata for the tile phase,
  2. each tile is sorted locally by an **in-kernel LSD radix sort**
     (``radix_sort.py``: r-bit digit passes, masked-cumsum ranks, one-hot
     matmul placement — no 1-D gathers; the seed's bitonic network kernel
     remains available as ``tile_sort`` / ``method="bitonic"``),
  3. sorted runs are fused pairwise, **one ``pallas_call`` per merge
     level**: ``grid=(num_pairs, blocks_per_pair)`` with merge-path
     (diagonal co-rank binary search) partitioning, ≤ 2·tile VMEM per
     program, ``log2(n/tile)`` launches total.  The kernel is lowered for
     real TPUs: 2-D ``(8, tile//8)`` blocks and the per-block ``la``
     co-rank scalar delivered in SMEM via ``PrefetchScalarGridSpec``
     (``interpret=True`` remains the tested default).

Stability: keys are packed as ``key << idx_bits | index`` into uint32 —
equal keys order by original index.  ``idx_bits`` is derived per call as
``ceil(log2(n))`` (``IDX_BITS = 20`` is the documented default cap), so
small batches admit keys up to ``2^(32 − ceil(log2(n)))``.  On the default
fused path the pack and the final ``& idx_mask`` unpack live *inside* the
first tile-sort and last merge-level kernels — ``argsort(jit=True)`` runs
zero standalone elementwise launches (``fused=False`` reconstructs them as
separate pack/unpack kernels for comparison; ``trace_launches`` shows the
two-launch drop).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import SeqWork, bound_depth, build_plan, even_levels
from .launch_trace import LaunchRecord, record, trace_launches
from .radix_sort import (SENTINEL, multi_tile_argsort_packed,  # noqa: F401 —
                         radix_tile_sort,                # SENTINEL re-export
                         radix_tile_sort_packed)

IDX_BITS = 20                 # documented default cap: tiles up to 2^20
IDX_MASK = (1 << IDX_BITS) - 1


def _pallas_call(kernel, *, kind: str, grid, in_specs, out_specs, out_shape,
                 interpret):
    record(kind, grid,
           [s.block_shape for s in in_specs] + [out_specs.block_shape])
    return pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                          out_specs=out_specs, out_shape=out_shape,
                          interpret=interpret)


# ---------------------------------------------------------------------------
# bitonic building blocks (pure jnp — used inside kernel bodies)
# ---------------------------------------------------------------------------

def _compare_exchange(x: jnp.ndarray, j: int, k: int) -> jnp.ndarray:
    """One bitonic stage via reshape/stride swaps — no gathers.

    Pairing (i, i^j) with i's j-bit clear is exactly the (row, lane) split of
    a ``(m/2j, 2, j)`` view; the direction bit ``i & k`` is constant per row
    because ``k ≥ 2j`` in every stage of the network.
    """
    m = x.shape[0]
    y = x.reshape(m // (2 * j), 2, j)
    a, b = y[:, 0, :], y[:, 1, :]
    lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
    row = jax.lax.broadcasted_iota(jnp.int32, (m // (2 * j), 1), 0)
    up = ((row * (2 * j)) & k) == 0
    return jnp.stack([jnp.where(up, lo, hi), jnp.where(up, hi, lo)],
                     axis=1).reshape(m)


def _bitonic_sort_network(x: jnp.ndarray) -> jnp.ndarray:
    """Full ascending bitonic sort of a power-of-two 1-D array."""
    n = x.shape[0]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            x = _compare_exchange(x, j, k)
            j //= 2
        k *= 2
    return x


def _bitonic_merge_network(x: jnp.ndarray) -> jnp.ndarray:
    """Monotonic merge of a bitonic input (ascending result).  All stages run
    ascending (``k = n``), so the direction select drops out entirely."""
    m = x.shape[0]
    j = m // 2
    while j >= 1:
        y = x.reshape(m // (2 * j), 2, j)
        a, b = y[:, 0, :], y[:, 1, :]
        x = jnp.stack([jnp.minimum(a, b), jnp.maximum(a, b)],
                      axis=1).reshape(m)
        j //= 2
    return x


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _tile_sort_kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_sort_network(x_ref[...])


def _pack_kernel(k_ref, o_ref, *, n, idx_bits):
    """Standalone elementwise pack launch (the ``fused=False`` path):
    ``key << idx_bits | index``, pad slots (index ≥ n) to the sentinel."""
    m = k_ref.shape[0]
    idx = jax.lax.broadcasted_iota(jnp.uint32, (m, 1), 0).reshape(m)
    packed = (k_ref[...].astype(jnp.uint32) << idx_bits) | idx
    o_ref[...] = jnp.where(idx < n, packed, jnp.uint32(SENTINEL))


def _unpack_kernel(x_ref, o_ref, *, idx_mask):
    """Standalone elementwise unpack launch (the ``fused=False`` path)."""
    o_ref[...] = (x_ref[...] & jnp.uint32(idx_mask)).astype(jnp.int32)


def _elementwise_imap(i):
    return (0,)


def _pack(keys: jnp.ndarray, *, n: int, idx_bits: int,
          interpret: bool) -> jnp.ndarray:
    m = keys.shape[0]
    return _pallas_call(
        functools.partial(_pack_kernel, n=n, idx_bits=idx_bits),
        kind="pack", grid=(1,),
        in_specs=[pl.BlockSpec((m,), _elementwise_imap)],
        out_specs=pl.BlockSpec((m,), _elementwise_imap),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.uint32),
        interpret=interpret)(keys)


def _unpack(x: jnp.ndarray, *, idx_mask: int, interpret: bool) -> jnp.ndarray:
    m = x.shape[0]
    return _pallas_call(
        functools.partial(_unpack_kernel, idx_mask=idx_mask),
        kind="unpack", grid=(1,),
        in_specs=[pl.BlockSpec((m,), _elementwise_imap)],
        out_specs=pl.BlockSpec((m,), _elementwise_imap),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret)(x)


def _merge_level_kernel(la_ref, a_ref, b_ref, o_ref, *, nb, unpack_mask):
    """Merge one fixed tile-sized output block of one run pair.

    ``a_ref``/``b_ref`` hold the merge-path windows for this block (≤ tile
    valid elements each, ``la`` of them from A); positions past the valid
    length are masked to the sentinel, the concat(A, reverse(B)) sequence is
    bitonic, and a gather-free bitonic merge finishes the block.  ``la`` is
    a scalar-prefetch input (SMEM on a real TPU): the whole co-rank table
    is available before the body runs, indexed by program id.  Blocks are
    2-D ``(8, tile//8)`` (sublane, lane) when the tile allows.  With
    ``unpack_mask`` set (last level of a fused argsort) the block is
    unpacked to the int32 order in-kernel.
    """
    shape = a_ref.shape
    tile = math.prod(shape)
    la = la_ref[pl.program_id(0) * nb + pl.program_id(1)]
    idx = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0).reshape(tile)
    a = jnp.where(idx < la, a_ref[...].reshape(tile), jnp.uint32(SENTINEL))
    b = jnp.where(idx < tile - la, b_ref[...].reshape(tile),
                  jnp.uint32(SENTINEL))
    merged = _bitonic_merge_network(jnp.concatenate([a, b[::-1]]))[:tile]
    if unpack_mask is not None:
        merged = (merged & jnp.uint32(unpack_mask)).astype(jnp.int32)
    o_ref[...] = merged.reshape(shape)


def tile_sort(x: jnp.ndarray, *, tile: int = 1024,
              interpret: bool = True) -> jnp.ndarray:
    """Sort each tile of a (n,) uint32 array locally with the bitonic
    network (the seed kernel — kept as the radix baseline and fallback).
    n % tile == 0."""
    n = x.shape[0]
    tile = min(tile, n)
    assert n % tile == 0 and (tile & (tile - 1)) == 0
    nt = n // tile
    return _pallas_call(
        _tile_sort_kernel,
        kind="tile_sort",
        grid=(nt,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# merge-path partitioning (driver-side, vectorized over every output block)
# ---------------------------------------------------------------------------

def _merge_path_starts(ab: jnp.ndarray, run: int, tile: int):
    """Co-rank split of every output diagonal of every run pair.

    ab: (num_pairs, 2, run) sorted runs.  For each pair and each diagonal
    ``d = b*tile`` (b = 0..2·run/tile), binary-search the smallest ``ia``
    with ``A[ia] > B[d-1-ia]`` — the count of A elements among the first
    ``d`` elements of the stable merge (ties go to A).  Returns
    ``(a_start, b_start, la)``, each (num_pairs, blocks_per_pair) int32.
    """
    num_pairs = ab.shape[0]
    nb = (2 * run) // tile
    a_run, b_run = ab[:, 0, :], ab[:, 1, :]
    d = jnp.arange(nb + 1, dtype=jnp.int32) * tile                 # (nb+1,)
    lo = jnp.broadcast_to(jnp.maximum(0, d - run), (num_pairs, nb + 1))
    hi = jnp.broadcast_to(jnp.minimum(d, run), (num_pairs, nb + 1))
    steps = max(1, run).bit_length() + 1

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) // 2
        a_mid = jnp.take_along_axis(a_run, jnp.clip(mid, 0, run - 1), axis=1)
        b_idx = jnp.clip(d[None, :] - 1 - mid, 0, run - 1)
        b_val = jnp.take_along_axis(b_run, b_idx, axis=1)
        go_right = a_mid <= b_val          # A[mid] within the first d merged
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    ia, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    a_start = ia[:, :-1]
    la = ia[:, 1:] - ia[:, :-1]
    b_start = d[None, :-1] - a_start
    return a_start, b_start, la


def _extract_windows(runs: jnp.ndarray, start: jnp.ndarray,
                     tile: int) -> jnp.ndarray:
    """Fixed tile-sized windows of each run at per-block start offsets.

    runs: (num_pairs, run), start: (num_pairs, nb) → (num_pairs, nb, tile).
    Reads past the run end are clamped; the kernel masks them out via ``la``.
    """
    num_pairs, run = runs.shape
    nb = start.shape[1]
    idx = start[:, :, None] + jnp.arange(tile, dtype=jnp.int32)[None, None, :]
    idx = jnp.minimum(idx, run - 1)
    src = jnp.broadcast_to(runs[:, None, :], (num_pairs, nb, run))
    return jnp.take_along_axis(src, idx, axis=2)


def _window_imap_2d(p, b, la):
    return (p, b, 0, 0)


def _window_imap_1d(p, b, la):
    return (p, b, 0)


def _merge_level(x: jnp.ndarray, *, run: int, tile: int, interpret: bool,
                 unpack_mask: Optional[int] = None) -> jnp.ndarray:
    """Merge all adjacent (2·run)-pairs of sorted runs in one pallas_call.

    Real-TPU lowering: window blocks are 2-D ``(8, tile//8)`` (sublane,
    lane) whenever ``tile % 8 == 0``, and the per-block ``la`` co-rank
    table travels as a scalar-prefetch operand (SMEM) instead of a blocked
    VMEM input.  ``unpack_mask`` fuses the final ``& idx_mask`` unpack of
    ``argsort`` into this launch (int32 output).
    """
    n = x.shape[0]
    assert n % (2 * run) == 0 and run % tile == 0
    num_pairs = n // (2 * run)
    nb = (2 * run) // tile                       # output blocks per pair
    ab = x.reshape(num_pairs, 2, run)
    a_start, b_start, la = _merge_path_starts(ab, run, tile)
    a_win = _extract_windows(ab[:, 0, :], a_start, tile)
    b_win = _extract_windows(ab[:, 1, :], b_start, tile)
    if tile % 8 == 0:
        block = (1, 1, 8, tile // 8)
        imap = _window_imap_2d
        a_win = a_win.reshape(num_pairs, nb, 8, tile // 8)
        b_win = b_win.reshape(num_pairs, nb, 8, tile // 8)
    else:
        block = (1, 1, tile)
        imap = _window_imap_1d
    out_dtype = jnp.uint32 if unpack_mask is None else jnp.int32
    kernel = functools.partial(_merge_level_kernel, nb=nb,
                               unpack_mask=unpack_mask)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_pairs, nb),
        in_specs=[pl.BlockSpec(block, imap), pl.BlockSpec(block, imap)],
        out_specs=pl.BlockSpec(block, imap),
    )
    record("merge_level", (num_pairs, nb), [block, block, block])
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(a_win.shape, out_dtype),
        interpret=interpret,
    )(la.reshape(-1).astype(jnp.int32), a_win, b_win)
    return out.reshape(n)


def merge_pair(a: jnp.ndarray, b: jnp.ndarray, *, tile: int = 1024,
               interpret: bool = True) -> jnp.ndarray:
    """Merge two sorted arrays of equal power-of-two length.

    Compatibility wrapper: one num_pairs=1 level of the level-batched
    merge-path kernel.
    """
    n = a.shape[0]
    return _merge_level(jnp.concatenate([a, b]), run=n, tile=min(tile, n),
                        interpret=interpret)


# ---------------------------------------------------------------------------
# composed sort (tile plan + level-batched merge schedule)
# ---------------------------------------------------------------------------

def _tile_plan(n: int, tile: int):
    """The Kvik plan driving the sort: ``even_levels(bound_depth(...))``
    over the index range.  even_levels parity is realized on the tile count
    (halve the tile once so the level count is even).  Returns
    ``(plan, depth, tile)``; plan is None when depth == 0."""
    tile = min(tile, n)
    depth = int(math.log2(n // tile))
    parity_ok = depth % 2 == 0
    if not parity_ok and tile >= 2:
        depth += 1          # even merge parity — the paper's even_levels
        tile = n >> depth   # concern, realized on the tile count
        parity_ok = True
    if depth == 0:
        return None, 0, tile
    # tile == 1 with odd depth cannot be re-tiled; run the odd schedule
    # rather than let even_levels force division below one element
    work = bound_depth(SeqWork(0, n, align=tile, min_size=tile), depth)
    plan = build_plan(even_levels(work) if parity_ok else work)
    return plan, depth, tile


def sort_u32(x: jnp.ndarray, *, tile: int = 1024, interpret: bool = True,
             method: str = "radix", total_bits: int = 32,
             digit_bits: int = 4, group: int = 8) -> jnp.ndarray:
    """Stable-ready sort of packed uint32 keys: tile sort, then one launch
    per merge level of the plan's schedule.

    The tile phase defaults to the in-kernel LSD radix sort
    (``ceil(total_bits / digit_bits)`` digit passes — pass ``total_bits``
    when the packed width is known, e.g. ``num_key_bits + idx_bits``);
    ``method="bitonic"`` keeps the seed's O(m·log²m) network.
    """
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"sort_u32 needs a power-of-two input, got n={n} "
                         "(pad first)")
    plan, depth, tile = _tile_plan(n, tile)
    if method == "radix":
        x = radix_tile_sort(x, tile=tile, total_bits=total_bits,
                            digit_bits=digit_bits, group=group,
                            interpret=interpret)
    elif method == "bitonic":
        x = tile_sort(x, tile=tile, interpret=interpret)
    else:
        raise ValueError(f"unknown tile-sort method {method!r}")
    if depth == 0:
        return x
    schedule = plan.merge_schedule()
    assert len(schedule) == depth
    for level in schedule:
        assert level.uniform, "sort plan must divide into uniform runs"
        x = _merge_level(x, run=level.run_length, tile=tile,
                         interpret=interpret)
    return x


def _argsort_impl(keys: jnp.ndarray, *, n: int, n_pad: int, tile: int,
                  interpret: bool, num_key_bits: int, idx_bits: int,
                  method: str, fused: bool, digit_bits: int,
                  group: int, strategy: str = "merge") -> jnp.ndarray:
    idx_mask = (1 << idx_bits) - 1
    if strategy == "multi_tile":
        # merge-tree-free path: 3 launches per digit pass (local sort +
        # histogram, cross-tile carry scan, global scatter), independent of
        # n.  n_pad is any multiple of the tile — no power-of-two padding.
        tile_mt = min(tile, n_pad)
        nt = n_pad // tile_mt
        if n_pad != n:
            pad = jnp.full((n_pad - n,), (1 << num_key_bits) - 1, keys.dtype)
            keys = jnp.concatenate([keys, pad])
        passes = None
        if nt > 1 and (nt & (nt - 1)) == 0:
            # power-of-two tile counts route through the plan so the
            # schedule metadata (mode, num_tiles, num_launches) is exercised
            depth = int(math.log2(nt))
            work = bound_depth(SeqWork(0, n_pad, align=tile_mt,
                                       min_size=tile_mt), depth)
            sched = build_plan(work).sort_schedule(
                sort_bits=num_key_bits, digit_bits=digit_bits,
                key_shift=idx_bits, mode="multi_tile")
            passes = sched.tile_passes
        return multi_tile_argsort_packed(
            keys, n=n, tile=tile_mt, num_key_bits=num_key_bits,
            idx_bits=idx_bits, digit_bits=digit_bits, group=group,
            passes=passes, interpret=interpret)[:n]
    plan, depth, tile = _tile_plan(n_pad, tile)
    if fused:
        # pack lives in the tile-sort kernel; pad keys carry the max key so
        # they sort to the tile end (the kernel emits sentinels for them)
        if n_pad != n:
            pad = jnp.full((n_pad - n,), (1 << num_key_bits) - 1, keys.dtype)
            keys = jnp.concatenate([keys, pad])
        schedule = (plan.sort_schedule(sort_bits=num_key_bits,
                                       digit_bits=digit_bits,
                                       key_shift=int(math.log2(tile)))
                    if plan is not None else None)
        x = radix_tile_sort_packed(
            keys, n=n, tile=tile, num_key_bits=num_key_bits,
            idx_bits=idx_bits, digit_bits=digit_bits, group=group,
            unpack=depth == 0, interpret=interpret,
            passes=schedule.tile_passes if schedule is not None else None)
        if depth == 0:
            return x[:n]
        levels = schedule.levels
        for i, level in enumerate(levels):
            assert level.uniform, "sort plan must divide into uniform runs"
            x = _merge_level(
                x, run=level.run_length, tile=tile, interpret=interpret,
                unpack_mask=idx_mask if i == len(levels) - 1 else None)
        return x[:n]
    # unfused: standalone pack/unpack launches around the plain u32 sort
    if n_pad != n:
        keys = jnp.concatenate(
            [keys, jnp.zeros((n_pad - n,), keys.dtype)])
    packed = _pack(keys, n=n, idx_bits=idx_bits, interpret=interpret)
    out = sort_u32(packed, tile=tile, interpret=interpret, method=method,
                   total_bits=num_key_bits + idx_bits, digit_bits=digit_bits,
                   group=group)
    return _unpack(out, idx_mask=idx_mask, interpret=interpret)[:n]


_ARGSORT_STATICS = ("n", "n_pad", "tile", "interpret", "num_key_bits",
                    "idx_bits", "method", "fused", "digit_bits", "group",
                    "strategy")


@functools.partial(jax.jit, static_argnames=_ARGSORT_STATICS)
def _argsort_jitted(keys, **kw):
    return _argsort_impl(keys, **kw)


def argsort(keys: jnp.ndarray, *, num_key_bits: int = 12, tile: int = 1024,
            interpret: bool = True, jit: bool = False, method: str = "radix",
            fused: Optional[bool] = None, digit_bits: int = 4,
            group: int = 8, strategy: Optional[str] = None) -> jnp.ndarray:
    """Stable argsort of small-integer keys (expert ids) — MoE dispatch entry.

    keys: (n,) int32 with values in [0, 2^num_key_bits).
    ``idx_bits = ceil(log2(n))`` is derived per call, so the hard error only
    fires when ``num_key_bits + idx_bits > 32`` — packing genuinely cannot
    fit (``IDX_BITS = 20`` is the documented default: the cap at the default
    ``num_key_bits=12``).

    ``strategy`` picks the global combine:

    * ``"multi_tile"`` (the default for small keys): multi-tile LSD radix —
      3 launches per digit pass (tile-local sort + histogram, cross-tile
      carry scan, global scatter), so the launch count depends only on
      ``num_key_bits``, not ``n``.  Input is padded to a multiple of the
      tile (pad keys sort to the end and are dropped).
    * ``"merge"``: the PR 2–4 merge tree — fused radix tile sort, then one
      launch per merge level (``log2(n/tile)``).  Auto-selected for wide
      keys (``num_key_bits > 16``), where ``ceil(bits/digit_bits)`` radix
      passes over the whole array would cost more launches and more data
      movement than the tree; also the only strategy for ``fused=False`` /
      ``method="bitonic"`` comparison pipelines.  Pads to a power of two.

    Both strategies are stable sorts of the same keys, so their outputs are
    bit-identical.  With ``jit=True`` the whole pipeline runs as one
    compiled program, cached per shape/config.
    """
    n = keys.shape[0]
    if fused is None:
        fused = method == "radix"
    if fused and method != "radix":
        raise ValueError("fused pack/unpack requires method='radix' "
                         "(the bitonic network kernel is the unfused "
                         "baseline)")
    if strategy is None:
        strategy = ("multi_tile" if fused and method == "radix"
                    and num_key_bits <= 16 else "merge")
    if strategy not in ("merge", "multi_tile"):
        raise ValueError(f"unknown argsort strategy {strategy!r}")
    if strategy == "multi_tile" and (not fused or method != "radix"):
        raise ValueError("strategy='multi_tile' requires the fused radix "
                         "pipeline (method='radix', fused=True)")
    idx_bits = max(1, (n - 1).bit_length()) if n else 1
    if num_key_bits + idx_bits > 32:
        raise ValueError(
            f"cannot pack: num_key_bits={num_key_bits} + idx_bits="
            f"{idx_bits} (= ceil(log2(n)) for n={n}) exceeds 32 — packed "
            "keys and indices would collide.  Shrink the batch or the key "
            f"range (n={n} admits keys up to 2^{32 - idx_bits})")
    if not isinstance(keys, jax.core.Tracer):
        kmax = int(jnp.max(keys)) if n else 0
        if kmax >= 1 << num_key_bits:
            raise ValueError(
                f"keys must be < 2^num_key_bits = {1 << num_key_bits}, got "
                f"max key {kmax}: packed keys would collide with the index "
                "bits and silently corrupt the order (raise num_key_bits)")
    if strategy == "multi_tile":
        # any whole number of tiles works — no power-of-two padding
        t_eff = min(tile, 1 << math.ceil(math.log2(max(2, n))))
        n_pad = -(-max(2, n) // t_eff) * t_eff
    else:
        n_pad = 1 << math.ceil(math.log2(max(2, n)))
    fn = _argsort_jitted if jit else _argsort_impl
    return fn(jnp.asarray(keys), n=n, n_pad=n_pad, tile=tile,
              interpret=interpret, num_key_bits=num_key_bits,
              idx_bits=idx_bits, method=method, fused=fused,
              digit_bits=digit_bits, group=group, strategy=strategy)


__all__ = ["argsort", "sort_u32", "tile_sort", "merge_pair",
           "trace_launches", "LaunchRecord", "IDX_BITS", "IDX_MASK"]
