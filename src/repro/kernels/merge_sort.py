"""Pallas stable merge sort — the paper's §3.7 showcase, deployed for MoE
token dispatch.

Structure mirrors Kvik's sort exactly:
  1. the input is divided into tiles by a Kvik plan (``even_levels`` ensures
     merge results land in the right buffer — here the tree is materialized
     functionally so the adaptor's concern becomes tile-count parity),
  2. each tile is sorted locally by a **bitonic network kernel** (the
     "sequential fallback" of the paper becomes the MXU/VPU-friendly
     fixed-size network — TPU adaptation, see DESIGN.md),
  3. sorted tiles are fused pairwise up the plan's **reduction tree** with a
     **bitonic merge kernel** (concat(A, reverse(B)) is bitonic; log2(n)
     monotonic compare-exchange stages finish the merge).

Stability: keys are packed as ``key << IDX_BITS | index`` into uint32 before
sorting — equal keys order by original index, which is what keeps intra-expert
token order deterministic in MoE dispatch (and what made the paper's sort
"stable").  Caller-facing API is ``argsort`` (returns the stable order).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import SeqWork, bound_depth, build_plan, even_levels

IDX_BITS = 20                 # tiles up to 2^20 elements
IDX_MASK = (1 << IDX_BITS) - 1


# ---------------------------------------------------------------------------
# bitonic building blocks (pure jnp — used inside kernel bodies)
# ---------------------------------------------------------------------------

def _compare_exchange(x: jnp.ndarray, j: int, k: int) -> jnp.ndarray:
    """One bitonic stage: partner = i ^ j, direction from bit k of i."""
    n = x.shape[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    partner = idx ^ j
    xp = x[partner]
    up = (idx & k) == 0
    lo = jnp.minimum(x, xp)
    hi = jnp.maximum(x, xp)
    is_lower = idx < partner
    want_lo = jnp.where(up, is_lower, ~is_lower)
    return jnp.where(want_lo, lo, hi)


def _bitonic_sort_network(x: jnp.ndarray) -> jnp.ndarray:
    """Full ascending bitonic sort of a power-of-two 1-D array."""
    n = x.shape[0]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            x = _compare_exchange(x, j, k)
            j //= 2
        k *= 2
    return x


def _bitonic_merge_network(x: jnp.ndarray) -> jnp.ndarray:
    """Monotonic merge of a bitonic input (ascending result)."""
    n = x.shape[0]
    j = n // 2
    while j >= 1:
        x = _compare_exchange(x, j, n)  # k = n → all ascending
        j //= 2
    return x


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _tile_sort_kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_sort_network(x_ref[...])


def _merge_kernel(a_ref, b_ref, o_ref, *, n: int):
    a = a_ref[...]
    b = b_ref[...]
    bi = jnp.concatenate([a, b[::-1]])     # bitonic by construction
    o_ref[...] = _bitonic_merge_network(bi)


def tile_sort(x: jnp.ndarray, *, tile: int = 1024,
              interpret: bool = True) -> jnp.ndarray:
    """Sort each tile of a (n,) uint32 array locally.  n % tile == 0."""
    n = x.shape[0]
    tile = min(tile, n)
    assert n % tile == 0 and (tile & (tile - 1)) == 0
    nt = n // tile
    return pl.pallas_call(
        _tile_sort_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)


def merge_pair(a: jnp.ndarray, b: jnp.ndarray, *,
               interpret: bool = True) -> jnp.ndarray:
    """Merge two sorted arrays of equal power-of-two length."""
    n = a.shape[0]
    return pl.pallas_call(
        functools.partial(_merge_kernel, n=n),
        in_specs=[pl.BlockSpec((n,), lambda: (0,)),
                  pl.BlockSpec((n,), lambda: (0,))],
        out_specs=pl.BlockSpec((2 * n,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((2 * n,), a.dtype),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# composed sort (tile plan + merge tree)
# ---------------------------------------------------------------------------

def sort_u32(x: jnp.ndarray, *, tile: int = 1024,
             interpret: bool = True) -> jnp.ndarray:
    """Stable-ready sort of packed uint32 keys via tile-sort + merge tree.

    The division is a Kvik plan: even_levels(bound_depth(...)) over the index
    range — exactly the adaptor stack the paper's sort uses.
    """
    n = x.shape[0]
    assert (n & (n - 1)) == 0, "power-of-two input (pad first)"
    tile = min(tile, n)
    depth = int(math.log2(n // tile))
    if depth % 2 == 1 and n >> (depth + 1) >= 2:
        depth += 1          # even merge parity — the paper's even_levels
        tile = n >> depth   # concern, realized on the tile count
    sorted_tiles = tile_sort(x, tile=tile, interpret=interpret)
    if depth == 0:
        return sorted_tiles

    plan = build_plan(bound_depth(SeqWork(0, n, align=tile, min_size=tile),
                                  depth))

    def leaf(work):
        return sorted_tiles[work.start:work.stop]

    def merge(a, b):
        return merge_pair(a, b, interpret=interpret)

    return plan.map_reduce(leaf, merge)


def argsort(keys: jnp.ndarray, *, num_key_bits: int = 12, tile: int = 1024,
            interpret: bool = True) -> jnp.ndarray:
    """Stable argsort of small-integer keys (expert ids) — MoE dispatch entry.

    keys: (n,) int32 with values < 2^num_key_bits; n padded to a power of two
    internally (pad keys sort to the end and are dropped).
    """
    n = keys.shape[0]
    n_pad = 1 << math.ceil(math.log2(max(2, n)))
    assert num_key_bits + IDX_BITS <= 32
    packed = (keys.astype(jnp.uint32) << IDX_BITS) | \
        jnp.arange(n, dtype=jnp.uint32)
    if n_pad != n:
        pad = jnp.full((n_pad - n,), jnp.uint32(0xFFFFFFFF))
        packed = jnp.concatenate([packed, pad])
    out = sort_u32(packed, tile=tile, interpret=interpret)
    order = (out & IDX_MASK).astype(jnp.int32)
    return order[:n]


__all__ = ["argsort", "sort_u32", "tile_sort", "merge_pair"]
