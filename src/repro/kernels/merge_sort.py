"""Pallas stable merge sort — the paper's §3.7 showcase, deployed for MoE
token dispatch.

Structure mirrors Kvik's sort, batched level-by-level for a compiled target
(full design note: ``src/repro/kernels/DESIGN.md``):

  1. the input is divided into tiles by a Kvik plan
     (``even_levels(bound_depth(...))`` — ``even_levels`` keeps the merge
     level count even, the paper's right-buffer concern),
  2. each tile is sorted locally by a **bitonic network kernel** whose
     compare-exchange is pure reshape/min/max (no 1-D gathers — TPU VPU
     friendly),
  3. sorted runs are fused pairwise, **one ``pallas_call`` per merge
     level**: the plan's :meth:`~repro.core.plan.Plan.merge_schedule` drives
     a ``grid=(num_pairs, blocks_per_pair)`` launch in which every grid cell
     produces one fixed ``tile``-sized slice of merged output.  Merge-path
     (diagonal co-rank binary search) partitioning assigns each cell a
     ≤ ``tile`` window of each input run, so per-program VMEM stays at
     2·tile inputs + 1·tile output *independent of n*, and the whole merge
     tree costs exactly ``log2(n/tile)`` kernel launches instead of the
     ``n/tile − 1`` per-pair launches of the old tree.

Stability: keys are packed as ``key << IDX_BITS | index`` into uint32 before
sorting — equal keys order by original index, which is what keeps intra-expert
token order deterministic in MoE dispatch (and what made the paper's sort
"stable").  Caller-facing API is ``argsort`` (returns the stable order).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import SeqWork, bound_depth, build_plan, even_levels

IDX_BITS = 20                 # tiles up to 2^20 elements
IDX_MASK = (1 << IDX_BITS) - 1
SENTINEL = 0xFFFFFFFF            # sorts after every real packed key


# ---------------------------------------------------------------------------
# launch accounting — lets tests pin the launch count and block footprint
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LaunchRecord:
    kind: str                 # "tile_sort" | "merge_level"
    grid: tuple
    max_block_elems: int      # largest single in/out block, in elements


_TRACE: Optional[List[LaunchRecord]] = None


@contextlib.contextmanager
def trace_launches():
    """Record every ``pallas_call`` this module issues while the context is
    open (counts *traced* calls — use on un-jitted entry points)."""
    global _TRACE
    prev, _TRACE = _TRACE, []
    try:
        yield _TRACE
    finally:
        _TRACE = prev


def _pallas_call(kernel, *, kind: str, grid, in_specs, out_specs, out_shape,
                 interpret):
    if _TRACE is not None:
        blocks = [s.block_shape for s in in_specs] + [out_specs.block_shape]
        _TRACE.append(LaunchRecord(
            kind=kind, grid=tuple(grid),
            max_block_elems=max(math.prod(b) for b in blocks)))
    return pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                          out_specs=out_specs, out_shape=out_shape,
                          interpret=interpret)


# ---------------------------------------------------------------------------
# bitonic building blocks (pure jnp — used inside kernel bodies)
# ---------------------------------------------------------------------------

def _compare_exchange(x: jnp.ndarray, j: int, k: int) -> jnp.ndarray:
    """One bitonic stage via reshape/stride swaps — no gathers.

    Pairing (i, i^j) with i's j-bit clear is exactly the (row, lane) split of
    a ``(m/2j, 2, j)`` view; the direction bit ``i & k`` is constant per row
    because ``k ≥ 2j`` in every stage of the network.
    """
    m = x.shape[0]
    y = x.reshape(m // (2 * j), 2, j)
    a, b = y[:, 0, :], y[:, 1, :]
    lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
    row = jax.lax.broadcasted_iota(jnp.int32, (m // (2 * j), 1), 0)
    up = ((row * (2 * j)) & k) == 0
    return jnp.stack([jnp.where(up, lo, hi), jnp.where(up, hi, lo)],
                     axis=1).reshape(m)


def _bitonic_sort_network(x: jnp.ndarray) -> jnp.ndarray:
    """Full ascending bitonic sort of a power-of-two 1-D array."""
    n = x.shape[0]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            x = _compare_exchange(x, j, k)
            j //= 2
        k *= 2
    return x


def _bitonic_merge_network(x: jnp.ndarray) -> jnp.ndarray:
    """Monotonic merge of a bitonic input (ascending result).  All stages run
    ascending (``k = n``), so the direction select drops out entirely."""
    m = x.shape[0]
    j = m // 2
    while j >= 1:
        y = x.reshape(m // (2 * j), 2, j)
        a, b = y[:, 0, :], y[:, 1, :]
        x = jnp.stack([jnp.minimum(a, b), jnp.maximum(a, b)],
                      axis=1).reshape(m)
        j //= 2
    return x


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _tile_sort_kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_sort_network(x_ref[...])


def _merge_level_kernel(la_ref, a_ref, b_ref, o_ref):
    """Merge one fixed tile-sized output block of one run pair.

    ``a_ref``/``b_ref`` hold the merge-path windows for this block (≤ tile
    valid elements each, ``la`` of them from A); positions past the valid
    length are masked to the sentinel, the concat(A, reverse(B)) sequence is
    bitonic, and a gather-free bitonic merge finishes the block.
    """
    tile = a_ref.shape[-1]
    la = la_ref[0, 0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0).reshape(tile)
    a = jnp.where(idx < la, a_ref[0, 0, :], jnp.uint32(SENTINEL))
    b = jnp.where(idx < tile - la, b_ref[0, 0, :], jnp.uint32(SENTINEL))
    merged = _bitonic_merge_network(jnp.concatenate([a, b[::-1]]))
    o_ref[0, 0, :] = merged[:tile]


def tile_sort(x: jnp.ndarray, *, tile: int = 1024,
              interpret: bool = True) -> jnp.ndarray:
    """Sort each tile of a (n,) uint32 array locally.  n % tile == 0."""
    n = x.shape[0]
    tile = min(tile, n)
    assert n % tile == 0 and (tile & (tile - 1)) == 0
    nt = n // tile
    return _pallas_call(
        _tile_sort_kernel,
        kind="tile_sort",
        grid=(nt,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# merge-path partitioning (driver-side, vectorized over every output block)
# ---------------------------------------------------------------------------

def _merge_path_starts(ab: jnp.ndarray, run: int, tile: int):
    """Co-rank split of every output diagonal of every run pair.

    ab: (num_pairs, 2, run) sorted runs.  For each pair and each diagonal
    ``d = b*tile`` (b = 0..2·run/tile), binary-search the smallest ``ia``
    with ``A[ia] > B[d-1-ia]`` — the count of A elements among the first
    ``d`` elements of the stable merge (ties go to A).  Returns
    ``(a_start, b_start, la)``, each (num_pairs, blocks_per_pair) int32.
    """
    num_pairs = ab.shape[0]
    nb = (2 * run) // tile
    a_run, b_run = ab[:, 0, :], ab[:, 1, :]
    d = jnp.arange(nb + 1, dtype=jnp.int32) * tile                 # (nb+1,)
    lo = jnp.broadcast_to(jnp.maximum(0, d - run), (num_pairs, nb + 1))
    hi = jnp.broadcast_to(jnp.minimum(d, run), (num_pairs, nb + 1))
    steps = max(1, run).bit_length() + 1

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) // 2
        a_mid = jnp.take_along_axis(a_run, jnp.clip(mid, 0, run - 1), axis=1)
        b_idx = jnp.clip(d[None, :] - 1 - mid, 0, run - 1)
        b_val = jnp.take_along_axis(b_run, b_idx, axis=1)
        go_right = a_mid <= b_val          # A[mid] within the first d merged
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    ia, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    a_start = ia[:, :-1]
    la = ia[:, 1:] - ia[:, :-1]
    b_start = d[None, :-1] - a_start
    return a_start, b_start, la


def _extract_windows(runs: jnp.ndarray, start: jnp.ndarray,
                     tile: int) -> jnp.ndarray:
    """Fixed tile-sized windows of each run at per-block start offsets.

    runs: (num_pairs, run), start: (num_pairs, nb) → (num_pairs, nb, tile).
    Reads past the run end are clamped; the kernel masks them out via ``la``.
    """
    num_pairs, run = runs.shape
    nb = start.shape[1]
    idx = start[:, :, None] + jnp.arange(tile, dtype=jnp.int32)[None, None, :]
    idx = jnp.minimum(idx, run - 1)
    src = jnp.broadcast_to(runs[:, None, :], (num_pairs, nb, run))
    return jnp.take_along_axis(src, idx, axis=2)


def _merge_level(x: jnp.ndarray, *, run: int, tile: int,
                 interpret: bool) -> jnp.ndarray:
    """Merge all adjacent (2·run)-pairs of sorted runs in one pallas_call."""
    n = x.shape[0]
    assert n % (2 * run) == 0 and run % tile == 0
    num_pairs = n // (2 * run)
    nb = (2 * run) // tile                       # output blocks per pair
    ab = x.reshape(num_pairs, 2, run)
    a_start, b_start, la = _merge_path_starts(ab, run, tile)
    a_win = _extract_windows(ab[:, 0, :], a_start, tile)
    b_win = _extract_windows(ab[:, 1, :], b_start, tile)
    out = _pallas_call(
        _merge_level_kernel,
        kind="merge_level",
        grid=(num_pairs, nb),
        in_specs=[pl.BlockSpec((1, 1), lambda p, b: (p, b)),
                  pl.BlockSpec((1, 1, tile), lambda p, b: (p, b, 0)),
                  pl.BlockSpec((1, 1, tile), lambda p, b: (p, b, 0))],
        out_specs=pl.BlockSpec((1, 1, tile), lambda p, b: (p, b, 0)),
        out_shape=jax.ShapeDtypeStruct((num_pairs, nb, tile), x.dtype),
        interpret=interpret,
    )(la, a_win, b_win)
    return out.reshape(n)


def merge_pair(a: jnp.ndarray, b: jnp.ndarray, *, tile: int = 1024,
               interpret: bool = True) -> jnp.ndarray:
    """Merge two sorted arrays of equal power-of-two length.

    Compatibility wrapper: one num_pairs=1 level of the level-batched
    merge-path kernel.
    """
    n = a.shape[0]
    return _merge_level(jnp.concatenate([a, b]), run=n, tile=min(tile, n),
                        interpret=interpret)


# ---------------------------------------------------------------------------
# composed sort (tile plan + level-batched merge schedule)
# ---------------------------------------------------------------------------

def sort_u32(x: jnp.ndarray, *, tile: int = 1024,
             interpret: bool = True) -> jnp.ndarray:
    """Stable-ready sort of packed uint32 keys: tile sort, then one launch
    per merge level of the plan's schedule.

    The division is a Kvik plan: ``even_levels(bound_depth(...))`` over the
    index range — the adaptor stack the paper's sort uses.  ``even_levels``
    parity is realized on the tile count (halve the tile once so the level
    count is even), then the plan's :meth:`merge_schedule` drives the levels.
    """
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"sort_u32 needs a power-of-two input, got n={n} "
                         "(pad first)")
    tile = min(tile, n)
    depth = int(math.log2(n // tile))
    parity_ok = depth % 2 == 0
    if not parity_ok and tile >= 2:
        depth += 1          # even merge parity — the paper's even_levels
        tile = n >> depth   # concern, realized on the tile count
        parity_ok = True
    x = tile_sort(x, tile=tile, interpret=interpret)
    if depth == 0:
        return x

    # tile == 1 with odd depth cannot be re-tiled; run the odd schedule
    # rather than let even_levels force division below one element
    work = bound_depth(SeqWork(0, n, align=tile, min_size=tile), depth)
    plan = build_plan(even_levels(work) if parity_ok else work)
    schedule = plan.merge_schedule()
    assert len(schedule) == depth
    for level in schedule:
        assert level.uniform, "sort plan must divide into uniform runs"
        x = _merge_level(x, run=level.run_length, tile=tile,
                         interpret=interpret)
    return x


def _argsort_impl(keys: jnp.ndarray, *, n: int, n_pad: int,
                  tile: int, interpret: bool) -> jnp.ndarray:
    packed = (keys.astype(jnp.uint32) << IDX_BITS) | \
        jnp.arange(n, dtype=jnp.uint32)
    if n_pad != n:
        pad = jnp.full((n_pad - n,), SENTINEL, jnp.uint32)
        packed = jnp.concatenate([packed, pad])
    out = sort_u32(packed, tile=tile, interpret=interpret)
    order = (out & IDX_MASK).astype(jnp.int32)
    return order[:n]


@functools.partial(jax.jit, static_argnames=("n", "n_pad", "tile",
                                             "interpret"))
def _argsort_jitted(keys, *, n, n_pad, tile, interpret):
    return _argsort_impl(keys, n=n, n_pad=n_pad, tile=tile,
                         interpret=interpret)


def argsort(keys: jnp.ndarray, *, num_key_bits: int = 12, tile: int = 1024,
            interpret: bool = True, jit: bool = False) -> jnp.ndarray:
    """Stable argsort of small-integer keys (expert ids) — MoE dispatch entry.

    keys: (n,) int32 with values in [0, 2^num_key_bits); n padded to a power
    of two internally (pad keys sort to the end and are dropped).  With
    ``jit=True`` the whole pipeline (pack → tile sort → merge levels →
    unpack) runs as one compiled program, cached per (n, tile).
    """
    n = keys.shape[0]
    if n > (1 << IDX_BITS):
        raise ValueError(
            f"argsort supports at most 2^{IDX_BITS} = {1 << IDX_BITS} "
            f"elements, got n={n}: packed indices would overflow IDX_BITS "
            "and collide with the keys (raise IDX_BITS / shrink the batch)")
    if num_key_bits + IDX_BITS > 32:
        raise ValueError(
            f"num_key_bits={num_key_bits} does not fit: key and index must "
            f"pack into 32 bits (num_key_bits + {IDX_BITS} ≤ 32)")
    if not isinstance(keys, jax.core.Tracer):
        kmax = int(jnp.max(keys)) if n else 0
        if kmax >= 1 << num_key_bits:
            raise ValueError(
                f"keys must be < 2^num_key_bits = {1 << num_key_bits}, got "
                f"max key {kmax}: packed keys would collide with the index "
                "bits and silently corrupt the order (raise num_key_bits)")
    n_pad = 1 << math.ceil(math.log2(max(2, n)))
    fn = _argsort_jitted if jit else _argsort_impl
    return fn(jnp.asarray(keys), n=n, n_pad=n_pad, tile=tile,
              interpret=interpret)


__all__ = ["argsort", "sort_u32", "tile_sort", "merge_pair",
           "trace_launches", "LaunchRecord", "IDX_BITS", "IDX_MASK"]
