"""In-kernel LSD radix tile sort — the merge sort's tile phase, rebuilt.

The seed tile sort ran an O(m·log²m) bitonic network per tile: at
``tile=1024`` that is 55 compare-exchange stages — ~550 traced ops per
kernel body, and trace/compile/dispatch overhead proportional to that is
exactly the per-task overhead that erases task-parallel speedups
("Runtime vs Scheduler", PAPERS.md).  This module replaces it with a
stable LSD radix sort whose whole pass loop is a single in-kernel
``fori_loop``: ``ceil(sort_bits / r)`` data-parallel passes, each a
constant ~20 traced ops, no 1-D gathers anywhere.

One pass (``r``-bit digit, radix ``R = 2^r``):

1. **Rank by masked cumulative sum.**  ``onehot[i, d] = [digit_i == d]``
   (a broadcast compare against a 2-D iota — no gather); an inclusive
   cumsum down the tile axis counts, for every element, how many earlier
   elements share its digit; the digit histogram's exclusive scan adds the
   count of all smaller digits.  ``rank = Σ_d onehot·(incl + excl) − 1``
   selects both terms in one masked reduction.  Stable by construction:
   equal digits keep their relative order.

2. **Gather-free placement.**  ``rank`` is a bijection onto ``[0, m)``, so
   scatter-by-rank is a permutation-matrix product.  A full ``(m, m)``
   one-hot is memory-hostile; instead ``rank`` splits as ``(row, col) =
   (rank // C, rank % C)`` and the move becomes one small matmul per
   payload: ``out[row, col] = Σ_i v_i · rowoh[i, row] · coloh[i, col]``
   (an MXU-shaped ``(rows, m) × (m, C)`` contraction).  Every output cell
   receives exactly one element, so f32 accumulation is exact for
   payloads below 2^24; wider payloads move as two 16-bit halves.

Fused pack (`radix_tile_sort_packed`): the kernel takes *raw keys* and
emits sorted ``key << idx_bits | global_index`` words — the pack that used
to be a standalone elementwise launch happens in-kernel.  Fusion also
makes the sort cheaper, not just launch-leaner: in-tile the index bits are
the (already ordered) local positions, so a *stable* rank over the key
digits alone reproduces the packed order exactly — 12-bit keys need
``ceil(12/r)`` passes instead of ``ceil((12+idx_bits)/r)``.  The moved
payload is the compact composite ``key·tile + position`` (≤ 24 bits for
the default ``tile=1024``/``num_key_bits≤14`` — single-einsum placement).

``group`` batches several tiles per grid cell (leading block axis) purely
to amortize interpret-mode per-op overhead; on a real TPU footprint is
``group·tile`` words of payload plus the ``(group·tile, R)`` one-hot, so
keep ``group`` small (default 8 ≈ 2 MB of VMEM at ``tile=1024``).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.plan import digit_passes
from .launch_trace import record

# the single definition — merge_sort imports it: pad words must compare
# above every real packed key in both the tile and the merge phases
SENTINEL = 0xFFFFFFFF

# int16 rank arithmetic holds counts up to 2·tile; keep a wide margin
_MAX_RADIX_TILE = 1 << 13


def _check_tile(tile: int, digit_bits: int) -> None:
    if tile & (tile - 1):
        raise ValueError(f"radix tile must be a power of two, got {tile}")
    if tile > _MAX_RADIX_TILE:
        raise ValueError(f"radix tile sort supports tile ≤ {_MAX_RADIX_TILE} "
                         f"(int16 rank arithmetic), got {tile}")
    if not 1 <= digit_bits <= 8:
        raise ValueError(f"digit_bits must be in [1, 8], got {digit_bits}")


def _pick_group(num_tiles: int, group: int) -> int:
    return math.gcd(num_tiles, max(1, group))


def _placement_split(m: int):
    """Balanced (rows, cols) factorization of the tile for the rank
    decomposition — rows·cols == m, both powers of two."""
    lb = m.bit_length() - 1
    rows = 1 << (lb // 2)
    return rows, m // rows


def _rank_and_counts(vals: jnp.ndarray, shift, digit_mask, radix: int):
    """Stable rank of each element of each row by the masked digit at
    ``shift`` (``digit_mask`` narrows the final pass so bits beyond the
    sort window never participate — tie order outside it is preserved),
    plus the per-row digit histogram.

    vals: (G, m) uint32 → ((G, m) int16 rank — a per-row permutation —
    and (G, R) int32 counts).  Masked-cumsum formulation: no gathers, one
    (G, m, R) intermediate.
    """
    G, m = vals.shape
    digit = ((vals >> shift) & digit_mask).astype(jnp.int16)
    onehot = (digit[..., None] ==
              jax.lax.broadcasted_iota(jnp.int16, (G, m, radix), 2)
              ).astype(jnp.int16)
    incl = jnp.cumsum(onehot, axis=1)                     # within-digit counts
    counts = incl[:, -1, :].astype(jnp.int32)             # digit histogram
    excl = (jnp.cumsum(counts, axis=1) - counts).astype(jnp.int16)
    # one masked reduction selects own-digit (incl − 1) + smaller-digit total
    rank = jnp.sum(onehot * (incl + excl[:, None, :]), axis=2) - 1
    return rank, counts


def _rank_by_digit(vals: jnp.ndarray, shift, digit_mask,
                   radix: int) -> jnp.ndarray:
    return _rank_and_counts(vals, shift, digit_mask, radix)[0]


def _placement_onehots(rank: jnp.ndarray, rows: int, cols: int):
    G, m = rank.shape
    rowoh = ((rank // cols)[..., None] ==
             jax.lax.broadcasted_iota(jnp.int16, (G, m, rows), 2)
             ).astype(jnp.float32)
    coloh = ((rank % cols)[..., None] ==
             jax.lax.broadcasted_iota(jnp.int16, (G, m, cols), 2)
             ).astype(jnp.float32)
    return rowoh, coloh


def _permute_narrow(v: jnp.ndarray, rowoh, coloh) -> jnp.ndarray:
    """Place values < 2^24 by rank (exact f32, single contraction)."""
    G, m = v.shape
    out = jnp.einsum("gmr,gmc->grc", v.astype(jnp.float32)[..., None] * rowoh,
                     coloh, preferred_element_type=jnp.float32)
    return out.reshape(G, m).astype(jnp.uint32)


def _permute_u32(v: jnp.ndarray, rowoh, coloh) -> jnp.ndarray:
    """Place full uint32 payloads by rank as two exact 16-bit halves."""
    lo = _permute_narrow(v & jnp.uint32(0xFFFF), rowoh, coloh)
    hi = _permute_narrow(v >> 16, rowoh, coloh)
    return (hi << 16) | lo


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _pass_mask(p, digit_bits: int, sort_bits: int):
    """Digit mask of pass ``p``: full ``digit_bits`` except the final pass,
    which narrows to the leftover ``sort_bits`` (the ``DigitPass.bits``
    arithmetic, applied in-kernel so out-of-window bits never rank)."""
    width = jnp.minimum(jnp.uint32(digit_bits),
                        jnp.uint32(sort_bits) -
                        p.astype(jnp.uint32) * digit_bits)
    return (jnp.uint32(1) << width) - jnp.uint32(1)


def _radix_sort_kernel(x_ref, o_ref, *, num_passes, digit_bits, sort_bits,
                       key_shift):
    """Generic per-tile stable LSD sort of packed uint32 words by the bits
    in [key_shift, key_shift + sort_bits)."""
    G, m = x_ref.shape
    rows, cols = _placement_split(m)
    radix = 1 << digit_bits

    def one_pass(p, x):
        shift = jnp.uint32(key_shift) + p.astype(jnp.uint32) * digit_bits
        rank = _rank_by_digit(x, shift, _pass_mask(p, digit_bits, sort_bits),
                              radix)
        rowoh, coloh = _placement_onehots(rank, rows, cols)
        return _permute_u32(x, rowoh, coloh)

    o_ref[...] = jax.lax.fori_loop(0, num_passes, one_pass, x_ref[...])


def _fused_tile_sort_kernel(k_ref, o_ref, *, n, num_key_bits, idx_bits,
                            num_passes, digit_bits, sort_bits, unpack):
    """Fused pack + radix tile sort (+ optional unpack).

    k_ref: (G, tile) int32 raw keys (pad rows carry the max key so they
    sort last).  The in-kernel payload is the composite ``key·tile + pos``;
    global packed words (or, with ``unpack``, the int32 order itself) are
    materialized only at the output write.
    """
    G, m = k_ref.shape
    lb = m.bit_length() - 1
    rows, cols = _placement_split(m)
    radix = 1 << digit_bits
    narrow = lb + num_key_bits <= 24          # composite exact in one einsum

    pos = jax.lax.broadcasted_iota(jnp.uint32, (G, m), 1)
    c0 = (k_ref[...].astype(jnp.uint32) << lb) | pos

    def one_pass(p, c):
        # rank on the *key* digits only: the position bits below lb are
        # already in order, and LSD stability carries them for free
        shift = jnp.uint32(lb) + p.astype(jnp.uint32) * digit_bits
        rank = _rank_by_digit(c, shift,
                              _pass_mask(p, digit_bits, sort_bits), radix)
        rowoh, coloh = _placement_onehots(rank, rows, cols)
        perm = _permute_narrow if narrow else _permute_u32
        return perm(c, rowoh, coloh)

    c = jax.lax.fori_loop(0, num_passes, one_pass, c0)

    base = (pl.program_id(0) * (G * m)).astype(jnp.uint32)
    gidx = (base + jax.lax.broadcasted_iota(jnp.uint32, (G, m), 0) * m +
            (c & jnp.uint32(m - 1)))
    idx_mask = jnp.uint32((1 << idx_bits) - 1)
    if unpack:
        o_ref[...] = jnp.where(gidx < n, gidx, idx_mask).astype(jnp.int32)
    else:
        packed = ((c >> lb) << idx_bits) | gidx
        o_ref[...] = jnp.where(gidx < n, packed, jnp.uint32(SENTINEL))


def _block_imap(i):
    return (i, 0)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def radix_tile_sort(x: jnp.ndarray, *, tile: int = 1024, total_bits: int = 32,
                    digit_bits: int = 4, key_shift: int = 0, group: int = 8,
                    interpret: bool = True) -> jnp.ndarray:
    """Sort each tile of a (n,) uint32 array by the ``total_bits`` bits at
    ``key_shift`` — stable, so tie order (bits outside the range) is
    preserved.  Drop-in replacement for the bitonic ``tile_sort``;
    ``ceil(total_bits / digit_bits)`` passes run inside one launch."""
    n = x.shape[0]
    tile = min(tile, n)
    _check_tile(tile, digit_bits)
    assert n % tile == 0
    nt = n // tile
    g = _pick_group(nt, group)
    passes = digit_passes(total_bits, digit_bits, key_shift=key_shift)
    kernel = functools.partial(_radix_sort_kernel, num_passes=len(passes),
                               digit_bits=digit_bits, sort_bits=total_bits,
                               key_shift=key_shift)
    record("tile_sort", (nt // g,), [(g, tile)])
    out = pl.pallas_call(
        kernel,
        grid=(nt // g,),
        in_specs=[pl.BlockSpec((g, tile), _block_imap)],
        out_specs=pl.BlockSpec((g, tile), _block_imap),
        out_shape=jax.ShapeDtypeStruct((nt, tile), x.dtype),
        interpret=interpret,
    )(x.reshape(nt, tile))
    return out.reshape(n)


def radix_tile_sort_packed(keys: jnp.ndarray, *, n: int, tile: int,
                           num_key_bits: int, idx_bits: int,
                           digit_bits: int = 4, group: int = 8,
                           unpack: bool = False, passes=None,
                           interpret: bool = True) -> jnp.ndarray:
    """Fused pack + tile sort: raw int32 keys (padded to a multiple of
    ``tile``; pad rows must carry the max key) → per-tile-sorted packed
    uint32 words ``key << idx_bits | global_index``, pad slots as the
    sentinel.  With ``unpack=True`` (single-tile pipelines) the kernel
    emits the int32 order directly — zero standalone elementwise launches
    on either side.  ``passes`` takes the plan's
    :meth:`~repro.core.plan.Plan.sort_schedule` digit-pass tuple and is
    what actually parameterizes the kernel (pass count, digit stride and
    ranked bit-width all come from it; derived locally when absent)."""
    n_pad = keys.shape[0]
    tile = min(tile, n_pad)
    assert n_pad % tile == 0
    nt = n_pad // tile
    g = _pick_group(nt, group)
    lb = tile.bit_length() - 1
    if passes is None:
        passes = digit_passes(num_key_bits, digit_bits, key_shift=lb)
    passes = tuple(passes)
    _check_tile(tile, passes[0].bits if passes else digit_bits)
    if passes and passes[0].shift != lb:
        # layout invariant, not arithmetic: the composite places the key
        # at bit log2(tile), so the schedule's key_shift must agree
        raise ValueError(f"schedule key_shift {passes[0].shift} != "
                         f"log2(tile) = {lb}")
    # the kernel strides uniformly by passes[0].bits (only the final pass
    # may narrow) — reject any other shape instead of silently mis-sorting
    for i, p in enumerate(passes):
        if p.shift != passes[0].shift + i * passes[0].bits or \
                (p.bits != passes[0].bits and i != len(passes) - 1) or \
                p.bits > passes[0].bits:
            raise ValueError(
                f"passes must be contiguous with uniform stride (last may "
                f"narrow), got {passes}")
    kernel = functools.partial(
        _fused_tile_sort_kernel, n=n, num_key_bits=num_key_bits,
        idx_bits=idx_bits, num_passes=len(passes),
        digit_bits=passes[0].bits if passes else digit_bits,
        sort_bits=sum(p.bits for p in passes), unpack=unpack)
    out_dtype = jnp.int32 if unpack else jnp.uint32
    record("tile_sort", (nt // g,), [(g, tile)])
    out = pl.pallas_call(
        kernel,
        grid=(nt // g,),
        in_specs=[pl.BlockSpec((g, tile), _block_imap)],
        out_specs=pl.BlockSpec((g, tile), _block_imap),
        out_shape=jax.ShapeDtypeStruct((nt, tile), out_dtype),
        interpret=interpret,
    )(keys.reshape(nt, tile))
    return out.reshape(n_pad)


# ---------------------------------------------------------------------------
# multi-tile LSD radix (PR 6 tentpole): kill the merge tree
#
# The merge-tree argsort pays 1 + log2(n/tile) launches.  A *global* LSD
# radix pays 3·ceil(num_key_bits / digit_bits) — independent of n:
#
#   per digit pass
#     1. local:   per-tile stable sort by the pass digit + per-tile digit
#                 histogram (one grid launch, the PR 4 rank machinery)
#     2. scan:    exclusive scan of the (num_tiles × R) histogram matrix
#                 flattened digit-major → global digit base offsets
#                 (ONE launch regardless of num_tiles — tile_scan.py's
#                 cross-tile VMEM carry)
#     3. scatter: after the local sort each (tile, digit) segment is
#                 contiguous in BOTH source and destination, so global
#                 placement is R masked fixed-size window copies per tile
#                 at dynamic offsets — no 1-D gathers, TPU-lowerable
#
# Stability: only the key digit bits are ranked; the packed index bits ride
# below them, so LSD stability orders equal keys by global index for free.
# Pad keys carry the max key and land at the global tail.
# ---------------------------------------------------------------------------

def _mt_local_kernel(x_ref, o_ref, h_ref, *, shift, bits, pack, idx_bits):
    """One digit pass, tile-local half: stable sort of each tile by the
    ``bits``-wide digit at ``shift`` plus the per-tile digit histogram.
    With ``pack`` (first pass) the input is raw int32 keys and the kernel
    emits ``key << idx_bits | global_index`` words — the pack launch is
    fused away exactly as in the single-tile pipeline."""
    G, m = x_ref.shape
    rows, cols = _placement_split(m)
    radix = 1 << bits
    if pack:
        base = (pl.program_id(0) * (G * m)).astype(jnp.uint32)
        gidx = (base + jax.lax.broadcasted_iota(jnp.uint32, (G, m), 0) * m +
                jax.lax.broadcasted_iota(jnp.uint32, (G, m), 1))
        c = (x_ref[...].astype(jnp.uint32) << idx_bits) | gidx
    else:
        c = x_ref[...]
    rank, counts = _rank_and_counts(c, jnp.uint32(shift),
                                    jnp.uint32(radix - 1), radix)
    rowoh, coloh = _placement_onehots(rank, rows, cols)
    o_ref[...] = _permute_u32(c, rowoh, coloh)
    h_ref[...] = counts


def _mt_scatter_kernel(x_ref, h_ref, b_ref, o_ref, *, radix, unpack_mask):
    """One digit pass, global half: place every (tile, digit) segment at
    its global base offset.

    Each fori step copies one fixed ``tile``-sized window from the locally
    sorted block into the output at a dynamic offset, masked to the
    segment's true length — lanes past it write back what they read, so
    every real slot is written exactly once with its final value and the
    sequential grid/loop order cannot clobber it.  ``unpack_mask`` (last
    pass) fuses the ``& idx_mask`` unpack in, emitting the int32 order."""
    g, m = x_ref.shape
    h2 = h_ref[...]                                   # (g, R) int32
    ls2 = jnp.cumsum(h2, axis=1) - h2                 # local segment starts
    h = h2.reshape(g * radix)
    lstart = ls2.reshape(g * radix)
    base = b_ref[...].reshape(g * radix)
    xx = x_ref[...].reshape(g * m)
    if unpack_mask is not None:
        xx = (xx & jnp.uint32(unpack_mask)).astype(jnp.int32)
    # segment reads may run past a row end (masked off below) — pad one tile
    xx = jnp.concatenate([xx, jnp.zeros((m,), xx.dtype)])
    idx = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0).reshape(m)

    def body(j, carry):
        cnt = jax.lax.dynamic_index_in_dim(h, j, keepdims=False)
        ls = jax.lax.dynamic_index_in_dim(lstart, j, keepdims=False)
        gb = jax.lax.dynamic_index_in_dim(base, j, keepdims=False)
        row = j // radix
        seg = jax.lax.dynamic_slice(xx, (row * m + ls,), (m,))
        cur = o_ref[pl.ds(gb, m)]
        o_ref[pl.ds(gb, m)] = jnp.where(idx < cnt, seg, cur)
        return carry

    jax.lax.fori_loop(0, g * radix, body, 0)


def _mt_local(x, *, nt, tile, shift, bits, pack, idx_bits, group, interpret):
    g = _pick_group(nt, group)
    radix = 1 << bits
    kernel = functools.partial(_mt_local_kernel, shift=shift, bits=bits,
                               pack=pack, idx_bits=idx_bits)
    record("radix_mt_local", (nt // g,), [(g, tile), (g, radix)])
    return pl.pallas_call(
        kernel,
        grid=(nt // g,),
        in_specs=[pl.BlockSpec((g, tile), _block_imap)],
        out_specs=(pl.BlockSpec((g, tile), _block_imap),
                   pl.BlockSpec((g, radix), _block_imap)),
        out_shape=(jax.ShapeDtypeStruct((nt, tile), jnp.uint32),
                   jax.ShapeDtypeStruct((nt, radix), jnp.int32)),
        interpret=interpret,
    )(x.reshape(nt, tile))


def _mt_scatter(local, hist, base, *, tile, radix, group, interpret,
                unpack_mask=None):
    nt = local.shape[0]
    g = _pick_group(nt, group)
    n_pad = nt * tile
    out_dtype = jnp.uint32 if unpack_mask is None else jnp.int32
    kernel = functools.partial(_mt_scatter_kernel, radix=radix,
                               unpack_mask=unpack_mask)
    record("radix_mt_scatter", (nt // g,), [(g, tile), (n_pad + tile,)])
    out = pl.pallas_call(
        kernel,
        grid=(nt // g,),
        in_specs=[pl.BlockSpec((g, tile), _block_imap),
                  pl.BlockSpec((g, radix), _block_imap),
                  pl.BlockSpec((g, radix), _block_imap)],
        # whole-array output, revisited by every grid step (sequential
        # masked RMW); one spare tile keeps the last windows in bounds
        out_specs=pl.BlockSpec((n_pad + tile,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_pad + tile,), out_dtype),
        interpret=interpret,
    )(local, hist, base)
    return out[:n_pad]


def multi_tile_argsort_packed(keys: jnp.ndarray, *, n: int, tile: int,
                              num_key_bits: int, idx_bits: int,
                              digit_bits: int = 4, group: int = 8,
                              scan_block: int = 256, passes=None,
                              interpret: bool = True) -> jnp.ndarray:
    """Global stable argsort via multi-tile LSD radix — no merge tree.

    keys: raw int32, padded to a multiple of ``tile`` with the max key (pad
    slots sort to the global tail).  Returns the full padded int32 order;
    callers slice ``[:n]``.  Launches: ``3 · num_passes`` (local + carry
    scan + scatter per digit pass), independent of ``n``; a single-tile
    input degenerates to the fused one-launch tile sort.  ``passes`` takes
    the plan's ``sort_schedule(mode="multi_tile")`` digit passes
    (``key_shift`` must equal ``idx_bits``: digits rank the key bits of the
    packed word, above the index bits)."""
    from .tile_scan import histogram_offsets

    n_pad = keys.shape[0]
    tile = min(tile, n_pad)
    assert n_pad % tile == 0
    nt = n_pad // tile
    if nt == 1:
        return radix_tile_sort_packed(
            keys, n=n, tile=tile, num_key_bits=num_key_bits,
            idx_bits=idx_bits, digit_bits=digit_bits, group=group,
            unpack=True, interpret=interpret)
    if passes is None:
        passes = digit_passes(num_key_bits, digit_bits, key_shift=idx_bits)
    passes = tuple(passes)
    if not passes:
        raise ValueError("multi-tile argsort needs at least one digit pass")
    if passes[0].shift != idx_bits:
        raise ValueError(f"schedule key_shift {passes[0].shift} != "
                         f"idx_bits = {idx_bits}")
    _check_tile(tile, max(p.bits for p in passes))
    idx_mask = (1 << idx_bits) - 1
    x = keys
    for i, p in enumerate(passes):
        local, hist = _mt_local(
            x, nt=nt, tile=tile, shift=p.shift, bits=p.bits, pack=(i == 0),
            idx_bits=idx_bits, group=group, interpret=interpret)
        base = histogram_offsets(hist, block=scan_block, interpret=interpret)
        x = _mt_scatter(
            local, hist, base, tile=tile, radix=1 << p.bits, group=group,
            interpret=interpret,
            unpack_mask=idx_mask if i == len(passes) - 1 else None)
    return x


# ---------------------------------------------------------------------------
# one-launch MoE dispatch: sort + gather fused into a single pallas_call
# ---------------------------------------------------------------------------

def _moe_dispatch_kernel(a_ref, o_ref, hist_ref, offs_ref, *, radix, d_col):
    """Two-sweep grid ``(2, nt)`` over the augmented row matrix
    ``A = [activations | e | p | tok]`` (f32; the expert id rides in column
    ``d_col``).

    Sweep 0 fills the ``(nt, R)`` histogram scratch.  Step (1, 0) turns it
    into global digit base offsets (digit-major exclusive scan — the
    ``histogram_offsets`` arithmetic, inline on scratch since the whole
    matrix is already in VMEM).  Sweep 1 stably sorts each tile's rows by
    expert digit (one-hot matmul row permutation — exact: every output row
    receives exactly one source row) and window-scatters the (tile, digit)
    segments at their global offsets, exactly like ``_mt_scatter_kernel``
    but moving whole rows.  One digit pass suffices because ``E ≤ radix``."""
    s = pl.program_id(0)
    t = pl.program_id(1)
    m, C = a_ref.shape
    av = a_ref[...]
    e = av[:, d_col].astype(jnp.uint32).reshape(1, m)
    rank, counts = _rank_and_counts(e, jnp.uint32(0),
                                    jnp.uint32(radix - 1), radix)

    @pl.when(s == 0)
    def _():
        hist_ref[pl.ds(t, 1), :] = counts

    @pl.when((s == 1) & (t == 0))
    def _():
        h = hist_ref[...]                             # (nt, R)
        flat = h.T.reshape(-1)                        # digit-major
        excl = jnp.cumsum(flat) - flat
        offs_ref[...] = excl.reshape(radix, -1).T

    @pl.when(s == 1)
    def _():
        # stable local sort of the rows: out[r, :] = A[rank⁻¹(r), :]
        poh = (rank.reshape(m)[:, None] ==
               jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
               ).astype(jnp.float32)
        rows = jnp.einsum("ir,ic->rc", poh, av,
                          preferred_element_type=jnp.float32)
        h = counts.reshape(radix)
        lstart = jnp.cumsum(h) - h
        base = offs_ref[pl.ds(t, 1), :].reshape(radix)
        xx = jnp.concatenate([rows, jnp.zeros((m, C), rows.dtype)])
        idx = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0).reshape(m)

        def body(d, carry):
            cnt = jax.lax.dynamic_index_in_dim(h, d, keepdims=False)
            ls = jax.lax.dynamic_index_in_dim(lstart, d, keepdims=False)
            gb = jax.lax.dynamic_index_in_dim(base, d, keepdims=False)
            seg = jax.lax.dynamic_slice(xx, (ls, 0), (m, C))
            cur = o_ref[pl.ds(gb, m), :]
            o_ref[pl.ds(gb, m), :] = jnp.where(idx[:, None] < cnt, seg, cur)
            return carry

        jax.lax.fori_loop(0, radix, body, 0)


def _moe_dispatch_impl(a, *, nt, tile, radix, d_col, interpret):
    from jax.experimental.pallas import tpu as pltpu
    n_pad, C = a.shape
    kernel = functools.partial(_moe_dispatch_kernel, radix=radix, d_col=d_col)
    record("moe_dispatch", (2, nt), [(tile, C), (n_pad + tile, C)])
    out = pl.pallas_call(
        kernel,
        grid=(2, nt),
        in_specs=[pl.BlockSpec((tile, C), lambda s, t: (t, 0))],
        out_specs=pl.BlockSpec((n_pad + tile, C), lambda s, t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad + tile, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((nt, radix), jnp.int32),
                        pltpu.VMEM((nt, radix), jnp.int32)],
        interpret=interpret,
    )(a)
    return out


_MOE_DISPATCH_STATICS = ("nt", "tile", "radix", "d_col", "interpret")
_moe_dispatch_jitted = functools.partial(
    jax.jit, static_argnames=_MOE_DISPATCH_STATICS)(_moe_dispatch_impl)


def moe_dispatch_sort(x: jnp.ndarray, experts: jnp.ndarray,
                      probs: jnp.ndarray, *, num_experts: int,
                      tile: int = 512, interpret: bool = True,
                      jit: bool = True):
    """One-``pallas_call`` MoE routing: stable sort of the (T·K,) expert
    assignments WITH the activation rows carried along — the
    ``xf[sorted_tok]`` gather of the old pipeline happens inside the final
    scatter, so dispatch is a single kernel launch at any T.

    x: (T, D) activations; experts/probs: (T, K) from ``route_topk``.
    Returns ``(xd (T·K, D), sorted_e, sorted_tok, sorted_p)`` — bit-identical
    to the argsort + gather path (f32 row moves are exact: one-hot
    permutations place each value once; ids/positions are < 2^24).
    Requires ``num_experts ≤ 256`` (one ≤ 9-bit digit pass; the sentinel
    digit ``E`` marks pad rows, which sort to the tail and are sliced off).
    """
    T, D = x.shape
    K = experts.shape[-1]
    E = num_experts
    if E > 256:
        raise ValueError(f"one-launch dispatch needs num_experts ≤ 256, "
                         f"got {E} (fall back to argsort + gather)")
    n = T * K
    bits = max(1, math.ceil(math.log2(E + 1)))    # digit E = pad sentinel
    radix = 1 << bits
    tile = min(tile, 1 << max(1, math.ceil(math.log2(max(2, n)))))
    n_pad = -(-n // tile) * tile

    xr = jnp.repeat(x.astype(jnp.float32), K, axis=0)       # (T·K, D)
    cols = [xr,
            experts.reshape(n, 1).astype(jnp.float32),
            probs.reshape(n, 1).astype(jnp.float32),
            jnp.repeat(jnp.arange(T, dtype=jnp.float32), K).reshape(n, 1)]
    a = jnp.concatenate(cols, axis=1)
    if n_pad != n:
        pad = jnp.zeros((n_pad - n, D + 3), jnp.float32)
        pad = pad.at[:, D].set(float(E))                    # sentinel digit
        a = jnp.concatenate([a, pad])

    fn = _moe_dispatch_jitted if jit else _moe_dispatch_impl
    out = fn(a, nt=n_pad // tile, tile=tile, radix=radix, d_col=D,
             interpret=interpret)[:n]
    xd = out[:, :D].astype(x.dtype)
    sorted_e = out[:, D].astype(jnp.int32)
    sorted_p = out[:, D + 1].astype(probs.dtype)
    sorted_tok = out[:, D + 2].astype(jnp.int32)
    return xd, sorted_e, sorted_tok, sorted_p


__all__ = ["radix_tile_sort", "radix_tile_sort_packed",
           "multi_tile_argsort_packed", "moe_dispatch_sort", "SENTINEL"]
