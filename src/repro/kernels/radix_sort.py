"""In-kernel LSD radix tile sort — the merge sort's tile phase, rebuilt.

The seed tile sort ran an O(m·log²m) bitonic network per tile: at
``tile=1024`` that is 55 compare-exchange stages — ~550 traced ops per
kernel body, and trace/compile/dispatch overhead proportional to that is
exactly the per-task overhead that erases task-parallel speedups
("Runtime vs Scheduler", PAPERS.md).  This module replaces it with a
stable LSD radix sort whose whole pass loop is a single in-kernel
``fori_loop``: ``ceil(sort_bits / r)`` data-parallel passes, each a
constant ~20 traced ops, no 1-D gathers anywhere.

One pass (``r``-bit digit, radix ``R = 2^r``):

1. **Rank by masked cumulative sum.**  ``onehot[i, d] = [digit_i == d]``
   (a broadcast compare against a 2-D iota — no gather); an inclusive
   cumsum down the tile axis counts, for every element, how many earlier
   elements share its digit; the digit histogram's exclusive scan adds the
   count of all smaller digits.  ``rank = Σ_d onehot·(incl + excl) − 1``
   selects both terms in one masked reduction.  Stable by construction:
   equal digits keep their relative order.

2. **Gather-free placement.**  ``rank`` is a bijection onto ``[0, m)``, so
   scatter-by-rank is a permutation-matrix product.  A full ``(m, m)``
   one-hot is memory-hostile; instead ``rank`` splits as ``(row, col) =
   (rank // C, rank % C)`` and the move becomes one small matmul per
   payload: ``out[row, col] = Σ_i v_i · rowoh[i, row] · coloh[i, col]``
   (an MXU-shaped ``(rows, m) × (m, C)`` contraction).  Every output cell
   receives exactly one element, so f32 accumulation is exact for
   payloads below 2^24; wider payloads move as two 16-bit halves.

Fused pack (`radix_tile_sort_packed`): the kernel takes *raw keys* and
emits sorted ``key << idx_bits | global_index`` words — the pack that used
to be a standalone elementwise launch happens in-kernel.  Fusion also
makes the sort cheaper, not just launch-leaner: in-tile the index bits are
the (already ordered) local positions, so a *stable* rank over the key
digits alone reproduces the packed order exactly — 12-bit keys need
``ceil(12/r)`` passes instead of ``ceil((12+idx_bits)/r)``.  The moved
payload is the compact composite ``key·tile + position`` (≤ 24 bits for
the default ``tile=1024``/``num_key_bits≤14`` — single-einsum placement).

``group`` batches several tiles per grid cell (leading block axis) purely
to amortize interpret-mode per-op overhead; on a real TPU footprint is
``group·tile`` words of payload plus the ``(group·tile, R)`` one-hot, so
keep ``group`` small (default 8 ≈ 2 MB of VMEM at ``tile=1024``).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.plan import digit_passes
from .launch_trace import record

# the single definition — merge_sort imports it: pad words must compare
# above every real packed key in both the tile and the merge phases
SENTINEL = 0xFFFFFFFF

# int16 rank arithmetic holds counts up to 2·tile; keep a wide margin
_MAX_RADIX_TILE = 1 << 13


def _check_tile(tile: int, digit_bits: int) -> None:
    if tile & (tile - 1):
        raise ValueError(f"radix tile must be a power of two, got {tile}")
    if tile > _MAX_RADIX_TILE:
        raise ValueError(f"radix tile sort supports tile ≤ {_MAX_RADIX_TILE} "
                         f"(int16 rank arithmetic), got {tile}")
    if not 1 <= digit_bits <= 8:
        raise ValueError(f"digit_bits must be in [1, 8], got {digit_bits}")


def _pick_group(num_tiles: int, group: int) -> int:
    return math.gcd(num_tiles, max(1, group))


def _placement_split(m: int):
    """Balanced (rows, cols) factorization of the tile for the rank
    decomposition — rows·cols == m, both powers of two."""
    lb = m.bit_length() - 1
    rows = 1 << (lb // 2)
    return rows, m // rows


def _rank_by_digit(vals: jnp.ndarray, shift, digit_mask,
                   radix: int) -> jnp.ndarray:
    """Stable rank of each element of each row by the masked digit at
    ``shift`` (``digit_mask`` narrows the final pass so bits beyond the
    sort window never participate — tie order outside it is preserved).

    vals: (G, m) uint32 → (G, m) int16 rank (a per-row permutation).
    Masked-cumsum formulation: no gathers, one (G, m, R) intermediate.
    """
    G, m = vals.shape
    digit = ((vals >> shift) & digit_mask).astype(jnp.int16)
    onehot = (digit[..., None] ==
              jax.lax.broadcasted_iota(jnp.int16, (G, m, radix), 2)
              ).astype(jnp.int16)
    incl = jnp.cumsum(onehot, axis=1)                     # within-digit counts
    counts = incl[:, -1, :].astype(jnp.int32)             # digit histogram
    excl = (jnp.cumsum(counts, axis=1) - counts).astype(jnp.int16)
    # one masked reduction selects own-digit (incl − 1) + smaller-digit total
    return jnp.sum(onehot * (incl + excl[:, None, :]), axis=2) - 1


def _placement_onehots(rank: jnp.ndarray, rows: int, cols: int):
    G, m = rank.shape
    rowoh = ((rank // cols)[..., None] ==
             jax.lax.broadcasted_iota(jnp.int16, (G, m, rows), 2)
             ).astype(jnp.float32)
    coloh = ((rank % cols)[..., None] ==
             jax.lax.broadcasted_iota(jnp.int16, (G, m, cols), 2)
             ).astype(jnp.float32)
    return rowoh, coloh


def _permute_narrow(v: jnp.ndarray, rowoh, coloh) -> jnp.ndarray:
    """Place values < 2^24 by rank (exact f32, single contraction)."""
    G, m = v.shape
    out = jnp.einsum("gmr,gmc->grc", v.astype(jnp.float32)[..., None] * rowoh,
                     coloh, preferred_element_type=jnp.float32)
    return out.reshape(G, m).astype(jnp.uint32)


def _permute_u32(v: jnp.ndarray, rowoh, coloh) -> jnp.ndarray:
    """Place full uint32 payloads by rank as two exact 16-bit halves."""
    lo = _permute_narrow(v & jnp.uint32(0xFFFF), rowoh, coloh)
    hi = _permute_narrow(v >> 16, rowoh, coloh)
    return (hi << 16) | lo


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _pass_mask(p, digit_bits: int, sort_bits: int):
    """Digit mask of pass ``p``: full ``digit_bits`` except the final pass,
    which narrows to the leftover ``sort_bits`` (the ``DigitPass.bits``
    arithmetic, applied in-kernel so out-of-window bits never rank)."""
    width = jnp.minimum(jnp.uint32(digit_bits),
                        jnp.uint32(sort_bits) -
                        p.astype(jnp.uint32) * digit_bits)
    return (jnp.uint32(1) << width) - jnp.uint32(1)


def _radix_sort_kernel(x_ref, o_ref, *, num_passes, digit_bits, sort_bits,
                       key_shift):
    """Generic per-tile stable LSD sort of packed uint32 words by the bits
    in [key_shift, key_shift + sort_bits)."""
    G, m = x_ref.shape
    rows, cols = _placement_split(m)
    radix = 1 << digit_bits

    def one_pass(p, x):
        shift = jnp.uint32(key_shift) + p.astype(jnp.uint32) * digit_bits
        rank = _rank_by_digit(x, shift, _pass_mask(p, digit_bits, sort_bits),
                              radix)
        rowoh, coloh = _placement_onehots(rank, rows, cols)
        return _permute_u32(x, rowoh, coloh)

    o_ref[...] = jax.lax.fori_loop(0, num_passes, one_pass, x_ref[...])


def _fused_tile_sort_kernel(k_ref, o_ref, *, n, num_key_bits, idx_bits,
                            num_passes, digit_bits, sort_bits, unpack):
    """Fused pack + radix tile sort (+ optional unpack).

    k_ref: (G, tile) int32 raw keys (pad rows carry the max key so they
    sort last).  The in-kernel payload is the composite ``key·tile + pos``;
    global packed words (or, with ``unpack``, the int32 order itself) are
    materialized only at the output write.
    """
    G, m = k_ref.shape
    lb = m.bit_length() - 1
    rows, cols = _placement_split(m)
    radix = 1 << digit_bits
    narrow = lb + num_key_bits <= 24          # composite exact in one einsum

    pos = jax.lax.broadcasted_iota(jnp.uint32, (G, m), 1)
    c0 = (k_ref[...].astype(jnp.uint32) << lb) | pos

    def one_pass(p, c):
        # rank on the *key* digits only: the position bits below lb are
        # already in order, and LSD stability carries them for free
        shift = jnp.uint32(lb) + p.astype(jnp.uint32) * digit_bits
        rank = _rank_by_digit(c, shift,
                              _pass_mask(p, digit_bits, sort_bits), radix)
        rowoh, coloh = _placement_onehots(rank, rows, cols)
        perm = _permute_narrow if narrow else _permute_u32
        return perm(c, rowoh, coloh)

    c = jax.lax.fori_loop(0, num_passes, one_pass, c0)

    base = (pl.program_id(0) * (G * m)).astype(jnp.uint32)
    gidx = (base + jax.lax.broadcasted_iota(jnp.uint32, (G, m), 0) * m +
            (c & jnp.uint32(m - 1)))
    idx_mask = jnp.uint32((1 << idx_bits) - 1)
    if unpack:
        o_ref[...] = jnp.where(gidx < n, gidx, idx_mask).astype(jnp.int32)
    else:
        packed = ((c >> lb) << idx_bits) | gidx
        o_ref[...] = jnp.where(gidx < n, packed, jnp.uint32(SENTINEL))


def _block_imap(i):
    return (i, 0)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def radix_tile_sort(x: jnp.ndarray, *, tile: int = 1024, total_bits: int = 32,
                    digit_bits: int = 4, key_shift: int = 0, group: int = 8,
                    interpret: bool = True) -> jnp.ndarray:
    """Sort each tile of a (n,) uint32 array by the ``total_bits`` bits at
    ``key_shift`` — stable, so tie order (bits outside the range) is
    preserved.  Drop-in replacement for the bitonic ``tile_sort``;
    ``ceil(total_bits / digit_bits)`` passes run inside one launch."""
    n = x.shape[0]
    tile = min(tile, n)
    _check_tile(tile, digit_bits)
    assert n % tile == 0
    nt = n // tile
    g = _pick_group(nt, group)
    passes = digit_passes(total_bits, digit_bits, key_shift=key_shift)
    kernel = functools.partial(_radix_sort_kernel, num_passes=len(passes),
                               digit_bits=digit_bits, sort_bits=total_bits,
                               key_shift=key_shift)
    record("tile_sort", (nt // g,), [(g, tile)])
    out = pl.pallas_call(
        kernel,
        grid=(nt // g,),
        in_specs=[pl.BlockSpec((g, tile), _block_imap)],
        out_specs=pl.BlockSpec((g, tile), _block_imap),
        out_shape=jax.ShapeDtypeStruct((nt, tile), x.dtype),
        interpret=interpret,
    )(x.reshape(nt, tile))
    return out.reshape(n)


def radix_tile_sort_packed(keys: jnp.ndarray, *, n: int, tile: int,
                           num_key_bits: int, idx_bits: int,
                           digit_bits: int = 4, group: int = 8,
                           unpack: bool = False, passes=None,
                           interpret: bool = True) -> jnp.ndarray:
    """Fused pack + tile sort: raw int32 keys (padded to a multiple of
    ``tile``; pad rows must carry the max key) → per-tile-sorted packed
    uint32 words ``key << idx_bits | global_index``, pad slots as the
    sentinel.  With ``unpack=True`` (single-tile pipelines) the kernel
    emits the int32 order directly — zero standalone elementwise launches
    on either side.  ``passes`` takes the plan's
    :meth:`~repro.core.plan.Plan.sort_schedule` digit-pass tuple and is
    what actually parameterizes the kernel (pass count, digit stride and
    ranked bit-width all come from it; derived locally when absent)."""
    n_pad = keys.shape[0]
    tile = min(tile, n_pad)
    assert n_pad % tile == 0
    nt = n_pad // tile
    g = _pick_group(nt, group)
    lb = tile.bit_length() - 1
    if passes is None:
        passes = digit_passes(num_key_bits, digit_bits, key_shift=lb)
    passes = tuple(passes)
    _check_tile(tile, passes[0].bits if passes else digit_bits)
    if passes and passes[0].shift != lb:
        # layout invariant, not arithmetic: the composite places the key
        # at bit log2(tile), so the schedule's key_shift must agree
        raise ValueError(f"schedule key_shift {passes[0].shift} != "
                         f"log2(tile) = {lb}")
    # the kernel strides uniformly by passes[0].bits (only the final pass
    # may narrow) — reject any other shape instead of silently mis-sorting
    for i, p in enumerate(passes):
        if p.shift != passes[0].shift + i * passes[0].bits or \
                (p.bits != passes[0].bits and i != len(passes) - 1) or \
                p.bits > passes[0].bits:
            raise ValueError(
                f"passes must be contiguous with uniform stride (last may "
                f"narrow), got {passes}")
    kernel = functools.partial(
        _fused_tile_sort_kernel, n=n, num_key_bits=num_key_bits,
        idx_bits=idx_bits, num_passes=len(passes),
        digit_bits=passes[0].bits if passes else digit_bits,
        sort_bits=sum(p.bits for p in passes), unpack=unpack)
    out_dtype = jnp.int32 if unpack else jnp.uint32
    record("tile_sort", (nt // g,), [(g, tile)])
    out = pl.pallas_call(
        kernel,
        grid=(nt // g,),
        in_specs=[pl.BlockSpec((g, tile), _block_imap)],
        out_specs=pl.BlockSpec((g, tile), _block_imap),
        out_shape=jax.ShapeDtypeStruct((nt, tile), out_dtype),
        interpret=interpret,
    )(keys.reshape(nt, tile))
    return out.reshape(n_pad)


__all__ = ["radix_tile_sort", "radix_tile_sort_packed", "SENTINEL"]
