"""Pallas flash-decode: one-token attention with KV-range splitting.

This kernel is the paper's divide-and-conquer (wrap_iter) pattern on silicon:
a Kvik policy splits the KV range [0, S) into blocks (``demand_split`` — the
adaptive schedule: exactly as many blocks as there is parallelism demand);
each grid step computes a *partial* softmax (m, l, acc) over its block; the
partials are then fused by the plan's symmetric **reduction tree**
(``combine_partials`` — associative, so the tree shape is free to match the
hardware, exactly the paper's argument for delegating reduction placement).

GQA: q-heads grouped per kv-head in the index map, like flash_attention.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import SeqWork, demand_split

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, m_ref, l_ref, acc_ref, *,
                   scale: float, bk: int):
    """Grid (B, H, nk).  Partials per kv block.

    q_ref: (1,1,hd); k_ref/v_ref: (1,bk,1,hd); len_ref: (1,) valid length.
    Outputs m/l: (1,1,1); acc: (1,1,1,hd).
    """
    ik = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale              # (hd,)
    k = k_ref[0, :, 0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    valid = len_ref[0]
    pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    s = jnp.einsum("kd,d->k", k, q)
    s = jnp.where(pos < valid, s, NEG_INF)
    m = s.max()
    p = jnp.exp(s - m)
    l = p.sum()
    acc = jnp.einsum("k,kd->d", p, v)
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l
    acc_ref[0, 0, 0] = acc


def decode_partials(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                    block_k: int = 512, scale: Optional[float] = None,
                    interpret: bool = True):
    """q: (B,H,hd); caches: (B,S,KV,hd); lengths: (B,).
    Returns per-block partials (m, l, acc) with leading nk axis."""
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    bk = min(block_k, S)
    assert S % bk == 0
    nk = S // bk
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk)
    m, l, acc = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, ik: (b, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, h, ik: (b, h, ik)),
            pl.BlockSpec((1, 1, 1), lambda b, h, ik: (b, h, ik)),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nk), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nk), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, lengths)
    return m, l, acc


def combine_partials(part_a, part_b):
    """Associative LSE-combine of two softmax partials — one node of the
    Kvik reduction tree."""
    m1, l1, a1 = part_a
    m2, l2, a2 = part_b
    m = jnp.maximum(m1, m2)
    s1 = jnp.exp(m1 - m)
    s2 = jnp.exp(m2 - m)
    return (m, l1 * s1 + l2 * s2,
            a1 * s1[..., None] + a2 * s2[..., None])


def flash_decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                 lengths: jnp.ndarray, *, block_k: int = 512,
                 scale: Optional[float] = None, demand: Optional[int] = None,
                 interpret: bool = True) -> jnp.ndarray:
    """Full decode attention: Pallas partials + plan-driven reduction tree.

    ``demand`` (default: #kv-blocks) sets the adaptive-schedule parallelism:
    the KV range is demand_split into that many pieces, and the partials are
    reduced pairwise along the plan tree.
    """
    B, H, hd = q.shape
    S = k_cache.shape[1]
    bk = min(block_k, S)
    nk = S // bk
    m, l, acc = decode_partials(q, k_cache, v_cache, lengths,
                                block_k=bk, scale=scale, interpret=interpret)

    plan = demand_split(SeqWork(0, nk), demand or nk)

    def leaf(work):
        sl = slice(work.start, work.stop)
        parts = [(m[:, :, i], l[:, :, i], acc[:, :, i])
                 for i in range(work.start, work.stop)]
        out = parts[0]
        for p in parts[1:]:
            out = combine_partials(out, p)
        return out

    mF, lF, aF = plan.map_reduce(leaf, combine_partials)
    return (aF / jnp.maximum(lF, 1e-30)[..., None]).astype(q.dtype)


__all__ = ["flash_decode", "decode_partials", "combine_partials"]
