"""Pure-jnp oracles for every kernel — the ground truth for allclose tests."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B,Sq,H,hd)  k,v: (B,Sk,KV,hd) — exact softmax attention, fp32."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_reference(q: jnp.ndarray, k_cache: jnp.ndarray,
                               v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                               scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B,H,hd)  caches: (B,S,KV,hd)  lengths: (B,)."""
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    k = jnp.repeat(k_cache, G, axis=2).astype(jnp.float32)
    v = jnp.repeat(v_cache, G, axis=2).astype(jnp.float32)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k) * scale
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    logits = jnp.where(mask[:, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v).astype(q.dtype)


def stable_argsort_reference(keys: jnp.ndarray) -> jnp.ndarray:
    return jnp.argsort(keys, stable=True).astype(jnp.int32)


__all__ = ["attention_reference", "decode_attention_reference",
           "stable_argsort_reference"]
