"""repro.data"""
