"""Deterministic, shard-resumable data pipeline.

Batches are a pure function of (seed, step, shard) — counter-based generation
means the pipeline state is a single integer, checkpoints are trivial, and
any host can regenerate any shard after elastic re-meshing (no data loss on
node failure — the fault-tolerance property that matters at 1000+ nodes).

Shard assignment is a Kvik plan: the global batch is a ``BatchWork`` split by
``demand_split`` over the DP replicas; the adaptive rebalancer
(``repro.train.straggler``) re-splits *host-side* work (prefetch shares)
between steps using ``divide_at`` — the paper's steal-driven division at the
only layer of a synchronous SPMD system that is genuinely dynamic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import BatchWork, demand_split


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pad_fraction: float = 0.0      # tail padding to exercise masks
    kind: str = "synthetic-lm"     # synthetic-lm | file

    # file-backed corpora: flat token memmap
    path: Optional[str] = None


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d) -> "PipelineState":
        return cls(step=int(d["step"]))


class DataPipeline:
    """Counter-based synthetic LM stream (or file-backed windows)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.state = PipelineState()
        self._tokens = None
        if cfg.kind == "file" and cfg.path:
            self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")

    # ---------------------------------------------------------------- core
    def _synthetic(self, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
        """Rows [lo, hi) of the step's global batch.

        One Philox counter per ROW — row r of step s is identical no matter
        which shard generates it (the elastic-recovery property)."""
        cfg = self.cfg
        rows = []
        lens = []
        for r in range(lo, hi):
            gen = np.random.Generator(
                np.random.Philox(key=cfg.seed, counter=[0, 0, step, r]))
            rows.append(gen.integers(1, cfg.vocab_size,
                                     size=cfg.seq_len + 1, dtype=np.int32))
            if cfg.pad_fraction > 0:
                lens.append(int(gen.integers(
                    int(cfg.seq_len * (1 - cfg.pad_fraction)), cfg.seq_len)))
        toks = np.stack(rows)
        if cfg.pad_fraction > 0:
            mask = np.arange(cfg.seq_len + 1)[None, :] < \
                np.asarray(lens)[:, None]
            toks = np.where(mask, toks, 0)
        tokens = toks[:, :-1]
        labels = np.where(toks[:, 1:] > 0, toks[:, 1:], -1).astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def _from_file(self, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        n = hi - lo
        total = len(self._tokens) - cfg.seq_len - 1
        base = (step * cfg.global_batch + lo) * cfg.seq_len
        rows = [(base + i * cfg.seq_len) % total for i in range(n)]
        toks = np.stack([self._tokens[r:r + cfg.seq_len + 1] for r in rows])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def batch_slice(self, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
        if self.cfg.kind == "file" and self._tokens is not None:
            return self._from_file(step, lo, hi)
        return self._synthetic(step, lo, hi)

    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self.batch_slice(self.state.step, 0, self.cfg.global_batch)
        self.state.step += 1
        return b

    # ------------------------------------------------------------- sharding
    def shard_plan(self, num_replicas: int,
                   shares: Optional[List[float]] = None) -> List[Tuple[int, int]]:
        """Per-replica [lo, hi) row ranges.  Equal split by default; the
        rebalancer passes ``shares`` (host-side prefetch weights)."""
        B = self.cfg.global_batch
        if shares is None:
            plan = demand_split(BatchWork(0, B), num_replicas)
            return [(w.start, w.stop) for w in plan.leaves()]
        total = sum(shares)
        bounds, acc = [], 0.0
        work = BatchWork(0, B)
        out = []
        remaining = work
        for s in shares[:-1]:
            cut = int(round(B * s / total))
            cut = max(1, min(cut, remaining.size() - 1))
            left, remaining = remaining.divide_at(cut)
            out.append((left.start, left.stop))
        out.append((remaining.start, remaining.stop))
        return out


def host_batch_to_device(batch: Dict[str, np.ndarray], shardings=None):
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}


__all__ = ["DataConfig", "DataPipeline", "PipelineState",
           "host_batch_to_device"]
