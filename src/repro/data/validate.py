"""Data/tensor auditing with by_blocks early abort (the paper's ``all``).

Production duty: before committing a checkpoint or ingesting a shard,
verify tensors are finite / token ids are in range.  The naive reduction
scans everything; the by_blocks schedule aborts at the first bad block and
bounds wasted verification work — measured in benchmarks/all_scan.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

from ..core import BlockStats, WorkRange, by_blocks


@dataclasses.dataclass
class AuditResult:
    ok: bool
    first_bad_block: Optional[Tuple[int, int]] = None
    stats: Optional[BlockStats] = None


def audit_array(x: np.ndarray, predicate: Callable[[np.ndarray], bool], *,
                first_block: int = 1 << 14) -> AuditResult:
    """Check ``predicate`` on geometric blocks of flat(x); abort on failure."""
    flat = np.asarray(x).reshape(-1)
    bad: list = [None]
    bb = by_blocks(first=first_block)

    def block_fn(blk, carry):
        seg = flat[blk.start:blk.stop]
        if not predicate(seg):
            bad[0] = (blk.start, blk.stop)
            return True
        return carry

    _, stats = bb.run(WorkRange(0, flat.shape[0]), block_fn, False,
                      should_stop=lambda c: c)
    return AuditResult(ok=bad[0] is None, first_bad_block=bad[0], stats=stats)


def all_finite(x) -> AuditResult:
    return audit_array(np.asarray(x, np.float32),
                       lambda seg: bool(np.isfinite(seg).all()))


def tokens_in_range(tokens, vocab_size: int) -> AuditResult:
    t = np.asarray(tokens)
    return audit_array(t, lambda seg: bool(((seg >= -1)
                                            & (seg < vocab_size)).all()))


def audit_pytree(tree: Any) -> Tuple[bool, list]:
    """All-finite audit over every leaf; returns (ok, bad_leaf_paths)."""
    bad = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(jax.device_get(leaf))
        if np.issubdtype(arr.dtype, np.floating) or arr.dtype.name == "bfloat16":
            if not all_finite(arr.astype(np.float32)).ok:
                bad.append(jax.tree_util.keystr(path))
    return (not bad), bad


__all__ = ["AuditResult", "audit_array", "all_finite", "tokens_in_range",
           "audit_pytree"]
