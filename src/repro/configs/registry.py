"""Architecture registry: ``--arch <id>`` → config module."""

from __future__ import annotations

from typing import Dict, List

from . import (chatglm3_6b, deepseek_v2_lite, jamba_1_5_large,
               llama32_vision_11b, llama3_8b, llama4_scout_17b, minitron_4b,
               whisper_medium, xlstm_1_3b, yi_9b)
from .base import ModelConfig

_MODULES = {
    "minitron-4b": minitron_4b,
    "chatglm3-6b": chatglm3_6b,
    "llama3-8b": llama3_8b,
    "yi-9b": yi_9b,
    "llama-3.2-vision-11b": llama32_vision_11b,
    "llama4-scout-17b-a16e": llama4_scout_17b,
    "deepseek-v2-lite-16b": deepseek_v2_lite,
    "xlstm-1.3b": xlstm_1_3b,
    "jamba-1.5-large-398b": jamba_1_5_large,
    "whisper-medium": whisper_medium,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "all_configs"]
