"""whisper-medium — encoder-decoder, conv frontend stubbed [arXiv:2212.04356].

24 encoder + 24 decoder layers (whisper-medium's real shape; the assignment's
"24L" is interpreted per-stack, see DESIGN.md), d_model=1024, 16H (MHA),
d_ff=4096, GELU MLPs, LayerNorm, vocab=51865 (padded +7 → 51872 so the
16-way model axis divides it).  The conv1d/mel frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, S_enc, d_model).
Every decoder layer cross-attends to the encoder output.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    dense_d_ff=4096,
    vocab_size=51865,
    vocab_padding=7,
    ffn_type="gelu",
    norm="layernorm",
    cross_attn_period=1,
    decoder_prefill_len=1024,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio", num_layers=2, encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        dense_d_ff=128, vocab_size=509, vocab_padding=3, ffn_type="gelu",
        norm="layernorm", cross_attn_period=1, decoder_prefill_len=32,
        loss_chunk=64)
