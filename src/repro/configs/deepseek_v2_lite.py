"""deepseek-v2-lite-16b — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434; hf].

27L, d_model=2048, 16H MLA (kv_lora_rank=512, qk_nope=128, qk_rope=64,
v_head=128), per-expert d_ff=1408, vocab=102400.  First layer is dense
(d_ff=10944); the remaining 26 are MoE.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,            # MLA is MHA at compute time
    d_ff=1408,
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    moe_layer_period=1,
    first_dense_layers=1,
    dense_d_ff=10944,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=96, vocab_size=512,
        attn_type="mla", kv_lora_rank=32, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16, num_experts=8,
        num_shared_experts=2, top_k=2, moe_d_ff=64, moe_layer_period=1,
        first_dense_layers=1, dense_d_ff=128, loss_chunk=64)
