"""chatglm3-6b — RoPE 2d (half-rotary), GQA kv=2 [arXiv:2406.12793; hf].

28L, d_model=4096, 32H (GQA kv=2), d_ff=13696, vocab=65024, head_dim=128.
ChatGLM applies rotary embedding to half of each head's dims
(``rotary_fraction=0.5``).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rotary_fraction=0.5,
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        rotary_fraction=0.5, loss_chunk=64)
