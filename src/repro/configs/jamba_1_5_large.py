"""jamba-1.5-large-398b — Mamba + attention 1:7, MoE 16e top-2
[arXiv:2403.19887; hf].

72L, d_model=8192, 64H (GQA kv=8), d_ff=24576, vocab=65536.  Period-8 block:
attention at position 4, Mamba elsewhere; MoE (16 experts, top-2) on every
other layer.  AdamW moments in bf16 (moment_dtype) — required to fit the
398B parameterization on a 256-chip pod, recorded in EXPERIMENTS.md.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    rope_theta=10000.0,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ssm_state_dim=16,
    ssm_expand=2,
    ssm_conv_dim=4,
    mlstm_chunk=256,
    num_experts=16,
    num_shared_experts=0,
    top_k=2,
    moe_d_ff=24576,
    moe_layer_period=2,
    moe_layer_offset=1,
    moment_dtype="bfloat16",
    fsdp=True,
    moe_2d_shard=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid", num_layers=8, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=512,
        block_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        ssm_state_dim=8, ssm_expand=2, mlstm_chunk=16, num_experts=4,
        top_k=2, moe_d_ff=96, moe_layer_period=2, moe_layer_offset=1,
        loss_chunk=64)
