"""``input_specs`` — ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation: the dry-run lowers
``train_step`` / ``serve_step`` against these.  For enc-dec and VLM families
the modality frontend is a stub — the spec provides the precomputed
embeddings directly (frames / image patches), per the assignment.

Conventions (documented in DESIGN.md):
* enc-dec train/prefill: encoder sees ``seq_len`` stub frames; the decoder
  sees ``seq_len // 4`` tokens (train) / ``decoder_prefill_len`` (prefill).
* enc-dec decode: decoder KV cache = ``seq_len``; cross-attention KV over
  1500 encoder positions (whisper's native 30 s window).
* decode shapes: cache buffers are part of the spec (serve_step signature is
  ``(params, tokens, cache, lengths)``).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from .base import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def _stub_inputs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        out["image_embeds"] = SDS((batch, cfg.num_image_tokens, cfg.d_model),
                                  jnp.dtype(cfg.compute_dtype))
    if cfg.is_encdec:
        out["frames"] = SDS((batch, seq, cfg.d_model),
                            jnp.dtype(cfg.compute_dtype))
    return out


def decoder_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Token-sequence length seen by the decoder stack for a given shape."""
    if not cfg.is_encdec:
        return shape.seq_len
    if shape.kind == "train":
        return max(128, shape.seq_len // 4)
    if shape.kind == "prefill":
        return cfg.decoder_prefill_len
    return shape.seq_len  # decode: cache length


def cross_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.family == "vlm":
        return cfg.num_image_tokens
    if cfg.is_encdec:
        return 1500 if shape.kind == "decode" else shape.seq_len
    return 0


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    S = decoder_len(cfg, shape)
    specs = {"tokens": SDS((B, S), jnp.int32),
             "labels": SDS((B, S), jnp.int32)}
    specs.update(_stub_inputs(cfg, B, shape.seq_len))
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Dict[str, Any]:
    B = shape.global_batch
    S = decoder_len(cfg, shape)
    specs = {"tokens": SDS((B, S), jnp.int32)}
    specs.update(_stub_inputs(cfg, B, shape.seq_len))
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Model
                       ) -> Dict[str, Any]:
    B = shape.global_batch
    S = shape.seq_len
    cache = model.abstract_cache(B, S, cross_len=cross_len(cfg, shape))
    cache = jax.tree.map(lambda x: SDS(x.shape, x.dtype), cache)
    return {
        "tokens": SDS((B,), jnp.int32),
        "lengths": SDS((B,), jnp.int32),
        "cache": cache,
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Model
                ) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape, model)
    raise ValueError(shape.kind)


__all__ = ["input_specs", "train_input_specs", "prefill_input_specs",
           "decode_input_specs", "decoder_len", "cross_len"]
