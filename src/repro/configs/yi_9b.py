"""yi-9b — llama-architecture GQA [arXiv:2403.04652; hf].

48L, d_model=4096, 32H (GQA kv=4), d_ff=11008, vocab=64000, head_dim=128.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke", family="dense", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=512,
        loss_chunk=64)
