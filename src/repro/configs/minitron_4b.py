"""minitron-4b — pruned Nemotron [arXiv:2407.14679; hf].

32L, d_model=3072, 24H (GQA kv=8), d_ff=9216 (squared-ReLU 2-matrix MLP,
Nemotron family), vocab=256000, head_dim=128.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    ffn_type="relu2",
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        ffn_type="relu2", loss_chunk=64)
