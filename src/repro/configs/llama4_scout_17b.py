"""llama4-scout-17b-a16e — MoE 16e top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

48L, d_model=5120, 40H (GQA kv=8), expert d_ff=8192, vocab=202048, MoE with
16 routed experts (top-1) + 1 shared expert on every layer (Scout's
interleave step is 1).  head_dim=128.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500000.0,
    num_experts=16,
    num_shared_experts=1,
    top_k=1,
    moe_d_ff=8192,
    moe_layer_period=1,
    moe_2d_shard=True,   # 193 GB expert bank — replication over 'data' is
                         # 12 GB/chip; 2-D sharding is mandatory here
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=512,
        num_experts=4, num_shared_experts=1, top_k=1, moe_d_ff=96,
        moe_layer_period=1, loss_chunk=64)
