"""llama-3.2-vision-11b — cross-attn image layers [hf:meta-llama/...-Vision].

40L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=128256.  Every 5th layer
carries an additional cross-attention sublayer over image patch embeddings.
The vision tower is a STUB: ``input_specs`` provides precomputed, projected
patch embeddings (B, 1601, d_model).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    cross_attn_period=5,
    num_image_tokens=1601,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-smoke", family="vlm", num_layers=5,
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512, cross_attn_period=5, num_image_tokens=17,
        loss_chunk=64)
