"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

48 blocks, d_model=2048, 4 heads, no separate FFN (d_ff=0; blocks carry their
own up/down projections).  Ratio 7:1 mLSTM:sLSTM — every 8th block is sLSTM.
Attention-free: the flash-attention kernels are inapplicable (DESIGN.md
§Arch-applicability); chunked-scan policies still apply.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    ssm_expand=2,
    ssm_conv_dim=4,
    mlstm_chunk=256,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm", num_layers=8, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=512,
        block_pattern=("mlstm",) * 7 + ("slstm",), ssm_expand=2,
        mlstm_chunk=16, tie_embeddings=True, loss_chunk=64)
