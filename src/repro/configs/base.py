"""Model/architecture configuration schema + shape suite.

Every assigned architecture gets a ``<id>.py`` module exporting ``CONFIG``
(the exact published shape) and ``smoke_config()`` (a reduced same-family
config for CPU tests).  ``repro.configs.registry`` maps ``--arch`` ids to
these modules.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None    # default d_model // num_heads

    # --- attention ---------------------------------------------------------
    attn_type: str = "gqa"            # gqa | mla
    rope_theta: float = 1e4
    rotary_fraction: float = 1.0      # ChatGLM3: 0.5 ("2d" half-rotary)
    # MLA (deepseek)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden (d_ff if 0)
    moe_layer_period: int = 1         # layer i is MoE iff i % period == offset
    moe_layer_offset: int = 0
    first_dense_layers: int = 0       # deepseek: first layer(s) stay dense
    dense_d_ff: int = 0               # d_ff for dense layers in MoE models
    capacity_factor: float = 1.25

    # --- SSM / hybrid --------------------------------------------------------
    block_pattern: Tuple[str, ...] = ()   # cycled; entries: attn|mamba|mlstm|slstm
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    mlstm_chunk: int = 256

    # --- VLM -----------------------------------------------------------------
    cross_attn_period: int = 0        # every k-th layer gets cross-attention
    num_image_tokens: int = 0

    # --- enc-dec (audio) ------------------------------------------------------
    encoder_layers: int = 0           # >0 → enc-dec; num_layers = decoder layers
    max_source_positions: int = 0
    decoder_prefill_len: int = 1024   # decoder prompt length for prefill shapes

    # --- numerics ------------------------------------------------------------
    ffn_type: str = "swiglu"          # swiglu | gelu | relu2
    vocab_padding: int = 0            # pad vocab so TP divides it (whisper)
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"     # AdamW m/v (jamba drops to bf16 to fit)

    # --- scheduling hooks (the paper's knobs, per-model defaults) -------------
    loss_chunk: int = 2048            # vocab-xent chunk size
    remat: str = "block"              # none | block  (remat each scanned block)
    fsdp: bool = False                # also shard params over the data axis
    moe_2d_shard: bool = False        # expert hidden dim over 'data' too —
                                      # only worth it when the expert bank
                                      # alone exceeds HBM (Jamba-398B);
                                      # costs a psum over 'data' per layer

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def padded_vocab(self) -> int:
        return self.vocab_size + self.vocab_padding

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern or ("attn",)

    def layer_kind(self, i: int) -> str:
        return self.pattern[i % len(self.pattern)]

    def layer_is_moe(self, i: int) -> bool:
        if not self.is_moe or i < self.first_dense_layers:
            return False
        return (i % self.moe_layer_period) == self.moe_layer_offset

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def dense_ffn_dim(self) -> int:
        return self.dense_d_ff or self.d_ff

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    def pdtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    # --- parameter counting (for MODEL_FLOPS = 6·N·D roofline term) ----------
    def param_count(self, *, active_only: bool = False) -> int:
        """Analytic parameter count; ``active_only`` counts top-k experts only
        (MoE activated parameters, the 6·N_active·D convention)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = 0
        # embeddings (+untied head)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attn_type == "mla":
                r, rd = self.kv_lora_rank, self.qk_rope_head_dim
                qd = self.num_heads * (self.qk_nope_head_dim + rd)
                p = d * qd                                   # q proj
                p += d * (r + rd)                            # kv down + k_rope
                p += r * self.num_heads * (self.qk_nope_head_dim
                                           + self.v_head_dim)  # kv up
                p += self.num_heads * self.v_head_dim * d    # out
                return p
            qd = self.num_heads * hd
            kvd = self.num_kv_heads * hd
            return d * (qd + 2 * kvd) + qd * d

        def ffn_params(ff: int) -> int:
            mats = 3 if self.ffn_type == "swiglu" else 2
            return mats * d * ff

        def mamba_params() -> int:
            di = self.ssm_expand * d
            dt_rank = max(1, d // 16)
            p = d * 2 * di                    # in_proj
            p += di * self.ssm_conv_dim       # conv
            p += di * (dt_rank + 2 * self.ssm_state_dim)  # x_proj
            p += dt_rank * di + di            # dt_proj
            p += di * self.ssm_state_dim      # A
            p += di * 2                       # D, skip
            p += di * d                       # out_proj
            return p

        def mlstm_params() -> int:
            di = self.ssm_expand * d
            dh = di // self.num_heads
            p = d * 2 * di                    # up proj (x and gate paths)
            p += 3 * self.num_heads * dh * dh  # blockdiag q, k, v
            p += 2 * di * self.num_heads      # i, f gate projections
            p += di * d                       # down proj
            return p

        def slstm_params() -> int:
            p = 4 * d * d                     # i, f, z, o recurrent blocks
            p += 4 * d * d                    # recurrent weights
            p += int(4 / 3 * d * d) * 2       # up/down ffn (conservative)
            return p

        total_layers = self.num_layers + self.encoder_layers
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                n += attn_params()
            elif kind == "mamba":
                n += mamba_params()
            elif kind == "mlstm":
                n += mlstm_params()
            elif kind == "slstm":
                n += slstm_params()
            if self.cross_attn_period and (i % self.cross_attn_period
                                           == self.cross_attn_period - 1):
                n += attn_params()
            # FFN
            if self.d_ff > 0 or self.is_moe:
                if self.layer_is_moe(i):
                    k = self.top_k if active_only else self.num_experts
                    n += k * ffn_params(self.expert_d_ff)
                    n += self.num_shared_experts * ffn_params(self.expert_d_ff)
                elif self.dense_ffn_dim > 0:
                    n += ffn_params(self.dense_ffn_dim)
        # encoder stack (attention + mlp, non-causal)
        for i in range(self.encoder_layers):
            n += attn_params() + ffn_params(self.dense_ffn_dim)
        # norms etc. are negligible; include final norm
        n += d
        return n

    def encoder_param_count(self) -> int:
        """Encoder-stack share of param_count (enc-dec MODEL_FLOPS split)."""
        if not self.is_encdec:
            return 0
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.num_heads * hd + 2 * self.num_kv_heads * hd) \
            + self.num_heads * hd * d
        mats = 3 if self.ffn_type == "swiglu" else 2
        ffn = mats * d * self.dense_ffn_dim
        return self.encoder_layers * (attn + ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: kind decides which step function is lowered."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Families whose attention cost per decode step is linear in cache length but
# whose *prefill/train* is quadratic: long_500k (decode) is only run for
# architectures with sub-quadratic sequence mixing (SSM / hybrid), per the
# assignment instructions.
SUBQUADRATIC_FAMILIES = {"ssm", "hybrid"}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable?, reason-if-not). Encodes the DESIGN.md §Arch-applicability
    skip rules."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention ({cfg.family})")
    return True, ""


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K", "shape_applicable",
           "SUBQUADRATIC_FAMILIES"]
