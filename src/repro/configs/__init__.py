"""Architecture configs: one module per assigned architecture + registry."""

from .base import (ModelConfig, ShapeConfig, SHAPES, TRAIN_4K, PREFILL_32K,
                   DECODE_32K, LONG_500K, shape_applicable)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K", "shape_applicable"]
