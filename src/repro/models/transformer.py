"""Model assembly: layer specs → periods → scanned stages → full models.

Architecture heterogeneity (Jamba's 1:7 mamba:attn with alternating MoE,
llama-vision's every-5th cross-attention, xLSTM's 7:1 mLSTM:sLSTM) is handled
by grouping layers into *periods*: the smallest repeating unit of
(mixer-kind, is-moe, has-cross) specs.  Parameters are stacked over period
repeats and the stack is traversed with ``lax.scan`` — one compiled period
body regardless of depth, which is what keeps 72-layer Jamba compilable and
is standard practice at scale (MaxText does the same).

``remat='block'`` wraps the period body in ``jax.checkpoint`` so backward
recomputes activations per period — the baseline activation policy.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (cross_attention, gqa_init, gqa_project_kv,
                        gqa_project_qkv, gqa_self_attention, mla_cache_payload,
                        mla_decode, mla_init, mla_self_attention,
                        blockwise_attention, plain_attention, attn_chunk_sizes,
                        decode_attention)
from .layers import (Params, chunked_softmax_xent, embed, embedding_init,
                     gelu_mlp, gelu_mlp_init, layernorm, layernorm_init,
                     rmsnorm, rmsnorm_init, swiglu, swiglu_init, unembed,
                     dense_init)
from .moe import moe_apply, moe_init
from .ssm import (mamba_forward, mamba_init, mamba_step, mlstm_forward,
                  mlstm_init, mlstm_step, slstm_forward, slstm_init,
                  slstm_step)


# ---------------------------------------------------------------------------
# Layer specs and periods
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str          # attn | mla | mamba | mlstm | slstm
    is_moe: bool
    has_cross: bool
    has_ffn: bool


def layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    specs = []
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn" and cfg.attn_type == "mla":
            kind = "mla"
        has_cross = bool(cfg.cross_attn_period) and \
            (i % cfg.cross_attn_period == cfg.cross_attn_period - 1)
        has_ffn = cfg.d_ff > 0 or (cfg.is_moe and cfg.layer_is_moe(i))
        specs.append(LayerSpec(kind, cfg.layer_is_moe(i), has_cross, has_ffn))
    return specs


def stage_layout(cfg: ModelConfig) -> Tuple[List[LayerSpec], List[LayerSpec], int]:
    """Returns (prefix_specs, period_specs, n_repeats): prefix layers are
    unrolled (deepseek's leading dense layer); the rest is period × repeats."""
    specs = layer_specs(cfg)
    pre = cfg.first_dense_layers
    prefix, rest = specs[:pre], specs[pre:]
    # find the smallest period that tiles `rest`
    for p in range(1, len(rest) + 1):
        if len(rest) % p != 0:
            continue
        if all(rest[i] == rest[i % p] for i in range(len(rest))):
            return prefix, rest[:p], len(rest) // p
    return prefix, rest, 1


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig):
    return (layernorm_init if cfg.norm == "layernorm" else rmsnorm_init)


def _norm(cfg: ModelConfig):
    return (layernorm if cfg.norm == "layernorm" else rmsnorm)


def _ffn_init(key, cfg: ModelConfig, d_ff: int):
    if cfg.ffn_type == "swiglu":
        return swiglu_init(key, cfg.d_model, d_ff, cfg.pdtype())
    return gelu_mlp_init(key, cfg.d_model, d_ff, cfg.pdtype())


def _ffn_apply(cfg: ModelConfig, params, x):
    if cfg.ffn_type == "swiglu":
        return swiglu(params, x)
    if cfg.ffn_type == "relu2":
        h = jnp.einsum("...d,df->...f", x, params["up"]) + params["up_b"]
        h = jnp.square(jax.nn.relu(h))
        return jnp.einsum("...f,fd->...d", h, params["down"]) + params["down_b"]
    return gelu_mlp(params, x)


def layer_init(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    ninit = _norm_init(cfg)
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": ninit(cfg.d_model, cfg.pdtype())}
    if spec.kind == "attn":
        p["mixer"] = gqa_init(ks[0], cfg)
    elif spec.kind == "mla":
        p["mixer"] = mla_init(ks[0], cfg)
    elif spec.kind == "mamba":
        p["mixer"] = mamba_init(ks[0], cfg)
    elif spec.kind == "mlstm":
        p["mixer"] = mlstm_init(ks[0], cfg)
    elif spec.kind == "slstm":
        p["mixer"] = slstm_init(ks[0], cfg)
    else:
        raise ValueError(spec.kind)
    if spec.has_cross:
        p["ln_cross"] = ninit(cfg.d_model, cfg.pdtype())
        p["cross"] = gqa_init(ks[1], cfg, cross=True)
    if spec.has_ffn:
        p["ln2"] = ninit(cfg.d_model, cfg.pdtype())
        if spec.is_moe:
            p["moe"] = moe_init(ks[2], cfg)
        else:
            p["ffn"] = _ffn_init(ks[2], cfg, cfg.dense_ffn_dim)
    return p


# --- full-sequence (train / encoder / prefill) apply ------------------------

def layer_apply(cfg: ModelConfig, spec: LayerSpec, lp: Params, x: jnp.ndarray,
                positions: jnp.ndarray, *, causal: bool = True,
                kv_states: Optional[jnp.ndarray] = None,
                collect_cache: bool = False,
                moe_strategy: str = "einsum",
                scan_impl: str = "lax"
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Returns (x, aux_loss, cache_payload-or-None)."""
    from ..dist.sharding import constrain, dp
    from jax.sharding import PartitionSpec as P
    norm = _norm(cfg)
    aux = jnp.zeros((), jnp.float32)
    payload = None
    h = norm(lp["ln1"], x, cfg.norm_eps)
    if spec.kind == "attn":
        mix = gqa_self_attention(lp["mixer"], cfg, h, positions,
                                 causal=causal)
        if collect_cache:
            k, v = gqa_project_kv(lp["mixer"], cfg, h, positions)
            kv_spec = P(dp(), "model", None, None)
            payload = {"k": constrain(k, kv_spec), "v": constrain(v, kv_spec)}
    elif spec.kind == "mla":
        mix = mla_self_attention(lp["mixer"], cfg, h, positions,
                                 causal=causal)
        if collect_cache:
            latent = mla_cache_payload(lp["mixer"], cfg, h, positions)
            payload = {"latent": constrain(latent, P(dp(), "model", None))}
    elif spec.kind == "mamba":
        mix, st = mamba_forward(lp["mixer"], cfg, h, scan_impl=scan_impl)
        if collect_cache:
            payload = st
    elif spec.kind == "mlstm":
        mix, st = mlstm_forward(lp["mixer"], cfg, h, scan_impl=scan_impl)
        if collect_cache:
            payload = st
    elif spec.kind == "slstm":
        mix, st = slstm_forward(lp["mixer"], cfg, h)
        if collect_cache:
            payload = st
    else:
        raise ValueError(spec.kind)
    x = x + mix

    if spec.has_cross:
        assert kv_states is not None, "cross-attn layer needs kv_states"
        hc = norm(lp["ln_cross"], x, cfg.norm_eps)
        x = x + cross_attention(lp["cross"], cfg, hc, kv_states)
        if collect_cache:
            # store cross K/V so decode never touches the encoder again
            B2, Skv, _ = kv_states.shape
            hd = cfg.resolved_head_dim
            ck = jnp.einsum("bsd,de->bse", kv_states,
                            lp["cross"]["wk"]).reshape(
                B2, Skv, cfg.num_kv_heads, hd)
            cv = jnp.einsum("bsd,de->bse", kv_states,
                            lp["cross"]["wv"]).reshape(
                B2, Skv, cfg.num_kv_heads, hd)
            payload = dict(payload or {})
            payload["ck"] = ck
            payload["cv"] = cv

    if spec.has_ffn:
        h2 = norm(lp["ln2"], x, cfg.norm_eps)
        if spec.is_moe:
            y, aux = moe_apply(lp["moe"], cfg, h2, strategy=moe_strategy)
        else:
            y = _ffn_apply(cfg, lp["ffn"], h2)
        x = x + y
    return x, aux, payload


# --- decode apply ------------------------------------------------------------

def layer_decode(cfg: ModelConfig, spec: LayerSpec, lp: Params,
                 x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                 positions: jnp.ndarray, lengths: jnp.ndarray, *,
                 moe_strategy: str = "einsum"
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B,1,D); cache: per-layer state dict; returns (x, new cache)."""
    norm = _norm(cfg)
    B = x.shape[0]
    h = norm(lp["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if spec.kind == "attn":
        # write the current token's K/V first — it attends to itself.
        # Mask-select (not scatter): a scatter onto the seq-sharded cache
        # makes GSPMD replicate the whole buffer; the select is local per
        # shard and costs the same read/write the attention pass pays anyway.
        q, k_new, v_new = gqa_project_qkv(lp["mixer"], cfg, h,
                                          positions[:, None])
        S_max = cache["k"].shape[1]
        at = (jnp.arange(S_max)[None, :] ==
              lengths[:, None])[:, :, None, None]      # (B,S,1,1)
        new_cache["k"] = jnp.where(at, k_new[:, 0][:, None], cache["k"])
        new_cache["v"] = jnp.where(at, v_new[:, 0][:, None], cache["v"])
        o = decode_attention(q[:, 0], new_cache["k"], new_cache["v"],
                             lengths + 1)
        y = jnp.einsum("be,ed->bd", o.reshape(B, -1),
                       lp["mixer"]["wo"])[:, None]
    elif spec.kind == "mla":
        y, new_latent = mla_decode(lp["mixer"], cfg, h, cache["latent"],
                                   positions, lengths)
        new_cache["latent"] = new_latent
    elif spec.kind == "mamba":
        y, st = mamba_step(lp["mixer"], cfg, h, cache)
        new_cache.update(st)
    elif spec.kind == "mlstm":
        y, st = mlstm_step(lp["mixer"], cfg, h, cache)
        new_cache.update(st)
    elif spec.kind == "slstm":
        y, st = slstm_step(lp["mixer"], cfg, h, cache)
        new_cache.update(st)
    else:
        raise ValueError(spec.kind)
    x = x + y

    if spec.has_cross:
        hc = norm(lp["ln_cross"], x, cfg.norm_eps)
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,de->bse", hc, lp["cross"]["wq"]).reshape(
            B, cfg.num_heads, hd)
        kvlen = jnp.full((B,), cache["ck"].shape[1], jnp.int32)
        o = decode_attention(q, cache["ck"], cache["cv"], kvlen)
        x = x + jnp.einsum("be,ed->bd", o.reshape(B, -1),
                           lp["cross"]["wo"])[:, None]

    if spec.has_ffn:
        h2 = norm(lp["ln2"], x, cfg.norm_eps)
        if spec.is_moe:
            y, _ = moe_apply(lp["moe"], cfg, h2, strategy=moe_strategy,
                             group_size=min(256, x.shape[0]))
            x = x + y
        else:
            x = x + _ffn_apply(cfg, lp["ffn"], h2)
    return x, new_cache


# --- chunked-prefill apply (by_blocks serving path) --------------------------

def layer_prefill_chunk(cfg: ModelConfig, spec: LayerSpec, lp: Params,
                        x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                        pos0, *, moe_strategy: str = "einsum",
                        scan_impl: str = "lax"
                        ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Process chunk positions [pos0, pos0+c) against cached history.

    x: (B, c, D).  Attention sees cache[:pos0] + intra-chunk causal; new KV
    is written into the cache.  SSM states continue from the cache.  ``pos0``
    is a *traced* scalar: one compilation per distinct chunk length ``c``,
    reused at every position (the by_blocks schedule then compiles O(log S)
    programs total, not O(log²S)).  The price is that attention runs over the
    full cache width with the causal mask doing the windowing — positions
    beyond pos0+c are masked to exactly zero probability, so the result is
    bit-equal to the sliced-history form.
    """
    norm = _norm(cfg)
    B, c, D = x.shape
    new_cache = dict(cache)
    h = norm(lp["ln1"], x, cfg.norm_eps)
    positions = pos0 + jnp.broadcast_to(jnp.arange(c), (B, c))

    if spec.kind == "attn":
        q, k, v = gqa_project_qkv(lp["mixer"], cfg, h, positions)
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos0, 1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos0, 1)
        new_cache["k"], new_cache["v"] = new_k, new_v
        S_max = new_k.shape[1]
        qc, kc = attn_chunk_sizes(c, S_max)
        if c <= 256 and S_max <= 1024:
            o = plain_attention(q, new_k, new_v, causal=True,
                                q_offset=pos0)
        else:
            o = blockwise_attention(q, new_k, new_v, causal=True,
                                    q_chunk=qc, kv_chunk=kc, q_offset=pos0)
        y = jnp.einsum("bse,ed->bsd", o.reshape(B, c, -1), lp["mixer"]["wo"])
    elif spec.kind == "mla":
        # absorbed chunk attention against the latent history
        payload = mla_cache_payload(lp["mixer"], cfg, h, positions)
        new_lat = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], payload, pos0, 1)
        new_cache["latent"] = new_lat
        y = _mla_chunk_absorbed(lp["mixer"], cfg, h, new_lat, positions,
                                pos0, c)
    elif spec.kind == "mamba":
        from .ssm import mamba_forward as _mf
        y, st = _mf(lp["mixer"], cfg, h, h0=cache["ssm"],
                    conv_buf=cache["conv"], scan_impl=scan_impl)
        new_cache.update(st)
    elif spec.kind == "mlstm":
        from .ssm import mlstm_forward
        y, st = mlstm_forward(lp["mixer"], cfg, h, state=cache,
                              scan_impl=scan_impl)
        new_cache.update({k2: st[k2] for k2 in ("C", "n", "m", "conv")})
    elif spec.kind == "slstm":
        from .ssm import slstm_forward
        y, st = slstm_forward(lp["mixer"], cfg, h, state=cache)
        new_cache.update({k2: st[k2] for k2 in ("c", "n", "h", "m", "conv")})
    else:
        raise ValueError(spec.kind)
    x = x + y

    if spec.has_cross:
        hc = norm(lp["ln_cross"], x, cfg.norm_eps)
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,de->bse", hc, lp["cross"]["wq"]).reshape(
            B, c, cfg.num_heads, hd)
        o = plain_attention(q, cache["ck"], cache["cv"], causal=False)
        x = x + jnp.einsum("bse,ed->bsd", o.reshape(B, c, -1),
                           lp["cross"]["wo"])

    if spec.has_ffn:
        h2 = norm(lp["ln2"], x, cfg.norm_eps)
        if spec.is_moe:
            y2, _ = moe_apply(lp["moe"], cfg, h2, strategy=moe_strategy,
                              group_size=min(256, c))
            x = x + y2
        else:
            x = x + _ffn_apply(cfg, lp["ffn"], h2)
    return x, new_cache


def _mla_chunk_absorbed(params: Params, cfg: ModelConfig, h: jnp.ndarray,
                        latent: jnp.ndarray, positions: jnp.ndarray,
                        pos0, c: int) -> jnp.ndarray:
    """MLA chunk attention in absorbed form (latent-history scoring).

    ``pos0`` may be traced — scoring runs over the full latent buffer and the
    causal mask (exact −inf → exactly-zero softmax weight) does the history
    windowing, so compilation is keyed on the chunk length only."""
    from .attention import NEG_INF
    from .layers import apply_rope, rope_table
    B = h.shape[0]
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    S_hist = latent.shape[1]
    scale = 1.0 / math.sqrt(nd + rd)

    q = jnp.einsum("bsd,de->bse", h, params["wq"]).reshape(B, c, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cos, sin = rope_table(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    w_uk = params["wkv_up"].reshape(r, H, nd + vd)[..., :nd]
    q_abs = jnp.einsum("bchn,rhn->bchr", q_nope, w_uk)

    c_hist, rope_hist = latent[..., :r], latent[..., r:]
    logits = (jnp.einsum("bchr,bsr->bhcs", q_abs, c_hist,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bchr,bsr->bhcs", q_rope, rope_hist,
                           preferred_element_type=jnp.float32)) * scale
    q_pos = pos0 + jnp.arange(c)
    k_pos = jnp.arange(S_hist)
    mask = q_pos[:, None] >= k_pos[None, :]
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhcs,bsr->bchr", p.astype(c_hist.dtype), c_hist,
                       preferred_element_type=jnp.float32)
    w_uv = params["wkv_up"].reshape(r, H, nd + vd)[..., nd:]
    o = jnp.einsum("bchr,rhv->bchv", o_lat.astype(h.dtype), w_uv)
    return jnp.einsum("bce,ed->bcd", o.reshape(B, c, H * vd), params["wo"])


# ---------------------------------------------------------------------------
# cache allocation
# ---------------------------------------------------------------------------

def layer_cache_shape(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_seq: int) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """Returns {name: (shape, dtype)} for one layer's decode state."""
    dt = cfg.dtype()
    d = cfg.d_model
    di = cfg.ssm_expand * d
    if spec.kind == "attn":
        hd = cfg.resolved_head_dim
        kv = cfg.num_kv_heads
        return {"k": ((batch, max_seq, kv, hd), dt),
                "v": ((batch, max_seq, kv, hd), dt)}
    if spec.kind == "mla":
        payload = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        return {"latent": ((batch, max_seq, payload), dt)}
    if spec.kind == "mamba":
        return {"ssm": ((batch, di, cfg.ssm_state_dim), jnp.float32),
                "conv": ((batch, cfg.ssm_conv_dim - 1, di), dt)}
    if spec.kind == "mlstm":
        H = cfg.num_heads
        dh = di // H
        return {"C": ((batch, H, dh, dh), jnp.float32),
                "n": ((batch, H, dh), jnp.float32),
                "m": ((batch, H), jnp.float32),
                "conv": ((batch, cfg.ssm_conv_dim - 1, di), dt)}
    if spec.kind == "slstm":
        return {k: ((batch, d), jnp.float32) for k in ("c", "n", "h", "m")} | \
            {"conv": ((batch, cfg.ssm_conv_dim - 1, d), dt)}
    raise ValueError(spec.kind)


__all__ = [
    "LayerSpec", "layer_specs", "stage_layout", "layer_init", "layer_apply",
    "layer_decode", "layer_cache_shape",
]
