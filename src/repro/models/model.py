"""The unified Model facade: init / loss / prefill / decode over any config.

Responsible for:
* parameter init (real arrays for smoke tests; ``jax.eval_shape`` abstract
  init for the dry-run — full-size models are never materialized on CPU),
* the scan-over-periods traversal (see transformer.py),
* encoder-decoder composition (whisper) and VLM cross-attention stubs,
* cache allocation/threading for serving.

Batch dicts:
  train:   {"tokens": (B,S) i32, "labels": (B,S) i32}         (+ stubs below)
  prefill: {"tokens": (B,S) i32}
  decode:  {"tokens": (B,) i32, "lengths": (B,) i32}
  stubs:   vlm  → {"image_embeds": (B, N_img, D) bf16}
           audio→ {"frames": (B, S_enc, D) bf16}
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (Params, chunked_softmax_xent, embed, embedding_init,
                     layernorm, layernorm_init, rmsnorm, rmsnorm_init,
                     unembed)
from .transformer import (LayerSpec, layer_apply, layer_cache_shape,
                          layer_decode, layer_init, stage_layout)


def sinusoidal_positions(seq: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    moe_strategy: str = "einsum"
    max_decoder_positions: int = 0   # learned decoder positions (whisper)
    # SSM recurrence backend for full-sequence paths: "lax" (associative
    # scan / chunk loop — differentiable, the training default) or "pallas"
    # (single-launch chunked scan, kernels/ssm_scan.py — the serving path).
    scan_impl: str = "lax"

    def __post_init__(self):
        if self.scan_impl not in ("lax", "pallas"):
            raise ValueError(
                f"scan_impl must be 'lax' or 'pallas', got {self.scan_impl!r}")
        self.prefix_specs, self.period_specs, self.repeats = \
            stage_layout(self.cfg)
        self.enc_spec = LayerSpec("attn", False, False, True) \
            if self.cfg.is_encdec else None

    @property
    def recurrent_only(self) -> bool:
        """True when decode state is O(1) per layer (no attention KV grows
        with the sequence) — serving then needs a constant page span per
        request instead of prompt+max_new cache positions."""
        specs = list(self.prefix_specs) + list(self.period_specs)
        return (not self.cfg.is_encdec
                and all(s.kind in ("mamba", "mlstm", "slstm")
                        and not s.has_cross for s in specs))

    # ------------------------------------------------------------------ init
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = iter(jax.random.split(key, 16 + cfg.num_layers
                                   + cfg.encoder_layers))
        params: Params = {
            "embed": embedding_init(next(ks), cfg.padded_vocab, cfg.d_model,
                                    cfg.pdtype()),
        }
        if not cfg.tie_embeddings:
            params["head"] = embedding_init(next(ks), cfg.padded_vocab,
                                            cfg.d_model, cfg.pdtype())
        ninit = layernorm_init if cfg.norm == "layernorm" else rmsnorm_init
        params["final_norm"] = ninit(cfg.d_model, cfg.pdtype())

        if self.prefix_specs:
            params["prefix"] = [layer_init(next(ks), cfg, s)
                                for s in self.prefix_specs]

        def one_period(k):
            kk = jax.random.split(k, len(self.period_specs))
            return [layer_init(kk[i], cfg, s)
                    for i, s in enumerate(self.period_specs)]

        reps = [one_period(next(ks)) for _ in range(self.repeats)]
        params["stage"] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)

        if cfg.is_encdec:
            encs = [layer_init(next(ks), cfg, self.enc_spec)
                    for _ in range(cfg.encoder_layers)]
            params["enc_stage"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                               *encs)
            params["enc_final_norm"] = ninit(cfg.d_model, cfg.pdtype())
            npos = self.max_decoder_positions or 4096
            params["dec_pos"] = (jax.random.normal(
                next(ks), (npos, cfg.d_model), jnp.float32) * 0.01
            ).astype(cfg.pdtype())
        return params

    def abstract_params(self, key=None) -> Any:
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    # ------------------------------------------------------------- internals
    def _norm(self, p, x):
        f = layernorm if self.cfg.norm == "layernorm" else rmsnorm
        return f(p, x, self.cfg.norm_eps)

    def _encode(self, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
        """Whisper encoder over stub frame embeddings."""
        cfg = self.cfg
        B, S, D = frames.shape
        x = frames.astype(cfg.dtype()) + sinusoidal_positions(S, D, cfg.dtype())
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(carry, lp):
            x = carry
            x, _, _ = layer_apply(cfg, self.enc_spec, lp, x, positions,
                                  causal=False)
            return x, None

        body_fn = jax.checkpoint(body) if cfg.remat == "block" else body
        x, _ = jax.lax.scan(body_fn, x, params["enc_stage"])
        return self._norm(params["enc_final_norm"], x)

    def _stage_scan(self, params: Params, x: jnp.ndarray,
                    positions: jnp.ndarray, *, kv_states, collect_cache: bool,
                    causal: bool = True):
        from ..dist.sharding import constrain, dp
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        specs = self.period_specs
        sp_spec = P(dp(), "model", None)   # sequence-parallel residual stream

        def body(carry, stage_lp):
            x, aux = carry
            x = constrain(x, sp_spec)
            payloads = []
            for pos, spec in enumerate(specs):
                x, a, pl = layer_apply(
                    cfg, spec, stage_lp[pos], x, positions, causal=causal,
                    kv_states=kv_states, collect_cache=collect_cache,
                    moe_strategy=self.moe_strategy,
                    scan_impl=self.scan_impl)
                aux = aux + a
                payloads.append(pl)
            x = constrain(x, sp_spec)
            ys = payloads if collect_cache else None
            return (x, aux), ys

        body_fn = jax.checkpoint(body) if cfg.remat == "block" else body
        (x, aux), ys = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["stage"])
        return x, aux, ys

    def _embed_in(self, params, tokens):
        return embed(params["embed"], tokens).astype(self.cfg.dtype())

    def _logits_head(self, params, x):
        cfg = self.cfg
        table = params["embed" if cfg.tie_embeddings else "head"]["table"]
        logits = jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
        if cfg.vocab_padding:
            neg = jnp.full((cfg.vocab_padding,), -1e30, jnp.float32)
            logits = logits.at[..., cfg.vocab_size:].set(neg)
        return logits

    # ----------------------------------------------------------------- train
    def loss_fn(self, params: Params, batch: Dict[str, jnp.ndarray]
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        kv_states = None
        if cfg.family == "vlm":
            kv_states = batch["image_embeds"].astype(cfg.dtype())

        x = self._embed_in(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        aux_total = jnp.zeros((), jnp.float32)

        if cfg.is_encdec:
            enc_out = self._encode(params, batch["frames"])
            kv_states = enc_out
            x = x + params["dec_pos"][:S].astype(cfg.dtype())

        for spec, lp in zip(self.prefix_specs, params.get("prefix", [])):
            x, a, _ = layer_apply(cfg, spec, lp, x, positions,
                                  kv_states=kv_states,
                                  moe_strategy=self.moe_strategy,
                                  scan_impl=self.scan_impl)
            aux_total += a

        x, aux, _ = self._stage_scan(params, x, positions,
                                     kv_states=kv_states, collect_cache=False)
        aux_total += aux
        x = self._norm(params["final_norm"], x)

        head = params["embed" if cfg.tie_embeddings else "head"]
        mask = (labels >= 0).astype(jnp.float32)
        labels_safe = jnp.maximum(labels, 0)
        loss = chunked_softmax_xent(head, x, labels_safe,
                                    chunk=cfg.loss_chunk, mask=mask)
        total = loss + 0.01 * aux_total
        return total, {"xent": loss, "aux": aux_total}

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int, *,
                   cross_len: int = 0) -> Any:
        """Zero-filled cache pytree.  Layout mirrors params: 'prefix' list +
        'stage' stacked (R, ...) per period position."""
        cfg = self.cfg

        def alloc(spec: LayerSpec, stacked: bool):
            shapes = layer_cache_shape(cfg, spec, batch, max_seq)
            if spec.has_cross:
                hd = cfg.resolved_head_dim
                shapes["ck"] = ((batch, cross_len, cfg.num_kv_heads, hd),
                                cfg.dtype())
                shapes["cv"] = ((batch, cross_len, cfg.num_kv_heads, hd),
                                cfg.dtype())
            out = {}
            for name, (shape, dt) in shapes.items():
                if stacked:
                    shape = (self.repeats,) + shape
                out[name] = jnp.zeros(shape, dt)
            return out

        cache: Dict[str, Any] = {}
        if self.prefix_specs:
            cache["prefix"] = [alloc(s, False) for s in self.prefix_specs]
        cache["stage"] = [alloc(s, True) for s in self.period_specs]
        return cache

    def abstract_cache(self, batch: int, max_seq: int, *, cross_len: int = 0):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_seq, cross_len=cross_len))

    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                max_seq: Optional[int] = None
                ) -> Tuple[jnp.ndarray, Any]:
        """Full prompt prefill.  Returns (last-token logits (B, V), cache).
        Chunked (by_blocks) prefill lives in repro.serve.prefill."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_seq = max_seq or S
        kv_states = None
        cross_payload = None
        if cfg.family == "vlm":
            kv_states = batch["image_embeds"].astype(cfg.dtype())
        x = self._embed_in(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        if cfg.is_encdec:
            enc_out = self._encode(params, batch["frames"])
            kv_states = enc_out
            x = x + params["dec_pos"][:S].astype(cfg.dtype())

        prefix_payloads = []
        for spec, lp in zip(self.prefix_specs, params.get("prefix", [])):
            x, _, pl = layer_apply(cfg, spec, lp, x, positions,
                                   kv_states=kv_states, collect_cache=True,
                                   moe_strategy=self.moe_strategy,
                                   scan_impl=self.scan_impl)
            prefix_payloads.append(pl)

        x, _, stage_payloads = self._stage_scan(
            params, x, positions, kv_states=kv_states, collect_cache=True)
        x = self._norm(params["final_norm"], x)
        logits = self._logits_head(params, x[:, -1:])[:, 0]

        cache = self._payloads_to_cache(prefix_payloads, stage_payloads,
                                        B, S, max_seq)
        return logits, cache

    def _payloads_to_cache(self, prefix_payloads, stage_payloads, B, S,
                           max_seq):
        """Place prefill payloads into (possibly larger) cache buffers."""
        cfg = self.cfg

        def place(payload, spec: LayerSpec, stacked: bool):
            out = {}
            for name, arr in payload.items():
                if name in ("k", "v", "latent"):
                    if max_seq != S:
                        # seq axis: stacked → axis 2 else axis 1
                        ax = 2 if stacked else 1
                        shape = list(arr.shape)
                        shape[ax] = max_seq
                        buf = jnp.zeros(tuple(shape), arr.dtype)
                        idx = [slice(None)] * len(shape)
                        idx[ax] = slice(0, S)
                        arr = buf.at[tuple(idx)].set(arr)
                out[name] = arr
            return out

        cache: Dict[str, Any] = {}
        if prefix_payloads:
            cache["prefix"] = [place(pl, s, False) for pl, s in
                               zip(prefix_payloads, self.prefix_specs)]
        cache["stage"] = [place(pl, s, True) for pl, s in
                          zip(stage_payloads, self.period_specs)]
        return cache

    def prefill_chunk(self, params: Params, tokens: jnp.ndarray, cache: Any,
                      pos0, *, all_logits: bool = False
                      ) -> Tuple[jnp.ndarray, Any]:
        """One by_blocks prefill chunk: tokens (B, c) at positions
        [pos0, pos0+c).  Returns (logits, updated cache); logits are the
        last position's (B, V) by default, or the whole chunk's (B, c, V)
        with ``all_logits=True`` — mixed-length batches gather each row's
        last *real* position from these.  ``pos0`` is a traced scalar:
        compilation is keyed on the chunk length only, so the by_blocks
        schedule compiles one program per distinct chunk size."""
        from .transformer import layer_prefill_chunk
        cfg = self.cfg
        B, c = tokens.shape
        x = self._embed_in(params, tokens)
        if cfg.is_encdec:
            dec_pos = jax.lax.dynamic_slice_in_dim(params["dec_pos"],
                                                   pos0, c, 0)
            x = x + dec_pos.astype(cfg.dtype())

        new_cache: Dict[str, Any] = {}
        if self.prefix_specs:
            new_prefix = []
            for spec, lp, lc in zip(self.prefix_specs, params["prefix"],
                                    cache["prefix"]):
                x, lc2 = layer_prefill_chunk(cfg, spec, lp, x, lc, pos0,
                                             moe_strategy=self.moe_strategy,
                                             scan_impl=self.scan_impl)
                new_prefix.append(lc2)
            new_cache["prefix"] = new_prefix

        specs = self.period_specs

        def body(x, xs):
            stage_lp, stage_cache = xs
            new_slices = []
            for pos, spec in enumerate(specs):
                x, c2 = layer_prefill_chunk(
                    cfg, spec, stage_lp[pos], x, stage_cache[pos], pos0,
                    moe_strategy=self.moe_strategy,
                    scan_impl=self.scan_impl)
                new_slices.append(c2)
            return x, new_slices

        x, new_stage = jax.lax.scan(body, x, (params["stage"],
                                              cache["stage"]))
        new_cache["stage"] = new_stage
        x = self._norm(params["final_norm"], x)
        if all_logits:
            logits = self._logits_head(params, x)          # (B, c, V)
        else:
            logits = self._logits_head(params, x[:, -1:])[:, 0]
        return logits, new_cache

    def encode_to_cache(self, params: Params, batch: Dict[str, jnp.ndarray],
                        cache: Any) -> Any:
        """Populate cross-attention K/V (ck/cv) from encoder output / image
        embeddings — run once before chunked prefill of cross-attn models."""
        cfg = self.cfg
        if cfg.family == "vlm":
            kv_states = batch["image_embeds"].astype(cfg.dtype())
        elif cfg.is_encdec:
            kv_states = self._encode(params, batch["frames"])
        else:
            return cache
        hd = cfg.resolved_head_dim
        B, Skv, _ = kv_states.shape

        def fill(lp_cross, lc):
            ck = jnp.einsum("bsd,de->bse", kv_states,
                            lp_cross["wk"]).reshape(B, Skv,
                                                    cfg.num_kv_heads, hd)
            cv = jnp.einsum("bsd,de->bse", kv_states,
                            lp_cross["wv"]).reshape(B, Skv,
                                                    cfg.num_kv_heads, hd)
            lc = dict(lc)
            lc["ck"], lc["cv"] = ck, cv
            return lc

        new_cache = dict(cache)
        if self.prefix_specs:
            new_cache["prefix"] = [
                fill(lp["cross"], lc) if spec.has_cross else lc
                for spec, lp, lc in zip(self.prefix_specs, params["prefix"],
                                        cache["prefix"])]
        new_stage = []
        for pos, spec in enumerate(self.period_specs):
            lc = cache["stage"][pos]
            if spec.has_cross:
                wk = params["stage"][pos]["cross"]["wk"]   # (R, D, KV·hd)
                wv = params["stage"][pos]["cross"]["wv"]
                R = wk.shape[0]
                ck = jnp.einsum("bsd,rde->rbse", kv_states, wk).reshape(
                    R, B, Skv, cfg.num_kv_heads, hd)
                cv = jnp.einsum("bsd,rde->rbse", kv_states, wv).reshape(
                    R, B, Skv, cfg.num_kv_heads, hd)
                lc = dict(lc)
                lc["ck"], lc["cv"] = ck, cv
            new_stage.append(lc)
        new_cache["stage"] = new_stage
        return new_cache

    def decode_step(self, params: Params, tokens: jnp.ndarray, cache: Any,
                    lengths: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
        """One token per sequence.  tokens: (B,), lengths: (B,) current valid
        prefix length.  Returns (logits (B, vocab), new cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = self._embed_in(params, tokens[:, None])
        positions = lengths
        if cfg.is_encdec:
            x = x + params["dec_pos"][lengths][:, None].astype(cfg.dtype())

        new_cache: Dict[str, Any] = {}
        if self.prefix_specs:
            new_prefix = []
            for spec, lp, lc in zip(self.prefix_specs, params["prefix"],
                                    cache["prefix"]):
                x, lc2 = layer_decode(cfg, spec, lp, x, lc, positions,
                                      lengths, moe_strategy=self.moe_strategy)
                new_prefix.append(lc2)
            new_cache["prefix"] = new_prefix

        specs = self.period_specs

        def body(x, xs):
            stage_lp, stage_cache = xs
            new_slices = []
            for pos, spec in enumerate(specs):
                x, c2 = layer_decode(cfg, spec, stage_lp[pos], x,
                                     stage_cache[pos], positions, lengths,
                                     moe_strategy=self.moe_strategy)
                new_slices.append(c2)
            return x, new_slices

        x, new_stage = jax.lax.scan(body, x, (params["stage"],
                                              cache["stage"]))
        new_cache["stage"] = new_stage
        x = self._norm(params["final_norm"], x)
        logits = self._logits_head(params, x)[:, 0]
        return logits, new_cache


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)


__all__ = ["Model", "build_model", "sinusoidal_positions"]
