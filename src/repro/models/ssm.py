"""State-space / recurrent mixers: Mamba (Jamba), mLSTM + sLSTM (xLSTM).

Training/prefill paths are *chunked*: the sequence is cut into chunks by the
core scheduler's geometry (``mlstm_chunk`` config, aligned like every other
block size in this framework), each chunk is processed with an intra-chunk
parallel form (associative scan for Mamba, stabilized attention-like form for
mLSTM), and a small recurrent state is carried between chunks with
``lax.scan``.  This is the TPU-native adaptation of these architectures: the
(B,S,Di,N) discretized tensors that CUDA kernels fuse are never materialized
beyond one chunk.

Decode paths are O(1) per token over explicit state pytrees.

sLSTM is genuinely sequential (its recurrence is why xLSTM mixes block types),
so its training path is an honest ``lax.scan`` over time.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import Params, dense_init

F32 = jnp.float32


def _c(x, *axes):
    """Sharding constraint shorthand (no-op outside a mesh context).

    GSPMD's propagation through checkpoint+scan+associative_scan loses the
    TP sharding of SSM activations (observed: full-Di fp32 tensors replicated
    per chip in the Jamba dry-run).  Explicit constraints at the block
    boundaries pin it down."""
    from ..dist.sharding import constrain, dp
    spec = [dp() if a == "dp" else a for a in axes]
    return constrain(x, P(*spec))


# ---------------------------------------------------------------------------
# causal depthwise conv (shared by mamba / xlstm blocks)
# ---------------------------------------------------------------------------

def causal_conv_init(key, dim: int, width: int, dtype) -> Params:
    w = (jax.random.normal(key, (width, dim), F32) / math.sqrt(width)).astype(dtype)
    return {"w": w, "b": jnp.zeros((dim,), dtype)}


def causal_conv(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,Di) depthwise causal conv, width = params['w'].shape[0]."""
    w = params["w"]
    width = w.shape[0]
    pad = jnp.zeros(x.shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(width):  # width is 4 — unrolled taps beat a conv op here
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out + params["b"]


def causal_conv_step(params: Params, x: jnp.ndarray, buf: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,Di); buf: (B,width-1,Di) past inputs → (y (B,Di), new buf)."""
    w = params["w"]
    width = w.shape[0]
    full = jnp.concatenate([buf, x[:, None, :]], axis=1)   # (B,width,Di)
    y = jnp.einsum("bwd,wd->bd", full, w) + params["b"]
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# Mamba (S6) block
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype()
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dt),
        "conv": causal_conv_init(ks[1], di, cfg.ssm_conv_dim, dt),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * n, dt),
        "dt_proj": dense_init(ks[3], dt_rank, di, dt),
        "dt_bias": jnp.zeros((di,), dt),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=F32), (di, n))).astype(F32),
        "D": jnp.ones((di,), F32),
        "out_proj": dense_init(ks[4], di, d, dt),
    }


def _mamba_inner(params: Params, cfg: ModelConfig, xc: jnp.ndarray,
                 h0: jnp.ndarray, *, scan_impl: str = "lax"
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One chunk of the selective scan.  xc: (B,c,Di) post-conv activations,
    h0: (B,Di,N) carry → (y (B,c,Di), h_final).  ``scan_impl="pallas"``
    routes the recurrence through the single-launch chunked scan
    (kernels/ssm_scan.py); "lax" is the associative_scan reference and the
    differentiable training path (interpret-mode Pallas has no VJP)."""
    n = cfg.ssm_state_dim
    dt_rank = max(1, cfg.d_model // 16)
    proj = jnp.einsum("bcd,de->bce", xc, params["x_proj"])
    dt_in, Bs, Cs = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bcr,rd->bcd", dt_in, params["dt_proj"])
        + params["dt_bias"]).astype(F32)                       # (B,c,Di)
    delta = _c(delta, "dp", None, "model")
    A = -jnp.exp(params["A_log"])                               # (Di,N)
    dA = _c(jnp.exp(delta[..., None] * A), "dp", None, "model", None)
    dBx = (delta * xc.astype(F32))[..., None] * Bs.astype(F32)[:, :, None, :]
    dBx = _c(dBx, "dp", None, "model", None)

    if scan_impl == "pallas":
        from ..kernels.ssm_scan import mamba_assoc_scan
        states = mamba_assoc_scan(dA, dBx, h0)                  # (B,c,Di,N)
    else:
        from ..kernels.ssm_scan import affine_combine
        prefA, within = jax.lax.associative_scan(affine_combine, (dA, dBx),
                                                 axis=1)
        states = within + prefA * h0[:, None]                   # (B,c,Di,N)
    states = _c(states, "dp", None, "model", None)
    y = jnp.einsum("bcdn,bcn->bcd", states, Cs.astype(F32))
    y = y + params["D"] * xc.astype(F32)
    return y.astype(xc.dtype), states[:, -1]


def mamba_forward(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                  h0: Optional[jnp.ndarray] = None,
                  conv_buf: Optional[jnp.ndarray] = None,
                  scan_impl: str = "lax"
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B,S,D) → (y (B,S,D), state {ssm, conv})."""
    B, S, D = x.shape
    di = cfg.ssm_expand * D
    n = cfg.ssm_state_dim
    chunk = min(cfg.mlstm_chunk, S)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xz = _c(xz, "dp", None, "model")
    xin, z = jnp.split(xz, 2, axis=-1)
    if conv_buf is None:
        xc = causal_conv(params["conv"], xin)
    else:  # continuing prefill: prepend buffered inputs
        width = params["conv"]["w"].shape[0]
        ext = jnp.concatenate([conv_buf, xin], axis=1)
        xc = causal_conv(params["conv"], ext)[:, width - 1:]
    xc = _c(jax.nn.silu(xc), "dp", None, "model")

    h0 = h0 if h0 is not None else jnp.zeros((B, di, n), F32)
    h0 = _c(h0, "dp", "model", None)
    if S % chunk == 0 and S > chunk:
        xs = xc.reshape(B, S // chunk, chunk, di).transpose(1, 0, 2, 3)

        def body(h, xck):
            y, h2 = _mamba_inner(params, cfg, xck, h, scan_impl=scan_impl)
            return h2, y

        hF, ys = jax.lax.scan(body, h0, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    else:
        y, hF = _mamba_inner(params, cfg, xc, h0, scan_impl=scan_impl)

    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    width = params["conv"]["w"].shape[0]
    if S >= width - 1:
        new_buf = xin[:, S - (width - 1):]
    else:
        base = (conv_buf if conv_buf is not None
                else jnp.zeros((B, width - 1, di), x.dtype))
        new_buf = jnp.concatenate([base, xin], axis=1)[:, -(width - 1):]
    return out, {"ssm": hF, "conv": new_buf}


def mamba_step(params: Params, cfg: ModelConfig, x: jnp.ndarray,
               state: Dict[str, jnp.ndarray]
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B,1,D) decode step."""
    B = x.shape[0]
    n = cfg.ssm_state_dim
    dt_rank = max(1, cfg.d_model // 16)
    xz = jnp.einsum("bd,de->be", x[:, 0], params["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_buf = causal_conv_step(params["conv"], xin, state["conv"])
    xc = jax.nn.silu(xc)
    proj = jnp.einsum("bd,de->be", xc, params["x_proj"])
    dt_in, Bs, Cs = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("br,rd->bd", dt_in, params["dt_proj"])
        + params["dt_bias"]).astype(F32)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(delta[..., None] * A)                          # (B,Di,N)
    dBx = (delta * xc.astype(F32))[..., None] * Bs.astype(F32)[:, None, :]
    h = dA * state["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cs.astype(F32)) + params["D"] * xc.astype(F32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bd,de->be", y, params["out_proj"])[:, None]
    return out, {"ssm": h, "conv": new_buf}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM's matrix-memory block) — stabilized chunkwise parallel form
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    dt = cfg.pdtype()
    return {
        "up": dense_init(ks[0], d, 2 * di, dt),
        "conv": causal_conv_init(ks[1], di, cfg.ssm_conv_dim, dt),
        # block-diagonal per-head projections (the official mLSTM shape —
        # full matrices would quadruple the parameter count at 4 heads)
        "wq": (jax.random.normal(ks[2], (h, di // h, di // h), F32)
               / math.sqrt(di // h)).astype(dt),
        "wk": (jax.random.normal(ks[3], (h, di // h, di // h), F32)
               / math.sqrt(di // h)).astype(dt),
        "wv": (jax.random.normal(ks[4], (h, di // h, di // h), F32)
               / math.sqrt(di // h)).astype(dt),
        "wi": dense_init(ks[5], di, h, dt),
        "wf": dense_init(ks[6], di, h, dt),
        "norm_scale": jnp.ones((di,), dt),
        "down": dense_init(ks[7], di, d, dt),
    }


def _headwise_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, nheads: int,
                      eps: float = 1e-5) -> jnp.ndarray:
    B, S, di = x.shape
    xh = x.reshape(B, S, nheads, di // nheads).astype(F32)
    var = jnp.mean(jnp.square(xh), axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, S, di) * scale.astype(F32)).astype(x.dtype)


def _mlstm_intra(q, k, v, log_i, log_f, carry):
    """Chunk outputs given the state ENTERING the chunk.

    q,k,v: (B,c,H,dh); log_i/log_f: (B,c,H) fp32.
    carry = (C (B,H,dh,dh), n (B,H,dh), m (B,H)) fp32.
    Returns (h (B,c,H,dh), F (B,c,H) inclusive gate cumsum, F_tot (B,H)).
    """
    B, c, H, dh = q.shape
    Chat, nhat, m_prev = carry
    scale = 1.0 / math.sqrt(dh)

    F = jnp.cumsum(log_f, axis=1)                    # (B,c,H) inclusive
    F_tot = F[:, -1]                                 # (B,H)
    # intra-chunk log-decay matrix b_ij = F_i - log_f_i? — use exclusive cumsum
    # for the query side so position i attends to j ≤ i with gain
    # exp(F_i - F_j + log_i_j): F here must be *inclusive of j's gate* on the
    # key side and exclusive on the diagonal.  Standard form:
    #   b_ij = (F_i - F_j) + log_i_j  for j ≤ i, where F is inclusive cumsum.
    b = (F[:, :, None, :] - F[:, None, :, :]
         + log_i[:, None, :, :])                     # (B,c_q,c_k,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    b = jnp.where(tri[None, :, :, None], b, -jnp.inf)

    g = F + m_prev[:, None, :]                       # inter gain (B,c,H)
    m_intra = jnp.max(b, axis=2)                     # (B,c,H)
    m_i = jnp.maximum(m_intra, g)
    m_i = jnp.maximum(m_i, -1e30)                    # guard all -inf rows

    P = jnp.exp(b - m_i[:, :, None, :])              # (B,c,c,H)
    qk = jnp.einsum("bihd,bjhd->bijh", q.astype(F32), k.astype(F32)) * scale
    W = P * qk                                       # weighted intra scores
    num_intra = jnp.einsum("bijh,bjhd->bihd", W, v.astype(F32))
    den_intra = jnp.einsum("bijh,bjhd->bihd", P, k.astype(F32) * scale)
    den_intra = jnp.einsum("bihd,bihd->bih", q.astype(F32), den_intra)

    inter_gain = jnp.exp(g - m_i)                    # (B,c,H)
    num_inter = jnp.einsum("bihd,bhde->bihe", q.astype(F32) * scale, Chat) \
        * inter_gain[..., None]
    den_inter = jnp.einsum("bihd,bhd->bih", q.astype(F32) * scale, nhat) \
        * inter_gain

    num = num_intra + num_inter
    den = den_intra + den_inter
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
    return h, F, F_tot


def _mlstm_chunk(q, k, v, log_i, log_f, carry):
    """One stabilized chunk: intra outputs + sequential carry update.

    Returns (h (B,c,H,dh), new carry).  The carry update is exactly one
    application of the ``logspace_affine_combine`` monoid
    (kernels/ssm_scan.py) to the chunk's summary — the identity the
    Pallas chunk-parallel path in :func:`mlstm_forward` rests on.
    """
    Chat, nhat, m_prev = carry
    h, F, F_tot = _mlstm_intra(q, k, v, log_i, log_f, carry)

    # carry update
    decay_k = F_tot[:, None, :] - F + log_i          # (B,c,H): gate j→end
    m_next = jnp.maximum(F_tot + m_prev, jnp.max(decay_k, axis=1))
    kv_gain = jnp.exp(decay_k - m_next[:, None, :])  # (B,c,H)
    C_new = (jnp.exp(F_tot + m_prev - m_next)[:, :, None, None] * Chat
             + jnp.einsum("bjh,bjhd,bjhe->bhde", kv_gain, k.astype(F32),
                          v.astype(F32)))
    n_new = (jnp.exp(F_tot + m_prev - m_next)[:, :, None] * nhat
             + jnp.einsum("bjh,bjhd->bhd", kv_gain, k.astype(F32)))
    return h, (C_new, n_new, m_next)


def _mlstm_chunk_summary(k, v, log_i, log_f):
    """The chunk's element of the log-space affine monoid.

    k,v: (B,c,H,dh); log_i/log_f: (B,c,H) fp32 → (la, m_loc, Ĉ, n̂):
    the whole chunk acts on the entering state as
    ``(C, n) ↦ exp(la)·(C, n) + exp(m_loc)·(Ĉ, n̂)`` with
    ``la = ΣF`` (total log forget) and ``(Ĉ, n̂)`` the chunk's own
    key-value outer products at scale ``exp(m_loc)``.  Independent of the
    carry, so every chunk computes its summary in parallel.
    """
    F = jnp.cumsum(log_f, axis=1)                    # (B,c,H)
    F_tot = F[:, -1]                                 # (B,H)
    decay_k = F_tot[:, None, :] - F + log_i          # (B,c,H)
    m_loc = jnp.maximum(jnp.max(decay_k, axis=1), -1e30)
    gain = jnp.exp(decay_k - m_loc[:, None, :])
    Chat = jnp.einsum("bjh,bjhd,bjhe->bhde", gain, k.astype(F32),
                      v.astype(F32))
    nhat = jnp.einsum("bjh,bjhd->bhd", gain, k.astype(F32))
    return F_tot, m_loc, Chat, nhat


def mlstm_forward(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                  state: Optional[Dict[str, jnp.ndarray]] = None,
                  scan_impl: str = "lax"
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, S, D = x.shape
    di = cfg.ssm_expand * D
    H = cfg.num_heads
    dh = di // H
    chunk = min(cfg.mlstm_chunk, S)

    xz = jnp.einsum("bsd,de->bse", x, params["up"])
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_buf = state["conv"] if state is not None else None
    if conv_buf is None:
        xc = causal_conv(params["conv"], xin)
    else:
        width = params["conv"]["w"].shape[0]
        ext = jnp.concatenate([conv_buf, xin], axis=1)
        xc = causal_conv(params["conv"], ext)[:, width - 1:]
    xc = jax.nn.silu(xc)

    xch = xc.reshape(B, S, H, dh)
    xih = xin.reshape(B, S, H, dh)
    q = _c(jnp.einsum("bshd,hde->bshe", xch, params["wq"]),
           "dp", None, None, "model")
    k = _c(jnp.einsum("bshd,hde->bshe", xch, params["wk"]),
           "dp", None, None, "model")
    v = _c(jnp.einsum("bshd,hde->bshe", xih, params["wv"]),
           "dp", None, None, "model")
    log_i = jnp.einsum("bsd,dh->bsh", xc, params["wi"]).astype(F32)
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", xc, params["wf"]).astype(F32))

    if state is not None:
        carry = (state["C"], state["n"], state["m"])
    else:
        carry = (jnp.zeros((B, H, dh, dh), F32), jnp.zeros((B, H, dh), F32),
                 jnp.zeros((B, H), F32))
    carry = (_c(carry[0], "dp", None, "model", None),
             _c(carry[1], "dp", None, "model"), carry[2])

    if S % chunk == 0 and S > chunk:
        nc = S // chunk
        def rs(t, last):
            return t.reshape((B, nc, chunk) + last).transpose(
                (1, 0, 2) + tuple(range(3, 3 + len(last))))
        qs, ks_, vs = rs(q, (H, dh)), rs(k, (H, dh)), rs(v, (H, dh))
        lis, lfs = rs(log_i, (H,)), rs(log_f, (H,))

        if scan_impl == "pallas":
            # chunk-parallel form: (1) every chunk's monoid summary in
            # parallel, (2) ONE pallas launch scans the carries entering
            # each chunk, (3) every chunk's outputs in parallel against
            # its entering carry.  The sequential lax.scan below applies
            # the same combine chunk-by-chunk, so the two paths agree to
            # fp32 reassociation error (pinned in tests/test_ssm_scan.py).
            from ..kernels.ssm_scan import (logspace_affine_combine,
                                            mlstm_carry_scan)
            C0, n0, m0 = carry
            la, mS, CS, nS = jax.vmap(_mlstm_chunk_summary)(
                ks_, vs, lis, lfs)
            la_e, m_e, C_e, n_e = mlstm_carry_scan(
                la, mS, CS, nS, (m0, C0, n0))
            hs, _, _ = jax.vmap(_mlstm_intra)(
                qs, ks_, vs, lis, lfs, (C_e, n_e, m_e))
            _, mF, CF, nF = logspace_affine_combine(
                (la_e[-1], m_e[-1], C_e[-1], n_e[-1]),
                (la[-1], mS[-1], CS[-1], nS[-1]))
            carry = (CF, nF, mF)
        else:
            def body(c, xs):
                qc, kc, vc, lic, lfc = xs
                h, c2 = _mlstm_chunk(qc, kc, vc, lic, lfc, c)
                return c2, h

            carry, hs = jax.lax.scan(body, carry, (qs, ks_, vs, lis, lfs))
        h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
    else:
        h, carry = _mlstm_chunk(q, k, v, log_i, log_f, carry)

    h = h.reshape(B, S, di).astype(x.dtype)
    h = _headwise_rmsnorm(h, params["norm_scale"], H)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", h, params["down"])

    width = params["conv"]["w"].shape[0]
    if S >= width - 1:
        new_buf = xin[:, S - (width - 1):]
    else:
        base = (conv_buf if conv_buf is not None
                else jnp.zeros((B, width - 1, di), x.dtype))
        new_buf = jnp.concatenate([base, xin], axis=1)[:, -(width - 1):]
    C_, n_, m_ = carry
    return out, {"C": C_, "n": n_, "m": m_, "conv": new_buf}


def mlstm_step(params: Params, cfg: ModelConfig, x: jnp.ndarray,
               state: Dict[str, jnp.ndarray]
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B,1,D) decode step with matrix-memory state."""
    B = x.shape[0]
    D = x.shape[-1]
    di = cfg.ssm_expand * D
    H = cfg.num_heads
    dh = di // H
    scale = 1.0 / math.sqrt(dh)

    xz = jnp.einsum("bd,de->be", x[:, 0], params["up"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_buf = causal_conv_step(params["conv"], xin, state["conv"])
    xc = jax.nn.silu(xc)
    q = jnp.einsum("bhd,hde->bhe", xc.reshape(B, H, dh), params["wq"])
    k = jnp.einsum("bhd,hde->bhe", xc.reshape(B, H, dh), params["wk"])
    v = jnp.einsum("bhd,hde->bhe", xin.reshape(B, H, dh), params["wv"])
    log_i = jnp.einsum("bd,dh->bh", xc, params["wi"]).astype(F32)
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bd,dh->bh", xc, params["wf"]).astype(F32))

    m_prev, C_prev, n_prev = state["m"], state["C"], state["n"]
    m_t = jnp.maximum(log_f + m_prev, log_i)
    f_t = jnp.exp(log_f + m_prev - m_t)
    i_t = jnp.exp(log_i - m_t)
    kf, vf, qf = k.astype(F32), v.astype(F32), q.astype(F32) * scale
    C_t = f_t[..., None, None] * C_prev + i_t[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", kf, vf)
    n_t = f_t[..., None] * n_prev + i_t[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C_t)
    den = jnp.einsum("bhd,bhd->bh", qf, n_t)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    h = h.reshape(B, 1, di).astype(x.dtype)
    h = _headwise_rmsnorm(h, params["norm_scale"], H)
    h = h[:, 0] * jax.nn.silu(z)
    out = jnp.einsum("bd,de->be", h, params["down"])[:, None]
    return out, {"C": C_t, "n": n_t, "m": m_t, "conv": new_buf}


# ---------------------------------------------------------------------------
# sLSTM — honest sequential recurrence
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ff = int(round(4 * d / 3 / 64)) * 64 or 64
    ks = jax.random.split(key, 7)
    dt = cfg.pdtype()
    return {
        "conv": causal_conv_init(ks[0], d, cfg.ssm_conv_dim, dt),
        "w": dense_init(ks[1], d, 4 * d, dt),       # z,i,f,o input weights
        "r": (jax.random.normal(ks[2], (4, h, dh, dh), F32)
              / math.sqrt(dh)).astype(dt),          # recurrent, block-diag
        "b": jnp.zeros((4 * d,), dt),
        "norm_scale": jnp.ones((d,), dt),
        "up": dense_init(ks[3], d, 2 * ff, dt),
        "down": dense_init(ks[4], ff, d, dt),
    }


def _slstm_cell(params: Params, cfg: ModelConfig, wx: jnp.ndarray,
                st: Tuple[jnp.ndarray, ...]):
    """wx: (B,4D) precomputed input contribution; state (c,n,h,m) each (B,D)."""
    B, d4 = wx.shape
    d = d4 // 4
    h_heads = cfg.num_heads
    dh = d // h_heads
    c, n, hprev, m = st
    rh = jnp.einsum("bhd,khde->bkhe",
                    hprev.reshape(B, h_heads, dh).astype(F32),
                    params["r"].astype(F32)).reshape(B, 4 * d)
    pre = wx.astype(F32) + rh + params["b"].astype(F32)
    z_, i_, f_, o_ = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_)
    o = jax.nn.sigmoid(o_)
    logf = jax.nn.log_sigmoid(f_)
    m_t = jnp.maximum(logf + m, i_)
    i_g = jnp.exp(i_ - m_t)
    f_g = jnp.exp(logf + m - m_t)
    c_t = f_g * c + i_g * z
    n_t = f_g * n + i_g
    h_t = o * c_t / jnp.maximum(n_t, 1.0)
    return (c_t, n_t, h_t, m_t)


def slstm_forward(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                  state: Optional[Dict[str, jnp.ndarray]] = None
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, S, D = x.shape
    conv_buf = state["conv"] if state is not None else None
    if conv_buf is None:
        xc = causal_conv(params["conv"], x)
    else:
        width = params["conv"]["w"].shape[0]
        ext = jnp.concatenate([conv_buf, x], axis=1)
        xc = causal_conv(params["conv"], ext)[:, width - 1:]
    xc = jax.nn.silu(xc)
    wx = jnp.einsum("bsd,de->bse", xc, params["w"])        # (B,S,4D)

    if state is not None:
        st = (state["c"], state["n"], state["h"], state["m"])
    else:
        z = jnp.zeros((B, D), F32)
        st = (z, z, z, jnp.full((B, D), -1e30, F32))

    def body(st, wxt):
        st2 = _slstm_cell(params, cfg, wxt, st)
        return st2, st2[2]

    st, hs = jax.lax.scan(body, st, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)              # (B,S,D)

    # headwise norm + GEGLU projection
    h = _headwise_rmsnorm(h, params["norm_scale"], cfg.num_heads)
    uu = jnp.einsum("bsd,de->bse", h, params["up"])
    a, g = jnp.split(uu, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", a * jax.nn.gelu(g), params["down"])

    width = params["conv"]["w"].shape[0]
    if S >= width - 1:
        new_buf = x[:, S - (width - 1):]
    else:
        base = (conv_buf if conv_buf is not None
                else jnp.zeros((B, width - 1, D), x.dtype))
        new_buf = jnp.concatenate([base, x], axis=1)[:, -(width - 1):]
    c, n, hh, m = st
    return out, {"c": c, "n": n, "h": hh, "m": m, "conv": new_buf}


def slstm_step(params: Params, cfg: ModelConfig, x: jnp.ndarray,
               state: Dict[str, jnp.ndarray]
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B = x.shape[0]
    xc, new_buf = causal_conv_step(params["conv"], x[:, 0], state["conv"])
    xc = jax.nn.silu(xc)
    wx = jnp.einsum("bd,de->be", xc, params["w"])
    st = (state["c"], state["n"], state["h"], state["m"])
    c, n, h, m = _slstm_cell(params, cfg, wx, st)
    hn = _headwise_rmsnorm(h.astype(x.dtype)[:, None], params["norm_scale"],
                           cfg.num_heads)
    uu = jnp.einsum("bsd,de->bse", hn, params["up"])
    a, g = jnp.split(uu, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", a * jax.nn.gelu(g), params["down"])
    return out, {"c": c, "n": n, "h": h, "m": m, "conv": new_buf}


__all__ = [
    "causal_conv_init", "causal_conv", "causal_conv_step",
    "mamba_init", "mamba_forward", "mamba_step",
    "mlstm_init", "mlstm_forward", "mlstm_step",
    "slstm_init", "slstm_forward", "slstm_step",
]
