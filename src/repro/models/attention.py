"""Attention: GQA (llama/minitron/yi/chatglm), MLA (deepseek), cross-attn (VLM,
enc-dec), plus decode paths over KV caches.

Blockwise attention is the pure-JAX flash attention used for training/prefill.
Its (q-block × kv-block) tiling is a Kvik plan: the lower-triangular tile set
of a causal attention is exactly the leaf set of a ``TileGrid2D`` division, and
the q/kv chunk sizes are chosen by the same adaptors that size every other
task in this framework (see ``attn_tile_plan``).  Upper-triangle tiles are
*skipped at plan time* — the compiled program does no masked-out FLOPs at
block granularity, which matters for the §Roofline compute term.

The Pallas kernel (``repro.kernels.flash_attention``) implements the same
schedule for the TPU target; this module is the lowering-friendly reference
used by the dry-run (Pallas custom-calls do not partition under GSPMD).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import SeqWork, bound_depth, build_plan
from .layers import Params, apply_rope, dense_init, rope_table

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Tile planning (the Kvik hook)
# ---------------------------------------------------------------------------

def attn_chunk_sizes(seq_q: int, seq_kv: int, *, target_chunk: int = 2048
                     ) -> Tuple[int, int]:
    """Pick (q_chunk, kv_chunk) via a bound_depth plan over the sequence.

    The depth is chosen so leaves are ≈ ``target_chunk`` — the same policy
    TBB's grain-size heuristic encodes, expressed as a Kvik adaptor.
    """
    def leaf(seq: int) -> int:
        depth = max(0, math.ceil(math.log2(max(1, seq / target_chunk))))
        plan = build_plan(bound_depth(SeqWork(0, seq), depth))
        sizes = plan.leaf_sizes()
        return max(sizes)
    return leaf(seq_q), leaf(seq_kv)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — training / prefill
# ---------------------------------------------------------------------------

def _chunk_attn_update(carry, qc, kc, vc, mask):
    """One (q-chunk, kv-chunk) tile with running-softmax state.

    qc: (B, KV, G, Cq, hd)   kc: (B, Ck, KV, hd)   vc: (B, Ck, KV, hv)
    carry: (m, l, acc) with shapes (B,KV,G,Cq), (B,KV,G,Cq), (B,KV,G,Cq,hv)
    mask: (Cq, Ck) additive (0 / -inf) or None.
    """
    m, l, acc = carry
    logits = jnp.einsum("bkgqd,bskd->bkgqs", qc, kc,
                        preferred_element_type=jnp.float32)
    if mask is not None:
        logits = logits + mask
    m_new = jnp.maximum(m, logits.max(axis=-1))
    p = jnp.exp(logits - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l = l * alpha + p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskv->bkgqv", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
    acc = acc * alpha[..., None] + pv
    return (m_new, l, acc)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool, scale: Optional[float] = None,
                        q_chunk: int = 1024, kv_chunk: int = 1024,
                        q_offset: int = 0) -> jnp.ndarray:
    """q: (B,Sq,H,hd)  k: (B,Sk,KV,hd)  v: (B,Sk,KV,hv) → (B,Sq,H,hv).

    GQA grouping is done by reshaping q to (B,KV,G,·,·) — repeated KV heads
    are never materialized.  Causal tiles above the diagonal are skipped at
    plan time (python loop ⇒ static slices in the jaxpr).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    hv = v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else (1.0 / math.sqrt(hd))
    q = (q * scale).reshape(B, Sq, KV, G, hd)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    n_q = (Sq + q_chunk - 1) // q_chunk

    # Pad KV to a tile multiple once; in-scan masks handle validity.  The
    # outer q loop stays in Python (static causal windows → true block-level
    # FLOP skipping); the inner kv walk is a lax.scan, so the HLO holds ONE
    # tile body per q-chunk instead of O(n²) unrolled tiles — the unrolled
    # form blew both compile time and buffer live-ranges (EXPERIMENTS §Perf).
    Skp = ((Sk + kv_chunk - 1) // kv_chunk) * kv_chunk
    if Skp != Sk:
        pad = Skp - Sk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # A *traced* q_offset (chunked prefill compiles once per chunk length,
    # not once per position) forfeits plan-time tile skipping: the causal
    # window is then enforced purely by the in-scan mask over the full kv
    # extent.  A static int keeps the block-level FLOP skipping.
    static_offset = isinstance(q_offset, int)
    outs = []
    for iq in range(n_q):
        q0 = iq * q_chunk
        cq = min(q_chunk, Sq - q0)
        qc = q[:, q0:q0 + cq].transpose(0, 2, 3, 1, 4)  # (B,KV,G,Cq,hd)
        # causal window for this q chunk: kv positions [0, q_offset+q0+cq)
        k_hi = min(Sk, q_offset + q0 + cq) if (causal and static_offset) \
            else Sk
        n_k = (k_hi + kv_chunk - 1) // kv_chunk
        q_pos = q_offset + q0 + jnp.arange(cq)

        m = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l = jnp.zeros((B, KV, G, cq), jnp.float32)
        acc = jnp.zeros((B, KV, G, cq, hv), jnp.float32)

        def body(carry, ik, q_pos=q_pos, k_hi=k_hi, qc=qc):
            kc = jax.lax.dynamic_slice_in_dim(k, ik * kv_chunk, kv_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, ik * kv_chunk, kv_chunk, 1)
            k_pos = ik * kv_chunk + jnp.arange(kv_chunk)
            valid = k_pos[None, :] < k_hi
            if causal:
                valid = valid & (q_pos[:, None] >= k_pos[None, :])
            mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
            return _chunk_attn_update(carry, qc, kc, vc, mask), None

        if n_k <= 2:
            carry = (m, l, acc)
            for ik in range(n_k):
                carry, _ = body(carry, ik)
        else:
            carry, _ = jax.lax.scan(body, (m, l, acc), jnp.arange(n_k))
        m, l, acc = carry
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, cq, H, hv)
                    .astype(v.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def plain_attention(q, k, v, *, causal: bool, scale=None, q_offset: int = 0):
    """Reference O(S²)-memory attention — smoke tests and tiny shapes."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else (1.0 / math.sqrt(hd))
    qg = (q * scale).reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        k_pos = jnp.arange(Sk)
        mask = jnp.where(q_pos[:, None] >= k_pos[None, :], 0.0, NEG_INF)
        logits = logits + mask
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskv->bkgqv", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, v.shape[-1]).astype(v.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """One-token attention against a cache.

    q: (B,H,hd)  caches: (B,S,KV,·)  lengths: (B,) valid prefix lengths.
    Softmax reductions over a sharded S axis lower to cheap scalar
    all-reduces — this is the distributed flash-decode combine (the paper's
    divide-and-conquer reduction tree) emerging from GSPMD for free.
    """
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = scale if scale is not None else (1.0 / math.sqrt(hd))
    qg = (q * scale).reshape(B, KV, G, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    mask = jnp.arange(S)[None, :] < lengths[:, None]  # (B,S)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskv->bkgv", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, v_cache.shape[-1]).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# Sharded (expanded + padded MHA) projections
#
# The production mesh fixes model-parallelism at 16.  GQA kv-head counts
# (2/4/8) and some q-head counts (24, 40) don't divide 16, so under a mesh
# context we rewrite the projections into an expanded MHA layout:
#   * kv heads are replicated up to the q-head count (grouping is undone),
#   * heads are zero-padded up to the next multiple of the model axis.
# Zero-padded q/v heads provably contribute exactly zero to the output, and
# wo's padded rows are zero so gradients are exact.  The overhead (repeated
# KV compute, Hp/H padding FLOPs) is measured in §Roofline — it is the cost
# of honoring the fixed mesh without touching stored parameters.
# ---------------------------------------------------------------------------

def padded_head_count(H: int, tp_n: int) -> int:
    return -(-H // tp_n) * tp_n


# 'minimal' replicates kv heads only up to the mesh width (llama3: 8→16,
# 2×); 'full' replicates to the q-head count (8→32, 4×) — kept switchable
# for the §Perf before/after measurements (hillclimb C).
KV_EXPANSION_MODE = ["minimal"]


def expanded_kv_count(H: int, KV: int, tp_n: int) -> int:
    if KV_EXPANSION_MODE[0] == "full":
        return padded_head_count(H, tp_n)
    if H % tp_n == 0:
        return KV if KV % tp_n == 0 else tp_n
    return padded_head_count(H, tp_n)


def expanded_qkv_weights(params: Params, cfg: ModelConfig, tp_n: int):
    """Expand (wq, wk, wv, wo) to a TP-aligned layout.

    q heads pad to Hp (next multiple of tp); kv heads replicate to KV_e =
    expanded_kv_count(...) — the minimal alignment that keeps every
    attention tensor local under 'model' sharding.  Zero-padded q heads and
    zero wo rows make padding exactly output- and gradient-neutral."""
    d = params["wq"].shape[0]
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    Hp = padded_head_count(H, tp_n)
    KV_e = expanded_kv_count(H, KV, tp_n)
    repl = KV_e // KV
    wq = params["wq"].reshape(d, H, hd)
    wq = jnp.pad(wq, ((0, 0), (0, Hp - H), (0, 0)))
    kv_idx = jnp.arange(KV_e) // repl
    wk = params["wk"].reshape(d, KV, hd)[:, kv_idx]
    wv = params["wv"].reshape(d, KV, hd)[:, kv_idx]
    wo = params["wo"].reshape(H, hd, d)
    wo = jnp.pad(wo, ((0, Hp - H), (0, 0), (0, 0)))
    return (wq.reshape(d, Hp * hd), wk.reshape(d, KV_e * hd),
            wv.reshape(d, KV_e * hd), wo.reshape(Hp * hd, d), Hp, KV_e)


def _attn_batch_spec():
    from ..dist.sharding import dp
    return dp()


def sharded_mha(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                positions: Optional[jnp.ndarray], *, causal: bool,
                kv_source: Optional[jnp.ndarray] = None, q_offset: int = 0,
                target_chunk: int = 2048) -> jnp.ndarray:
    """Self/cross attention in expanded-padded MHA layout, head-sharded over
    the model axis.  ``kv_source`` switches to cross-attention (no RoPE)."""
    from ..dist.sharding import constrain, current_ctx
    from jax.sharding import PartitionSpec as P
    ctx = current_ctx()
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    wq, wk, wv, wo, Hp, KV_e = expanded_qkv_weights(params, cfg, ctx.tp)
    kv_in = kv_source if kv_source is not None else x
    Skv = kv_in.shape[1]
    dpb = _attn_batch_spec()
    hspec = P(dpb, None, "model", None)

    q = jnp.einsum("bsd,de->bse", x, wq).reshape(B, S, Hp, hd)
    k = jnp.einsum("bsd,de->bse", kv_in, wk).reshape(B, Skv, KV_e, hd)
    v = jnp.einsum("bsd,de->bse", kv_in, wv).reshape(B, Skv, KV_e, hd)
    if kv_source is None and positions is not None:
        rd = int(hd * cfg.rotary_fraction)
        rd -= rd % 2
        cos, sin = rope_table(positions, rd, cfg.rope_theta)
        q = apply_rope(q, cos, sin, rotary_dims=rd)
        k = apply_rope(k, cos, sin, rotary_dims=rd)
    q, k, v = constrain(q, hspec), constrain(k, hspec), constrain(v, hspec)

    qc, kc = attn_chunk_sizes(S, Skv, target_chunk=target_chunk)
    if S <= 256 and Skv <= 1024:
        o = plain_attention(q, k, v, causal=causal, q_offset=q_offset)
    else:
        o = blockwise_attention(q, k, v, causal=causal, q_chunk=qc,
                                kv_chunk=kc, q_offset=q_offset)
    o = constrain(o, hspec)
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, Hp * hd), wo)


# ---------------------------------------------------------------------------
# GQA self-attention layer
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype()
    return {
        "wq": dense_init(ks[0], d, H * hd, dt),
        "wk": dense_init(ks[1], d, KV * hd, dt),
        "wv": dense_init(ks[2], d, KV * hd, dt),
        "wo": dense_init(ks[3], H * hd, d, dt),
    }


def gqa_project_qkv(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                    positions: Optional[jnp.ndarray], *,
                    rope: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) → q (B,S,H,hd), k,v (B,S,KV,hd) with RoPE applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    if rope and positions is not None:
        rd = int(hd * cfg.rotary_fraction)
        rd -= rd % 2
        cos, sin = rope_table(positions, rd, cfg.rope_theta)
        q = apply_rope(q, cos, sin, rotary_dims=rd)
        k = apply_rope(k, cos, sin, rotary_dims=rd)
    return q, k, v


def gqa_project_kv(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                   positions: Optional[jnp.ndarray]
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """KV-only projection (cache payloads during prefill)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(
        B, S, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(
        B, S, cfg.num_kv_heads, hd)
    if positions is not None:
        rd = int(hd * cfg.rotary_fraction)
        rd -= rd % 2
        cos, sin = rope_table(positions, rd, cfg.rope_theta)
        k = apply_rope(k, cos, sin, rotary_dims=rd)
    return k, v


def mla_cache_payload(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                      positions: jnp.ndarray) -> jnp.ndarray:
    """(B,S,r+rd) latent cache payload — cheap, no head expansion."""
    rd = cfg.qk_rope_head_dim
    c_kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_down"])
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["wk_rope"])
    cos, sin = rope_table(positions, rd, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return jnp.concatenate([c_kv, k_rope], axis=-1)


def gqa_self_attention(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                       positions: jnp.ndarray, *, causal: bool = True,
                       q_offset: int = 0,
                       target_chunk: int = 2048) -> jnp.ndarray:
    """Full-sequence self attention (train / encoder)."""
    from ..dist.sharding import current_ctx
    if current_ctx() is not None:
        return sharded_mha(params, cfg, x, positions, causal=causal,
                           q_offset=q_offset, target_chunk=target_chunk)
    B, S, D = x.shape
    q, k, v = gqa_project_qkv(params, cfg, x, positions)
    qc, kc = attn_chunk_sizes(S, S, target_chunk=target_chunk)
    if S <= 256:
        o = plain_attention(q, k, v, causal=causal, q_offset=q_offset)
    else:
        o = blockwise_attention(q, k, v, causal=causal, q_chunk=qc,
                                kv_chunk=kc, q_offset=q_offset)
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), params["wo"])


def cross_attention(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                    kv_states: jnp.ndarray,
                    *, target_chunk: int = 2048) -> jnp.ndarray:
    """Cross-attention: queries from x, keys/values from kv_states (no RoPE,
    no causal mask).  kv_states: (B, Skv, D)."""
    from ..dist.sharding import current_ctx
    if current_ctx() is not None:
        return sharded_mha(params, cfg, x, None, causal=False,
                           kv_source=kv_states, target_chunk=target_chunk)
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    Skv = kv_states.shape[1]
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = jnp.einsum("bsd,de->bse", kv_states, params["wk"]).reshape(
        B, Skv, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", kv_states, params["wv"]).reshape(
        B, Skv, cfg.num_kv_heads, hd)
    if S <= 256 and Skv <= 1024:
        o = plain_attention(q, k, v, causal=False)
    else:
        qc, kc = attn_chunk_sizes(S, Skv, target_chunk=target_chunk)
        o = blockwise_attention(q, k, v, causal=False, q_chunk=qc, kv_chunk=kc)
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), params["wo"])


def gqa_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray,
               k_cache: jnp.ndarray, v_cache: jnp.ndarray,
               positions: jnp.ndarray, lengths: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step.  x: (B,1,D); caches (B,S,KV,hd); positions (B,);
    returns (y (B,1,D), k_new (B,1,KV,hd), v_new) — the caller scatters the
    new kv into the cache (cache update strategies differ per layout)."""
    B = x.shape[0]
    q, k, v = gqa_project_qkv(params, cfg, x, positions[:, None])
    o = decode_attention(q[:, 0], k_cache, v_cache, lengths)
    y = jnp.einsum("be,ed->bd", o.reshape(B, -1), params["wo"])[:, None, :]
    return y, k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    H = cfg.num_heads
    r = cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    dt = cfg.pdtype()
    return {
        "wq": dense_init(ks[0], d, H * (nd + rd), dt),        # queries
        "wkv_down": dense_init(ks[1], d, r, dt),              # latent c_kv
        "wk_rope": dense_init(ks[2], d, rd, dt),              # shared rope key
        "wkv_up": dense_init(ks[3], r, H * (nd + vd), dt),    # k_nope ++ v
        "wo": dense_init(ks[4], H * vd, d, dt),
    }


def mla_project(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray):
    """Returns q (B,S,H,nd+rd), k (B,S,H,nd+rd), v (B,S,H,vd), and the cache
    payload (c_kv ++ k_rope) of size r+rd per token."""
    B, S, _ = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cos, sin = rope_table(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_down"])       # (B,S,r)
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["wk_rope"])      # (B,S,rd)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)          # (B,S,1,rd)

    kv = jnp.einsum("bsr,re->bse", c_kv, params["wkv_up"]).reshape(
        B, S, H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    cache_payload = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)
    return qq, k, v, cache_payload


def mla_self_attention(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                       positions: jnp.ndarray, *, causal: bool = True,
                       q_offset: int = 0, target_chunk: int = 2048):
    from ..dist.sharding import constrain, current_ctx, dp
    from jax.sharding import PartitionSpec as P
    B, S, D = x.shape
    q, k, v, _ = mla_project(params, cfg, x, positions)
    if current_ctx() is not None:   # MLA is MHA: heads shard directly
        hspec = P(dp(), None, "model", None)
        q, k, v = constrain(q, hspec), constrain(k, hspec), constrain(v, hspec)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    # MLA is MHA at compute time (KV=H)
    if S <= 256:
        o = plain_attention(q, k, v, causal=causal, scale=scale,
                            q_offset=q_offset)
    else:
        qc, kc = attn_chunk_sizes(S, S, target_chunk=target_chunk)
        o = blockwise_attention(q, k, v, causal=causal, scale=scale,
                                q_chunk=qc, kv_chunk=kc, q_offset=q_offset)
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), params["wo"])


def mla_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray,
               latent_cache: jnp.ndarray, positions: jnp.ndarray,
               lengths: jnp.ndarray):
    """Absorbed MLA decode: score directly against the latent cache.

    latent_cache: (B, S, r+rd) = c_kv ++ k_rope.  The current token's payload
    is scattered into the cache *before* attention (it attends to itself).
    Returns (y (B,1,D), updated latent cache).
    """
    B = x.shape[0]
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    S = latent_cache.shape[1]
    scale = 1.0 / math.sqrt(nd + rd)

    q = jnp.einsum("bd,de->be", x[:, 0], params["wq"]).reshape(B, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cos, sin = rope_table(positions[:, None], rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope[:, None], cos, sin)[:, 0]           # (B,H,rd)

    # current token's cache payload, written before scoring
    c_new = jnp.einsum("bd,dr->br", x[:, 0], params["wkv_down"])
    k_rope_new = jnp.einsum("bd,dr->br", x[:, 0], params["wk_rope"])
    k_rope_new = apply_rope(k_rope_new[:, None, None, :], cos, sin)[:, 0, 0]
    payload = jnp.concatenate([c_new, k_rope_new], axis=-1)
    at = (jnp.arange(S)[None, :] == lengths[:, None])[:, :, None]
    latent_cache = jnp.where(at, payload[:, None], latent_cache)

    # absorb W_uk into the query: q_abs (B,H,r)
    w_uk = params["wkv_up"].reshape(r, H, nd + vd)[..., :nd]       # (r,H,nd)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)

    c_cache = latent_cache[..., :r]                                # (B,S,r)
    rope_cache = latent_cache[..., r:]                             # (B,S,rd)
    logits = (jnp.einsum("bhr,bsr->bhs", q_abs, c_cache,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bsr->bhs", q_rope, rope_cache,
                           preferred_element_type=jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] < (lengths + 1)[:, None]
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o_latent = jnp.einsum("bhs,bsr->bhr", p.astype(c_cache.dtype), c_cache,
                          preferred_element_type=jnp.float32)      # (B,H,r)
    w_uv = params["wkv_up"].reshape(r, H, nd + vd)[..., nd:]       # (r,H,vd)
    o = jnp.einsum("bhr,rhv->bhv", o_latent.astype(x.dtype), w_uv)
    y = jnp.einsum("be,ed->bd", o.reshape(B, H * vd), params["wo"])[:, None]
    return y, latent_cache


__all__ = [
    "attn_chunk_sizes", "blockwise_attention", "plain_attention",
    "decode_attention", "gqa_init", "gqa_project_qkv", "gqa_self_attention",
    "cross_attention", "gqa_decode", "mla_init", "mla_project",
    "mla_self_attention", "mla_decode",
]
