"""Shared layers: norms, RoPE variants, FFNs, embeddings, chunked loss.

All layers are pure functions over explicit parameter pytrees (nested dicts of
arrays) — no framework dependency, full control over sharding annotations.
Initializers return parameters in ``cfg.param_dtype``; computation runs in
``cfg.compute_dtype`` (mixed precision).

The cross-entropy loss is computed in sequence chunks planned by the core
scheduler (``SeqWork`` + ``bound_depth``): with 202k–256k vocabularies the
full logits tensor is the single largest activation in the model, and chunking
it is a genuine deployment requirement, not a toy — the chunk plan is a Kvik
plan.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import SeqWork, bound_depth, build_plan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE — full, half (ChatGLM's "RoPE 2d" applies rotary to half the dims),
# and positions-only tables for decode.
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for a rotary table over ``head_dim`` dims."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_table(positions: jnp.ndarray, head_dim: int, theta: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables of shape positions.shape + (head_dim//2,)."""
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               *, rotary_dims: Optional[int] = None) -> jnp.ndarray:
    """Rotate the first ``rotary_dims`` dims of the head dimension.

    x: (..., seq, heads, head_dim); cos/sin: (..., seq, rotary_dims//2).
    ``rotary_dims=None`` rotates everything (llama style); ChatGLM3 rotates
    only the first half of each head ("2d" RoPE: the other half carries
    positional information from the prefix scheme — kept unrotated here).
    """
    hd = x.shape[-1]
    rd = rotary_dims or hd
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    c = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    if rd < hd:
        out = jnp.concatenate([out, xp], axis=-1)
    return out


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": dense_init(k1, d, d_ff, dtype),
            "up": dense_init(k2, d, d_ff, dtype),
            "down": dense_init(k3, d_ff, d, dtype)}


def swiglu(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, params["gate"])
    u = jnp.einsum("...d,df->...f", x, params["up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["down"])


def gelu_mlp_init(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"up": dense_init(k1, d, d_ff, dtype),
            "up_b": jnp.zeros((d_ff,), dtype),
            "down": dense_init(k2, d_ff, d, dtype),
            "down_b": jnp.zeros((d,), dtype)}


def gelu_mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, params["up"]) + params["up_b"]
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, params["down"]) + params["down_b"]


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype) -> Params:
    return {"table": embed_init(key, vocab, d, dtype)}


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,vd->...v", x, params["table"])


def chunked_softmax_xent(head_params: Params, hidden: jnp.ndarray,
                         labels: jnp.ndarray, *, chunk: int = 1024,
                         mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Cross-entropy over a huge vocabulary without materializing full logits.

    The sequence axis is split by a Kvik plan (SeqWork + bound_depth sized so
    leaves ≈ ``chunk``) and scanned; each leaf computes logits for its chunk
    only.  Peak activation drops from seq×vocab to chunk×vocab.
    Returns the summed loss and the token count (for exterior normalization).
    """
    b, s, d = hidden.shape
    table = head_params["table"]  # (vocab, d)

    depth = max(0, math.ceil(math.log2(max(1, s / chunk))))
    plan = build_plan(bound_depth(SeqWork(0, s, align=1), depth))
    sizes = plan.leaf_sizes()
    # equal leaves → scan; else unrolled (plans over pow2 seq are balanced)
    if len(set(sizes)) == 1 and len(sizes) > 1:
        c = sizes[0]
        hid = hidden.reshape(b, len(sizes), c, d).transpose(1, 0, 2, 3)
        lab = labels.reshape(b, len(sizes), c).transpose(1, 0, 2)
        msk = (mask.reshape(b, len(sizes), c).transpose(1, 0, 2)
               if mask is not None else jnp.ones_like(lab, jnp.float32))

        def body(carry, xs):
            h, l, m = xs
            logits = jnp.einsum("bcd,vd->bcv", h, table).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
            loss = ((lse - gold) * m).sum()
            return carry + loss, None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                (hid, lab, msk))
    else:
        total = jnp.zeros((), jnp.float32)
        for w in plan.leaves():
            h = hidden[:, w.start:w.stop]
            l = labels[:, w.start:w.stop]
            m = (mask[:, w.start:w.stop] if mask is not None
                 else jnp.ones(l.shape, jnp.float32))
            logits = jnp.einsum("bcd,vd->bcv", h, table).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
            total = total + ((lse - gold) * m).sum()
    denom = (mask.sum() if mask is not None
             else jnp.asarray(b * s, jnp.float32))
    return total / jnp.maximum(denom, 1.0)


__all__ = [
    "Params", "dense_init", "embed_init", "rmsnorm_init", "rmsnorm",
    "layernorm_init", "layernorm", "rope_freqs", "rope_table", "apply_rope",
    "swiglu_init", "swiglu", "gelu_mlp_init", "gelu_mlp",
    "embedding_init", "embed", "unembed", "chunked_softmax_xent",
]
