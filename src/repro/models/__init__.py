"""Model substrate: layers, attention (GQA/MLA/cross), MoE, SSM, assembly."""

from .model import Model, build_model
from .transformer import LayerSpec, layer_specs, stage_layout

__all__ = ["Model", "build_model", "LayerSpec", "layer_specs", "stage_layout"]
