"""Mixture-of-Experts: routing, dispatch, expert FFNs, shared experts.

Two dispatch strategies, both first-class:

* ``einsum`` (baseline / paper-faithful phase): GShard-style grouped one-hot
  dispatch.  Tokens are viewed in groups; a (G, S, E, C) dispatch tensor is
  contracted against activations.  Under GSPMD (tokens sharded over ``data``,
  experts over ``model``) the contraction lowers to all-to-alls.  Its FLOP
  overhead is *measured* in §Roofline and becomes a hillclimb target.

* ``sort`` (the Kvik showcase): tokens are stably sorted by expert id — the
  paper's parallel stable merge sort, §3.7 — then gathered into capacity bins.
  Stability preserves intra-expert token order, which keeps the combine a
  cheap gather.  On TPU the sort is the Pallas ``merge_sort`` kernel; the
  jnp path uses ``jnp.argsort(..., stable=True)``.  Used inside ``shard_map``
  expert-parallel dispatch (``repro.dist.moe_shard_map``) and in examples.

Router: softmax → top-k → renormalize (DeepSeek convention); auxiliary
load-balance loss returned for the trainer.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import Params, dense_init, swiglu, swiglu_init


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    dt = cfg.pdtype()
    params: Params = {
        "router": dense_init(ks[0], d, e, dt),
        "gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                 / math.sqrt(d)).astype(dt),
        "up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
               / math.sqrt(d)).astype(dt),
        "down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                 / math.sqrt(f)).astype(dt),
    }
    if cfg.num_shared_experts > 0:
        params["shared"] = swiglu_init(
            ks[4], d, f * cfg.num_shared_experts, dt)
    return params


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def route_topk(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (..., D) → (probs (..., k), experts (..., k) int32, aux_loss scalar).

    Softmax over experts, top-k, renormalized.  The aux loss is the standard
    Switch/GShard load-balance term: E · Σ_e f_e · p_e.
    """
    logits = jnp.einsum("...d,de->...e", x, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    e = router_w.shape[-1]
    # fraction of tokens routed to each expert (first choice) & mean prob
    first = jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32)
    f_e = first.reshape(-1, e).mean(0)
    p_e = probs.reshape(-1, e).mean(0)
    aux = e * jnp.sum(f_e * p_e)
    return top_p.astype(x.dtype), top_e.astype(jnp.int32), aux


def capacity_per_group(group_size: int, num_experts: int, top_k: int,
                       capacity_factor: float) -> int:
    c = math.ceil(group_size * top_k * capacity_factor / num_experts)
    return max(4, ((c + 3) // 4) * 4)


# ---------------------------------------------------------------------------
# einsum (GShard) dispatch
# ---------------------------------------------------------------------------

def moe_einsum(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
               group_size: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (out (B,S,D), aux_loss).

    Tokens are regrouped to (G, group_size, D); G stays divisible by the data
    axis because B is.  Capacity overflows drop (standard GShard semantics —
    the residual connection carries dropped tokens).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    g = min(group_size, S)
    G = T // g
    xg = x.reshape(G, g, D)

    probs, experts, aux = route_topk(params["router"], xg, K)  # (G,g,K)
    C = capacity_per_group(g, E, K, cfg.capacity_factor)

    dispatch = jnp.zeros((G, g, E, C), x.dtype)
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for j in range(K):
        onehot = jax.nn.one_hot(experts[..., j], E, dtype=jnp.int32)  # (G,g,E)
        pos = jnp.cumsum(onehot, axis=1) - 1 + counts[:, None, :]
        counts = counts + onehot.sum(axis=1)
        keep = (pos < C) & (onehot > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, 0), C, dtype=x.dtype)
        sel = (keep.astype(x.dtype))[..., None] * pos_oh           # (G,g,E,C)
        sel = sel * onehot.astype(x.dtype)[..., None]
        dispatch = dispatch + sel
        combine = combine + sel.astype(jnp.float32) * \
            probs[..., j].astype(jnp.float32)[..., None, None]

    from ..dist.sharding import constrain, dp
    from jax.sharding import PartitionSpec as P
    # Two expert-parallel regimes (EXPERIMENTS.md §Perf, hillclimb A):
    # * moe_2d_shard (Jamba-398B): stationary weights, 2-D sharded
    #   (experts × model, hidden × data); token groups replicate over 'data'
    #   and a psum folds the f-sharded partials.  No weight all-gathers, so
    #   XLA cannot hoist 796 GB of experts out of the layer scan (the
    #   failure mode that produced 84 GiB/device temps).
    # * EP-only (small expert banks): tokens stay 'data'-sharded, experts
    #   over 'model' — the classic all-to-all MoE; no per-layer psum.
    g_ax = None if cfg.moe_2d_shard else dp()
    f_ax = dp() if cfg.moe_2d_shard else None   # pod×data when multi-pod
    xe = jnp.einsum("gsd,gsec->egcd", xg, dispatch)                # (E,G,C,D)
    xe = constrain(xe, P("model", g_ax, None, None))
    h = jnp.einsum("egcd,edf->egcf", xe, params["gate"])
    u = jnp.einsum("egcd,edf->egcf", xe, params["up"])
    h = jax.nn.silu(h) * u
    h = constrain(h, P("model", g_ax, None, f_ax))
    ye = jnp.einsum("egcf,efd->egcd", h, params["down"])
    ye = constrain(ye, P("model", g_ax, None, None))
    out = jnp.einsum("egcd,gsec->gsd", ye, combine.astype(x.dtype))
    out = out.reshape(B, S, D)

    if cfg.num_shared_experts > 0:
        out = out + swiglu(params["shared"], x)
    return out, aux


# ---------------------------------------------------------------------------
# sort-based dispatch (the paper's stable sort at work)
# ---------------------------------------------------------------------------

def sort_route(params: Params, cfg: ModelConfig, x: jnp.ndarray,
               sort_fn=None):
    """Shared sort-dispatch prelude: route, flatten to (T·K,) assignments,
    stably sort by expert id (§3.7 — stability keeps the combine a gather).

    Returns ``(xd, sorted_e, sorted_tok, sorted_p, aux)`` with ``xd`` the
    permuted activations (T·K, D).  ``sort_fn(keys) -> order`` must be a
    *stable* argsort — default ``jnp.argsort(stable=True)``; the string
    ``"pallas"`` routes through the one-launch fused dispatch kernel
    (``kernels.radix_sort.moe_dispatch_sort``): the stable sort by expert
    id AND the ``xf[sorted_tok]`` activation gather happen inside a single
    ``pallas_call`` — activation rows ride through the radix scatter as
    payload, so routing costs one kernel launch at any T (``jit=True``
    caches the compiled kernel per (T·K, E, D) shape).  Expert counts
    beyond the kernel's 256-expert digit width fall back to the multi-tile
    radix ``argsort`` + gather.  Used by ``moe_sort_dispatch`` and
    ``repro.dist.expert.moe_shard_map``.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, D)
    probs, experts, aux = route_topk(params["router"], xf, K)     # (T,K)
    if sort_fn == "pallas":
        if E <= 256:
            from ..kernels.radix_sort import moe_dispatch_sort
            xd, sorted_e, sorted_tok, sorted_p = moe_dispatch_sort(
                xf, experts, probs, num_experts=E, interpret=True, jit=True)
            return xd, sorted_e, sorted_tok, sorted_p, aux
        from ..kernels.merge_sort import argsort as kernel_argsort
        bits = max(1, math.ceil(math.log2(max(2, E))))
        sort_fn = functools.partial(kernel_argsort, num_key_bits=bits,
                                    interpret=True, jit=True)

    flat_e = experts.reshape(T * K)
    flat_p = probs.reshape(T * K)
    token_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)

    order = (sort_fn(flat_e) if sort_fn is not None
             else jnp.argsort(flat_e, stable=True))
    sorted_e = flat_e[order].astype(jnp.int32)
    sorted_tok = token_of[order]
    sorted_p = flat_p[order]
    return xf[sorted_tok], sorted_e, sorted_tok, sorted_p, aux


def sort_combine(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                 y: jnp.ndarray, sorted_tok: jnp.ndarray,
                 sorted_p: jnp.ndarray) -> jnp.ndarray:
    """Shared epilogue: combine-weight scale, scatter-add back to token
    order, shared-expert residual."""
    B, S, D = x.shape
    y = y * sorted_p[:, None].astype(y.dtype)
    out = jnp.zeros((B * S, D), y.dtype).at[sorted_tok].add(y)
    out = out.reshape(B, S, D).astype(x.dtype)
    if cfg.num_shared_experts > 0:
        out = out + swiglu(params["shared"], x)
    return out


def moe_sort_dispatch(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                      sort_fn=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-shard sort-based MoE (exact, gather/scatter based).

    Capacity-free (dropless): every token is processed; expert batches are
    ragged, realized as one grouped einsum over a (T·K, D) permuted
    activation with segment boundaries.  See ``sort_route`` for the sort.
    """
    E = cfg.num_experts
    xd, sorted_e, sorted_tok, sorted_p, aux = sort_route(params, cfg, x,
                                                         sort_fn)
    # ragged expert GEMMs via one-hot masked einsum over experts — on TPU this
    # is a ragged/grouped matmul; here the jnp fallback keeps shapes static.
    seg = jax.nn.one_hot(sorted_e, E, dtype=x.dtype)              # (T·K, E)
    h = jnp.einsum("td,edf,te->tf", xd, params["gate"], seg)
    u = jnp.einsum("td,edf,te->tf", xd, params["up"], seg)
    y = jnp.einsum("tf,efd,te->td", jax.nn.silu(h) * u, params["down"], seg)
    return sort_combine(params, cfg, x, y, sorted_tok, sorted_p), aux


def moe_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
              strategy: str = "einsum", group_size: int = 256,
              sort_fn=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if strategy == "einsum":
        return moe_einsum(params, cfg, x, group_size=group_size)
    if strategy == "sort":
        return moe_sort_dispatch(params, cfg, x, sort_fn=sort_fn)
    raise ValueError(f"unknown MoE strategy {strategy!r}")


__all__ = ["moe_init", "route_topk", "capacity_per_group", "moe_einsum",
           "sort_route", "sort_combine", "moe_sort_dispatch", "moe_apply"]
