"""Paper §2.1/§3.6 quantitative claims — task-creation counts.

* thief_splitting, balanced work, p a power of two → O(p) tasks;
* adaptive → tasks = successful steals + 1 (measured identity);
* naive full division → Ω(n) tasks (the baseline both improve on).

All dynamic numbers come from the one unified :class:`repro.core.Runtime`
with the policy swapped — the same engine, so counts are comparable.
"""

from __future__ import annotations

from repro.core import (AdaptivePolicy, CostModel, JoinPolicy, Runtime,
                        WorkRange, build_plan, thief_splitting)

from .common import emit

N = 1 << 18


def run() -> None:
    naive_plan = build_plan(WorkRange(0, N, min_size=N // (1 << 14)))
    emit("task_counts/naive_full_division", 0.0,
         f"tasks={naive_plan.num_tasks()}")

    for p in (2, 4, 8, 16, 32):
        cost = CostModel(per_item=1.0)
        thief = Runtime(p, cost, JoinPolicy(), seed=0).run(
            thief_splitting(WorkRange(0, N), p=p))
        adapt = Runtime(p, cost, AdaptivePolicy(), seed=0).run(WorkRange(0, N))
        emit(f"task_counts/p{p}/thief", thief.makespan,
             f"tasks={thief.tasks_created} tasks_per_p="
             f"{thief.tasks_created/p:.1f}")
        emit(f"task_counts/p{p}/adaptive", adapt.makespan,
             f"tasks={adapt.tasks_created} "
             f"steals+1={adapt.steals_successful + 1} identity="
             f"{adapt.tasks_created == adapt.steals_successful + 1}")
