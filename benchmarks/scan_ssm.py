"""Chunked Pallas SSM scan vs launch-per-step — the BENCH_scan_ssm.json rows.

Three claims from the ssm_scan design (src/repro/kernels/ssm_scan.py):

1. The single-launch chunked scan beats a multi-launch peer — the same
   kernel issued once per chunk with the carry threaded through the host,
   i.e. what the scan costs *without* the VMEM carry (pinned as a
   recomputed boolean, like the sort launch rows — absolute times vary
   per host).  The XLA ``lax.scan`` number rides along unpinned: interpret
   mode measures launch structure on host, not device speed
   (the moe_dispatch/sort_compare convention).
2. The launch count is 1 regardless of sequence length: 512 and 4096-step
   scans both record exactly one ``ssm_scan`` launch (``pinned_ints``, the
   analogue of the radix sort's launches-independent-of-n rows).
3. The Pallas result equals the ``lax.associative_scan`` oracle (seeded
   with ``carry0``) — the equivalence guarantee, pinned at a non-power-of-2
   length so the identity-padding path is exercised too.

Plus the serving half: an xlstm (recurrent-only) smoke model served through
ContinuousEngine uses O(1) state slots per request, and the entropy-gated
decode tick retires confident lanes early — pinned invariants are that the
gated stream is an exact prefix of the ungated stream, the gated run costs
fewer decode steps, and the gate actually fired.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .common import emit, time_fn

SEED = 0
EOS = 2


# ---------------------------------------------------------------------------
# raw scan: chunked pallas vs per-step lax.scan
# ---------------------------------------------------------------------------

def _mamba_inputs(key, B, L, Di, N):
    import jax
    import jax.numpy as jnp
    k1, k2, k3 = jax.random.split(key, 3)
    # realistic selective-scan magnitudes: dA = exp(-softplus(..)) ∈ (0, 1)
    dA = jnp.exp(-jax.nn.softplus(jax.random.normal(k1, (B, L, Di, N))))
    dBx = 0.1 * jax.random.normal(k2, (B, L, Di, N))
    h0 = jax.random.normal(k3, (B, Di, N))
    return dA, dBx, h0


def _scan_rows() -> None:
    import jax
    from repro.kernels.launch_trace import trace_launches
    from repro.kernels.ssm_scan import (AFFINE_UNITS, affine_combine,
                                        mamba_assoc_scan,
                                        mamba_assoc_scan_ref,
                                        mamba_seq_scan_ref)
    from repro.kernels.tile_scan import batched_scan

    import jax.numpy as jnp

    B, L, Di, N = 2, 512, 16, 16
    block = 64
    dA, dBx, h0 = _mamba_inputs(jax.random.PRNGKey(SEED), B, L, Di, N)

    # multi-launch peer: the SAME kernel, one pallas_call per chunk, carry
    # threaded through the host — the launch pattern the VMEM carry removes.
    # Same interpret-mode tax on both sides, so the ratio is launch
    # structure, not emulation noise.
    @jax.jit
    def chunk_call(dAc, dBxc, h):
        _, states = batched_scan(
            (dAc, dBxc), combine=affine_combine, units=AFFINE_UNITS,
            carry0=(jnp.ones_like(h), h), block=block, kind="ssm_scan")
        return states

    def run_multi():
        h = h0
        for c in range(L // block):
            s = chunk_call(dA[:, c * block:(c + 1) * block],
                           dBx[:, c * block:(c + 1) * block], h)
            s.block_until_ready()       # host round trip between launches
            h = s[:, -1]

    seq = jax.jit(mamba_seq_scan_ref)

    def run_single():
        mamba_assoc_scan(dA, dBx, h0, block=block).block_until_ready()

    def run_seq():
        seq(dA, dBx, h0).block_until_ready()

    t_single = time_fn(run_single)
    t_multi = time_fn(run_multi)
    t_seq = time_fn(run_seq)
    speedup = t_multi / max(t_single, 1e-9)
    emit("scan/mamba/single_vs_multi_launch", t_single,
         f"multi_launch={t_multi:.0f}us speedup={speedup:.2f}x "
         f"lax_scan={t_seq:.0f}us (B={B} L={L} feat={Di * N} "
         f"chunks={L // block}; lax row unpinned — interpret-mode tax)",
         pinned_ints=["single_launch_beats_multi"],
         single_launch_beats_multi=int(t_single < t_multi),
         speedup_x100=int(speedup * 100),
         multi_us=t_multi, single_us=t_single, lax_scan_us=t_seq)

    # -- launch count independent of sequence length -----------------------
    def launches(L):
        dA, dBx, h0 = _mamba_inputs(jax.random.PRNGKey(1), 1, L, 4, 4)
        with trace_launches() as tr:
            import jax.numpy as jnp
            batched_scan((dA, dBx), combine=affine_combine,
                         units=AFFINE_UNITS,
                         carry0=(jnp.ones_like(h0), h0),
                         kind="ssm_scan")
        return sum(1 for r in tr if r.kind == "ssm_scan")

    n512, n4096 = launches(512), launches(4096)
    emit("scan/mamba/launch_invariance", 0.0,
         f"launches: L=512→{n512} L=4096→{n4096} (1 each; a log-depth "
         f"tree would need 9 and 12)",
         pinned_ints=["launches_s512", "launches_s4096"],
         launches_s512=n512, launches_s4096=n4096)

    # -- equivalence at a non-power-of-2 length (padding path) -------------
    dA2, dBx2, h02 = _mamba_inputs(jax.random.PRNGKey(2), 2, 300, 8, 8)
    got = mamba_assoc_scan(dA2, dBx2, h02)
    want = mamba_assoc_scan_ref(dA2, dBx2, h02)
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    emit("scan/mamba/equivalence", 0.0,
         f"max|pallas - assoc_scan| = {err:.2e} at L=300 (non-pow2)",
         pinned_ints=["equiv_ok"], equiv_ok=int(err < 1e-4), max_err=err)


# ---------------------------------------------------------------------------
# model level: mlstm forward, pallas vs lax chunk loop
# ---------------------------------------------------------------------------

def _xlstm(scan_impl):
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.models.model import Model
    cfg = get_smoke_config("xlstm-1.3b")
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    model = Model(cfg, scan_impl=scan_impl)
    params = model.init(jax.random.PRNGKey(SEED))
    return model, params


def _mlstm_rows() -> None:
    import jax
    import jax.numpy as jnp

    lax_m, params = _xlstm("lax")
    pal_m, _ = _xlstm("pallas")
    B, S = 2, 64   # S > mlstm_chunk → the chunked carry-scan path
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 3,
                                lax_m.cfg.vocab_size).astype(jnp.int32)
    batch = {"tokens": tokens}

    lax_fn = jax.jit(lambda p, tk: lax_m.prefill(p, {"tokens": tk})[0])
    pal_fn = jax.jit(lambda p, tk: pal_m.prefill(p, {"tokens": tk})[0])
    lg_lax = lax_fn(params, tokens)
    lg_pal = pal_fn(params, tokens)
    err = float(np.max(np.abs(np.asarray(lg_lax) - np.asarray(lg_pal))))

    t_lax = time_fn(lambda: lax_fn(params, tokens).block_until_ready())
    t_pal = time_fn(lambda: pal_fn(params, tokens).block_until_ready())
    emit("scan/mlstm/forward_pallas_vs_lax", t_pal,
         f"lax={t_lax:.0f}us max|Δlogits|={err:.2e} (xlstm smoke, "
         f"S={S}, chunk={lax_m.cfg.mlstm_chunk})",
         pinned_ints=["mlstm_equiv_ok"],
         mlstm_equiv_ok=int(err < 1e-3), max_err=err,
         lax_us=t_lax, pallas_us=t_pal)


# ---------------------------------------------------------------------------
# serving: SSM state slots + entropy-gated early exit
# ---------------------------------------------------------------------------

def _serve(model, params, prompts, exit_entropy):
    import time as _time
    from repro.serve.engine import ContinuousEngine, EngineConfig, Request
    eng = ContinuousEngine(model, params, EngineConfig(
        max_batch=3, max_seq=128, eos_id=EOS, decode_tick=4, page_size=16,
        exit_entropy=exit_entropy))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=24))
    t0 = _time.perf_counter()
    done = []
    while eng.pending:
        done += eng.step()
    return {r.rid: np.asarray(r.result) for r in done}, eng, \
        _time.perf_counter() - t0


def _serve_rows() -> None:
    from repro.serve.engine import Request

    model, params = _xlstm("pallas")
    rng = np.random.RandomState(SEED)
    prompts = [rng.randint(3, model.cfg.vocab_size,
                           size=rng.randint(5, 40)).astype(np.int32)
               for _ in range(6)]

    span = None
    if model.recurrent_only:
        from repro.serve.engine import ContinuousEngine, EngineConfig
        eng = ContinuousEngine(model, params, EngineConfig(
            max_batch=3, max_seq=128, eos_id=EOS, page_size=16))
        span = eng._slot_span(Request(rid=0, prompt=prompts[0], max_new=24))
    emit("serve/ssm/state_slots", 0.0,
         f"recurrent_only={model.recurrent_only} slot_span={span} pages "
         f"(== page_size: O(1) state per request, not O(seq))",
         pinned_ints=["state_slot_o1"],
         state_slot_o1=int(model.recurrent_only
                           and span == 16))

    base, eng0, t0 = _serve(model, params, prompts, None)
    # tau near log(vocab): the gate fires once a lane's entropy settles —
    # on the smoke model that is nearly immediately, which is the point:
    # the invariants (prefix exactness, fewer steps) are what gets pinned.
    gated, eng1, t1 = _serve(model, params, prompts, 8.0)

    prefix = all(np.array_equal(gated[k], base[k][:len(gated[k])])
                 for k in base)
    steps0 = eng0.telemetry.decode_steps
    steps1 = eng1.telemetry.decode_steps
    toks = sum(len(v) for v in base.values())
    emit("serve/ssm/early_exit_goodput", t1 * 1e6 / max(len(prompts), 1),
         f"gated {steps1} vs ungated {steps0} decode steps, "
         f"early_exits={eng1.telemetry.early_exits}, prefix_exact="
         f"{int(prefix)} ({toks} base tokens)",
         pinned_ints=["gated_prefix_exact", "gated_fewer_steps",
                      "early_exits_nonzero"],
         gated_prefix_exact=int(prefix),
         gated_fewer_steps=int(steps1 < steps0),
         early_exits_nonzero=int(eng1.telemetry.early_exits > 0),
         gated_steps=steps1, ungated_steps=steps0,
         gated_s=t1, ungated_s=t0)


def run() -> None:
    _scan_rows()
    _mlstm_rows()
    _serve_rows()


if __name__ == "__main__":
    from .common import header
    header()
    run()
