"""Paper Fig. 5 — ``all`` with cancellation: the all-finite data audit.

A production duty: verify a tensor stream has no NaN/Inf before committing a
checkpoint.  The naive reduction scans everything; by_blocks aborts at the
first offending block.  Variance-width (the paper's main observation for
``all``) is reported via min/max over target positions.
"""

from __future__ import annotations

import numpy as np

from repro.core import WorkRange, by_blocks

from .common import emit, time_fn

N = 100_000_000


def run() -> None:
    data = np.ones(N, np.float32)
    rng = np.random.RandomState(1)

    def naive(d):
        return bool(np.isfinite(d).all())

    bb = by_blocks(first=1 << 16)

    def blocked(d):
        bad = [False]

        def block_fn(blk, carry):
            ok = bool(np.isfinite(d[blk.start:blk.stop]).all())
            if not ok:
                bad[0] = True
            return carry or not ok

        _, stats = bb.run(WorkRange(0, N), block_fn, False,
                          should_stop=lambda c: c)
        return bad[0], stats

    # clean input: both do full work
    t_naive = time_fn(lambda: naive(data), iters=3)
    t_block = time_fn(lambda: blocked(data)[0], iters=3)
    emit("all/clean/naive", t_naive, "result=True")
    emit("all/clean/by_blocks", t_block,
         f"overhead={t_block/t_naive:.2f}x")

    # poisoned input at random positions: by_blocks aborts early
    times, works = [], []
    for _ in range(5):
        pos = int(rng.randint(0, N))
        data[pos] = np.nan
        bad, stats = blocked(data)
        assert bad
        times.append(time_fn(lambda: blocked(data)[0], warmup=0, iters=1))
        works.append(stats.items_run / N)
        data[pos] = 1.0
    emit("all/poisoned/by_blocks", float(np.mean(times)),
         f"mean_work={np.mean(works):.2%} min={min(works):.2%} "
         f"max={max(works):.2%}")
    emit("all/poisoned/naive", t_naive, "work=100%")
