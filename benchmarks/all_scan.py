"""Paper Fig. 5 — ``all`` with cancellation: the all-finite data audit.

A production duty: verify a tensor stream has no NaN/Inf before committing a
checkpoint.  The naive reduction scans everything; by_blocks aborts at the
first offending block.  Variance-width (the paper's main observation for
``all``) is reported via min/max over target positions.

Two views, same policy (the unified-runtime port):

* real wall clock — the ``by_blocks`` scheduler executing numpy block scans;
* virtual time — the same geometric-block policy as a ``ByBlocksPolicy`` on
  the unified discrete-event ``Runtime`` (``simulate``), which predicts the
  wasted-work distribution the real run then confirms.
"""

from __future__ import annotations

import numpy as np

from repro.core import (AdaptivePolicy, ByBlocksPolicy, CostModel, WorkRange,
                        by_blocks, simulate)

from .common import emit, time_fn

N = 100_000_000
SIM_N = 1_000_000          # virtual-time items (scale model, not wall clock)


def run() -> None:
    data = np.ones(N, np.float32)
    rng = np.random.RandomState(1)

    def naive(d):
        return bool(np.isfinite(d).all())

    bb = by_blocks(first=1 << 16)

    def blocked(d):
        bad = [False]

        def block_fn(blk, carry):
            ok = bool(np.isfinite(d[blk.start:blk.stop]).all())
            if not ok:
                bad[0] = True
            return carry or not ok

        _, stats = bb.run(WorkRange(0, N), block_fn, False,
                          should_stop=lambda c: c)
        return bad[0], stats

    # clean input: both do full work
    t_naive = time_fn(lambda: naive(data), iters=3)
    t_block = time_fn(lambda: blocked(data)[0], iters=3)
    emit("all/clean/naive", t_naive, "result=True", n=N)
    emit("all/clean/by_blocks", t_block,
         f"overhead={t_block/t_naive:.2f}x", n=N,
         overhead_vs_naive=t_block / t_naive)

    # poisoned input at random positions: by_blocks aborts early
    times, works = [], []
    for _ in range(5):
        pos = int(rng.randint(0, N))
        data[pos] = np.nan
        bad, stats = blocked(data)
        assert bad
        times.append(time_fn(lambda: blocked(data)[0], warmup=0, iters=1))
        works.append(stats.items_run / N)
        data[pos] = 1.0
    emit("all/poisoned/by_blocks", float(np.mean(times)),
         f"mean_work={np.mean(works):.2%} min={min(works):.2%} "
         f"max={max(works):.2%}",
         mean_work=float(np.mean(works)), min_work=float(min(works)),
         max_work=float(max(works)))
    emit("all/poisoned/naive", t_naive, "work=100%")

    # unified-runtime view: the same geometric by_blocks policy, virtual
    # time, p workers running each block's items under an inner adaptive
    # policy.  Predicted wasted-work fractions should bracket the measured
    # ones above (same growth=2 geometric series → ≤ 50% overscan).
    cost = CostModel(per_item=1.0, split_overhead=4.0)
    for p in (1, 8):
        fracs = []
        srng = np.random.RandomState(2)
        for _ in range(5):
            bad_at = int(srng.randint(0, SIM_N))
            res = simulate(
                WorkRange(0, SIM_N),
                ByBlocksPolicy(inner=AdaptivePolicy(), first=1 << 10), p,
                cost, seed=0,
                stop_predicate=lambda i, bad_at=bad_at:
                    i if i == bad_at else None)
            assert res.stopped_early
            fracs.append(res.items_processed / res.items_total)
        emit(f"all/sim_p{p}/by_blocks_policy", float(np.mean(fracs)) * 100,
             f"mean_scan={np.mean(fracs):.2%} max={max(fracs):.2%} "
             f"(unified Runtime, virtual time)",
             p=p, mean_scan=float(np.mean(fracs)),
             max_scan=float(max(fracs)))
