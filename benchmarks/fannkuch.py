"""Paper Fig. 8 — fannkuch-redux: expensive splits, adaptive wins.

The structure that matters: generating the *first* permutation of a stolen
range costs O(n²); advancing to the next costs O(1) amortized.  Static
over-decomposition pays the split cost num_blocks times; thief_splitting
pays it per steal-cascade; the adaptive schedule pays it exactly
(successful steals) times.  Virtual-time simulation over PermRange with the
real cost structure; speedup curves over worker counts.
"""

from __future__ import annotations

import numpy as np

from repro.core import (AdaptivePolicy, CostModel, JoinPolicy, PermRange,
                        Runtime, StaticPartitionPolicy, thief_splitting,
                        total_permutations)

from .common import emit

N_PERM = 9          # 9! = 362,880 permutations


def run() -> None:
    total = total_permutations(N_PERM)
    split_cost = float(N_PERM * N_PERM)
    cost = CostModel(per_item=1.0,
                     split_cost_fn=lambda w: split_cost,
                     steal_latency=2.0)

    for p in (4, 16, 64):
        work = lambda: PermRange(N_PERM, 0, total)
        static8 = Runtime(p, cost,
                          StaticPartitionPolicy(num_blocks=8 * p)).run(work())
        thief = Runtime(p, cost, JoinPolicy(), seed=0).run(
            thief_splitting(work(), p=p))
        adapt = Runtime(p, CostModel(per_item=1.0, steal_latency=2.0),
                        AdaptivePolicy(), seed=0).run(work())
        for name, res in (("static8", static8), ("thief", thief),
                          ("adaptive", adapt)):
            emit(f"fannkuch/p{p}/{name}", res.makespan,
                 f"speedup={res.speedup_vs_serial:.2f} "
                 f"tasks={res.tasks_created} "
                 f"steals={res.steals_successful}")

    # heterogeneous pod (a 2× straggler) — the load-imbalance case the
    # paper attributes the omp-static drops to
    p = 16
    speeds = [1.0] * (p - 1) + [0.5]
    static = Runtime(p, cost, StaticPartitionPolicy(num_blocks=8 * p),
                     speeds=speeds).run(PermRange(N_PERM, 0, total))
    adapt = Runtime(p, CostModel(per_item=1.0, steal_latency=2.0),
                    AdaptivePolicy(), seed=0, speeds=speeds).run(
        PermRange(N_PERM, 0, total))
    emit("fannkuch/straggler/static8", static.makespan,
         f"speedup={static.speedup_vs_serial:.2f}")
    emit("fannkuch/straggler/adaptive", adapt.makespan,
         f"speedup={adapt.speedup_vs_serial:.2f} "
         f"gain={static.makespan/adapt.makespan:.2f}x")
