"""Paper Fig. 3/4 — find_first with and without by_blocks.

Two layers of evidence, matching DESIGN.md's validation split:
* virtual-time simulation (exact scheduling semantics, p workers): speedups
  for {thief_splitting, adaptive} × {blocks, no-blocks}, uniform and
  worst-case (n/2 − 1) target positions;
* real wall-clock: by_blocks early-exit scan over a 100M-element array on
  this host (1 core — absolute speedups are 1, the measured quantity is the
  *work saved*, which is machine-independent).
"""

from __future__ import annotations

import numpy as np

from repro.core import (AdaptivePolicy, ByBlocksPolicy, CostModel, JoinPolicy,
                        Runtime, WorkRange, by_blocks, thief_splitting)

from .common import emit, time_fn

N = 1_000_000


def _sim_find_first(scheduler: str, blocks: bool, target: int, p: int = 16,
                    seed: int = 0):
    """One unified-runtime run per configuration.  With ``blocks`` the outer
    by_blocks loop and the inner scheduler are *composed policies* on the
    same engine — previously this required a hand-rolled loop over separate
    per-block simulator instances."""
    cost = CostModel(per_item=1.0, steal_latency=2.0, check_overhead=0.05)

    def hit_leaf(work):          # join predicate: sees leaf Divisibles
        if work.start <= target < work.stop:
            return target
        return None

    def hit_item(item):          # adaptive predicate: sees items
        return target if item == target else None

    wrap = None
    if scheduler == "adaptive":
        inner, pred = AdaptivePolicy(), hit_item
        work = WorkRange(0, N)
    else:
        inner, pred = JoinPolicy(), hit_leaf
        if blocks:
            work, wrap = WorkRange(0, N), lambda b: thief_splitting(b, p=p)
        else:
            work = thief_splitting(WorkRange(0, N), p=p)
    policy = (ByBlocksPolicy(inner=inner, first=p, wrap=wrap)
              if blocks else inner)
    res = Runtime(p, cost, policy, seed=seed, stop_predicate=pred).run(work)
    return res.makespan, res.items_processed


def run() -> None:
    rng = np.random.RandomState(0)
    p = 16
    for case, targets in (("uniform", rng.randint(0, N, 5)),
                          ("worst", [N // 2 - 1])):
        for sched in ("thief", "adaptive"):
            for blocks in (False, True):
                ts, items = [], []
                for t in targets:
                    mk, it = _sim_find_first(sched, blocks, int(t), p=p)
                    ts.append(mk)
                    items.append(it)
                serial = float(np.mean([t + 1 for t in targets]))
                speedup = serial / float(np.mean(ts))
                waste = float(np.mean(items)) / serial
                emit(f"find_first/{case}/{sched}"
                     f"{'+blocks' if blocks else ''}",
                     float(np.mean(ts)),
                     f"speedup={speedup:.2f}x waste_ratio={waste:.2f}")

    # real wall-clock early-exit scan (work saved is the metric)
    data = np.zeros(100_000_000, np.int8)
    target = len(data) // 2 - 1
    data[target] = 1

    def naive():
        return int(np.argmax(data))

    bb = by_blocks(first=1 << 16)

    def blocked():
        found = [-1]

        def block_fn(blk, carry):
            seg = data[blk.start:blk.stop]
            i = int(np.argmax(seg))
            if seg[i]:
                found[0] = blk.start + i
                return True
            return carry

        _, stats = bb.run(WorkRange(0, len(data)), block_fn, False,
                          should_stop=lambda c: c)
        return found[0], stats

    t_naive = time_fn(naive)
    t_block = time_fn(lambda: blocked()[0])
    _, stats = blocked()
    emit("find_first/wallclock/naive", t_naive, f"items={len(data)}")
    emit("find_first/wallclock/by_blocks", t_block,
         f"items={stats.items_run} "
         f"saved={1 - stats.items_run/len(data):.2%}")
