"""Multi-tenant SLO serving under 3× overload — the BENCH_slo.json rows.

One seeded two-tenant trace (interactive / batch / background classes,
arrivals compressed so the offered decode work is ~3× what the engine can
drain in the arrival window) is replayed against the same ContinuousEngine
twice: once under FIFO (the PR 8 behavior — every class waits behind every
other, so overload collapses all classes uniformly) and once under the
class-ranked PriorityServePolicy with deadline shedding.  A third replay
hot-swaps FIFO → priority mid-run on a live engine.

Wall-clock numbers cannot be pinned across machines (the trace is scaled by
the measured per-token decode cost, like serve_load), so the pinned rows
are recomputed booleans — the graceful-degradation invariants themselves:

* interactive p99 under priority ≥2× better than under FIFO (a shed
  request's latency is its time-to-drop: the user-visible wait);
* every request the priority run sheds is batch/background — interactive
  work never degrades first;
* conservation: each run accounts every submitted rid exactly once
  (served + shed, no losses, no duplicates);
* the hot-swap replay's tokens all match serving each request one at a
  time on the synchronous engine — exactness is preserved across a live
  ``set_policy()``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from .common import emit

EOS = 7
SEED = 0
MAX_BATCH = 4
MAX_SEQ = 224
OVERLOAD = 3.0                 # offered work / drain capacity
P99_BAR = 2.0


def _model():
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.models.model import Model
    cfg = get_smoke_config("llama3-8b")
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(SEED))
    return model, params


def _engine(model, params, policy=None, class_caps=None):
    from repro.serve.engine import ContinuousEngine, EngineConfig
    return ContinuousEngine(model, params, EngineConfig(
        max_batch=MAX_BATCH, eos_id=EOS, max_seq=MAX_SEQ,
        decode_tick=8, prefill_block_budget=4,
        class_caps=class_caps), policy=policy)


def _warmed(model, params, vocab, policy=None, class_caps=None):
    """A fresh engine with its jit compiles already paid.

    Each ContinuousEngine builds its own jitted decode tick, so a fresh
    instance stalls ~1s on its first step — long enough to swamp any
    deadline in the trace.  Drain one deadline-free request per prompt
    shape before the replay clock starts."""
    import dataclasses as _dc
    from repro.chaos.serving import make_request
    eng = _engine(model, params, policy, class_caps)
    seen = set()
    for it in _trace(1.0):
        if it.prompt_len in seen:
            continue
        seen.add(it.prompt_len)
        eng.submit(make_request(
            _dc.replace(it, arrival=0.0, deadline_s=None), vocab, SEED))
    while eng.pending:
        eng.step()
    return eng


def _classes(span_s: float) -> Dict[str, Dict]:
    """The two-tenant SLO mix.  Deadlines are fractions of the arrival
    span: under ~3× overload the drain takes ~OVERLOAD spans, so batch and
    background deadlines (well under one drain) must expire for late
    arrivals, while the interactive deadline (2 spans) only binds when
    interactive work is stuck behind other classes — i.e. under FIFO,
    where a request arriving at ``a`` waits ~(OVERLOAD-1)·a behind the
    backlog.  Interactive is ~20% of the offered work, so the priority
    run serves it far inside one span."""
    return {
        "interactive": dict(n=8, prompt_len=12, max_new=8, priority=2,
                            deadline_s=2.0 * span_s,
                            tenants=("tenant-a", "tenant-b")),
        "batch": dict(n=16, prompt_len=24, max_new=32,
                      deadline_s=0.5 * span_s,
                      tenants=("tenant-a", "tenant-b")),
        "background": dict(n=8, prompt_len=24, max_new=48,
                           deadline_s=0.35 * span_s,
                           tenants=("tenant-b",)),
    }


def _trace(span_s: float):
    from repro.chaos.serving import slo_mix_trace
    return slo_mix_trace(SEED, span_s=span_s, classes=_classes(span_s))


def _p99(latencies: List[float]) -> float:
    return float(np.percentile(np.asarray(latencies), 99))


def run() -> None:
    from repro.chaos.serving import make_request, replay
    from repro.serve.engine import Engine, EngineConfig
    from repro.serve.slo import FifoServePolicy, PriorityServePolicy

    model, params = _model()
    vocab = model.cfg.vocab_size

    # Calibrate the overload knob against this machine: drain the whole mix
    # as one burst (deadline-free, arrivals at 0) on a pre-warmed engine and
    # take the wall time as the engine's capacity for this work.  Offering
    # the same work inside ``drain/OVERLOAD`` is then a ~3× overload by
    # construction, however fast the host is.
    burst = tuple(dataclasses.replace(it, arrival=0.0, deadline_s=None)
                  for it in _trace(1.0))
    cap_eng = _warmed(model, params, vocab)
    t0 = time.perf_counter()
    replay(cap_eng, burst, vocab=vocab, seed=SEED)
    drain_s = time.perf_counter() - t0
    spt = max(cap_eng.telemetry.decode_s_per_token, 1e-9)

    classes = _classes(1.0)
    n_requests = sum(c["n"] for c in classes.values())
    span_s = drain_s / OVERLOAD
    trace = _trace(span_s)

    # -- FIFO baseline vs class-ranked priority on the SAME trace ----------
    fifo_res = replay(_warmed(model, params, vocab, FifoServePolicy()),
                      trace, vocab=vocab, seed=SEED)
    # class caps keep one lane free of batch/background work, so an
    # arriving interactive request never waits a full decode epoch for a
    # slot — the per-class Cap adaptors doing real SLO isolation.
    pri_eng = _warmed(model, params, vocab, PriorityServePolicy(),
                      class_caps={"batch": 2, "background": 1})
    pri_res = replay(pri_eng, trace, vocab=vocab, seed=SEED)

    fifo_p99 = _p99(fifo_res.latencies("interactive"))
    pri_p99 = _p99(pri_res.latencies("interactive"))
    ratio = fifo_p99 / max(pri_p99, 1e-9)
    emit("serve/slo/interactive_p99_vs_fifo", pri_p99 * 1e6,
         f"ratio={ratio:.2f}x pri_p99={pri_p99:.3f}s "
         f"fifo_p99={fifo_p99:.3f}s (>= {P99_BAR}x bar, {OVERLOAD:.0f}x "
         f"overload)",
         pinned_ints=["p99_ratio_ge_2x"],
         p99_ratio_ge_2x=int(ratio >= P99_BAR),
         ratio_x100=int(ratio * 100),
         pri_p99_s=pri_p99, fifo_p99_s=fifo_p99,
         span_s=span_s, overload=OVERLOAD, requests=n_requests)

    shed_classes = sorted({r.slo for r in pri_res.shed})
    purity = all(s in ("batch", "background") for s in shed_classes)
    emit("serve/slo/shed_purity", 0.0,
         f"shed={len(pri_res.shed)}/{n_requests} classes={shed_classes} "
         f"by_tenant={pri_eng.telemetry.shed_by_tenant}",
         pinned_ints=["shed_all_batch_background", "shed_nonzero"],
         shed_all_batch_background=int(purity),
         shed_nonzero=int(len(pri_res.shed) > 0),
         shed=len(pri_res.shed), fifo_shed=len(fifo_res.shed),
         shed_by_class={s: sum(1 for r in pri_res.shed if r.slo == s)
                        for s in shed_classes})

    conserved = (fifo_res.conserved(trace) and pri_res.conserved(trace)
                 and not fifo_res.rejected and not pri_res.rejected)
    emit("serve/slo/conservation", 0.0,
         f"fifo={len(fifo_res.served)}+{len(fifo_res.shed)} "
         f"pri={len(pri_res.served)}+{len(pri_res.shed)} of {n_requests}; "
         f"zero lost or duplicated={int(conserved)}",
         pinned_ints=["zero_lost_or_duplicated"],
         zero_lost_or_duplicated=int(conserved))

    # -- live hot-swap preserves exactness ---------------------------------
    swap_eng = _warmed(model, params, vocab, FifoServePolicy())
    swap_at = 4

    def swap(step: int, eng) -> None:
        if step == swap_at and eng.telemetry.policy_swaps == 0:
            eng.set_policy(PriorityServePolicy())

    swap_trace = tuple(dataclasses.replace(it, deadline_s=None)
                       for it in _trace(span_s * 0.5))
    swap_res = replay(swap_eng, swap_trace, vocab=vocab, seed=SEED,
                      on_step=swap)
    ref_eng = Engine(model, params, EngineConfig(
        max_batch=1, eos_id=EOS, max_seq=MAX_SEQ))
    refs: Dict[int, np.ndarray] = {}
    for it in swap_trace:
        ref_eng.submit(make_request(it, vocab, SEED))
        while ref_eng.queue or ref_eng._residual is not None:
            for r in ref_eng.step():
                refs[r.rid] = np.asarray(r.result)
    exact = (len(swap_res.served) == len(swap_trace)
             and all(np.array_equal(refs[r.rid], np.asarray(r.result))
                     for r in swap_res.served))
    emit("serve/slo/hotswap_exactness", 0.0,
         f"swapped at step {swap_at}, served={len(swap_res.served)}, "
         f"exact vs one-at-a-time={int(exact)}",
         pinned_ints=["exact_tokens_after_swap", "policy_swapped"],
         exact_tokens_after_swap=int(exact),
         policy_swapped=int(swap_eng.telemetry.policy_swaps >= 1))

    snap = pri_eng.telemetry.snapshot()
    emit("serve/slo/telemetry", spt * 1e6,
         f"class_preemptions={snap['class_preemptions']} "
         f"shed={snap['shed']} admissions={snap['admissions']} "
         f"deferred_pages={snap['deferred_pages']}",
         **{k: v for k, v in snap.items()})


if __name__ == "__main__":
    from .common import header
    header()
    run()
