"""Paper Fig. 7 — our composable sort vs library sorts.

Single-core host: we compare against np.sort / jnp.argsort as the
"state-of-the-art library" stand-ins the paper compared against (TBB pstl,
gnu parallel).  The honest claim on 1 core is overhead-parity, not speedup;
the 1.5× speedup claim from the paper is about *parallel scaling*, which the
virtual-time runtime reproduces (see fannkuch + task_counts benches).
Also measured: the Pallas merge-sort kernel path (interpret mode) at a
shape where interpretation cost is tolerable — correctness is the claim.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import SeqWork, bound_depth, build_plan
from repro.kernels.merge_sort import argsort as kernel_argsort

from .common import emit, time_fn
from .sort_adaptors import composed_sort

N = 1 << 20


def run() -> None:
    keys = np.random.RandomState(0).randint(0, 1 << 30, N).astype(np.int32)

    t_np = time_fn(lambda: np.sort(keys, kind="stable"), iters=3)
    emit("sort_compare/np.sort", t_np, f"n={N}")

    jk = jnp.asarray(keys)
    t_jnp = time_fn(lambda: jnp.sort(jk).block_until_ready(), iters=3)
    emit("sort_compare/jnp.sort", t_jnp, f"ratio_vs_np={t_jnp/t_np:.2f}")

    plan = build_plan(bound_depth(SeqWork(0, N, min_size=1 << 14), 6))
    t_ours = time_fn(lambda: composed_sort(keys, plan), iters=3)
    emit("sort_compare/kvik_composed", t_ours,
         f"ratio_vs_np={t_ours/t_np:.2f} tasks={plan.num_tasks()}")

    # Pallas kernel (interpret mode → correctness + structure, not speed)
    small = jnp.asarray(keys[: 1 << 14] & 0x7FF)
    t_kernel = time_fn(
        lambda: kernel_argsort(small, tile=1024,
                               interpret=True).block_until_ready(),
        warmup=1, iters=1)
    order = np.asarray(kernel_argsort(small, tile=1024, interpret=True))
    ok = bool((np.asarray(small)[order] == np.sort(np.asarray(small))).all())
    emit("sort_compare/pallas_merge_sort_interpret", t_kernel,
         f"n={1<<14} correct={ok}")
