"""Paper Fig. 7 — our composable sort vs library sorts.

Single-core host: we compare against np.sort / jnp.argsort as the
"state-of-the-art library" stand-ins the paper compared against (TBB pstl,
gnu parallel).  The honest claim on 1 core is overhead-parity, not speedup;
the 1.5× speedup claim from the paper is about *parallel scaling*, which the
virtual-time runtime reproduces (see fannkuch + task_counts benches).
Also measured: the Pallas merge-sort kernel path (interpret mode) at a
shape where interpretation cost is tolerable — correctness is the claim.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import (CostModel, DepJoinPolicy, JoinPolicy, Runtime,
                        SeqWork, bound_depth, build_plan, even_levels)
from repro.kernels.merge_sort import argsort as kernel_argsort

from .common import emit, time_fn
from .sort_adaptors import composed_sort

N = 1 << 20


def run() -> None:
    keys = np.random.RandomState(0).randint(0, 1 << 30, N).astype(np.int32)

    t_np = time_fn(lambda: np.sort(keys, kind="stable"), iters=3)
    emit("sort_compare/np.sort", t_np, f"n={N}")

    jk = jnp.asarray(keys)
    t_jnp = time_fn(lambda: jnp.sort(jk).block_until_ready(), iters=3)
    emit("sort_compare/jnp.sort", t_jnp, f"ratio_vs_np={t_jnp/t_np:.2f}")

    plan = build_plan(bound_depth(SeqWork(0, N, min_size=1 << 14), 6))
    t_ours = time_fn(lambda: composed_sort(keys, plan), iters=3)
    emit("sort_compare/kvik_composed", t_ours,
         f"ratio_vs_np={t_ours/t_np:.2f} tasks={plan.num_tasks()}")

    # Pallas kernel (interpret mode → correctness + structure, not speed)
    small = jnp.asarray(keys[: 1 << 14] & 0x7FF)
    t_kernel = time_fn(
        lambda: kernel_argsort(small, tile=1024,
                               interpret=True).block_until_ready(),
        warmup=1, iters=1)
    order = np.asarray(kernel_argsort(small, tile=1024, interpret=True))
    ok = bool((np.asarray(small)[order] == np.sort(np.asarray(small))).all())
    emit("sort_compare/pallas_merge_sort_interpret", t_kernel,
         f"n={1<<14} correct={ok}")

    # Parallel scaling (the paper's actual 1.5× claim) on the unified
    # virtual-time runtime: the merge sort's even_levels+bound_depth adaptor
    # stack under join vs depjoin.  In this discrete-event model an owner is
    # never parked on a join (it keeps working and reduces when idle), so
    # depjoin's reduce-by-last-finisher measures as *parity* (gain ≈ 1.0)
    # rather than the thread-parking win real executors see; the row is here
    # to pin that parity, same engine for both policies.
    sort_cost = CostModel(per_item=1.0, split_overhead=8.0,
                          reduce_cost=200.0, steal_latency=2.0)
    for p in (4, 16):
        work = lambda: even_levels(bound_depth(
            SeqWork(0, N, min_size=1 << 14), 8))
        join = Runtime(p, sort_cost, JoinPolicy(), seed=0).run(work())
        dep = Runtime(p, sort_cost, DepJoinPolicy(), seed=0).run(work())
        emit(f"sort_compare/sim_p{p}/join", join.makespan,
             f"speedup={join.speedup_vs_serial:.2f} "
             f"reductions={join.reductions}")
        emit(f"sort_compare/sim_p{p}/depjoin", dep.makespan,
             f"speedup={dep.speedup_vs_serial:.2f} "
             f"gain={join.makespan/dep.makespan:.2f}x")
