"""Paper Fig. 7 — our composable sort vs library sorts.

Single-core host: we compare against np.sort / jnp.argsort as the
"state-of-the-art library" stand-ins the paper compared against (TBB pstl,
gnu parallel).  The honest claim on 1 core is overhead-parity, not speedup;
the 1.5× speedup claim from the paper is about *parallel scaling*, which the
virtual-time runtime reproduces (see fannkuch + task_counts benches).

The Pallas path is the perf trajectory's hillclimb target: the **before**
row re-runs the seed's per-pair merge tree (one ``pallas_call`` per tree
node, whole-array blocks, gather-based bitonic merges) and the **after** row
runs the level-batched merge-path sort (one launch per level, fixed ≤2·tile
blocks).  PR 4 adds the tile-phase hillclimb on top: bitonic network vs
fused in-kernel LSD radix (``tile_bitonic_before`` / ``tile_radix_after``,
bit-identical outputs) and the fused pack/unpack launch-count drop of
``argsort(jit=True)``.  All rows land in ``BENCH_sort.json``; 📌-pinned
rows are guarded by ``tools/bench_delta.py`` in CI.
"""

from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import (CostModel, DepJoinPolicy, JoinPolicy, Runtime,
                        SeqWork, bound_depth, build_plan, even_levels)
from repro.kernels import merge_sort as ms
from repro.kernels.merge_sort import argsort as kernel_argsort
from repro.kernels.radix_sort import radix_tile_sort_packed

from .common import emit, time_fn
from .sort_adaptors import composed_sort

N = 1 << 20
N_PALLAS = 1 << 16
TILE = 1024
NUM_KEY_BITS = 12


# ---------------------------------------------------------------------------
# "before": the seed's per-pair merge tree, reconstructed for comparison
# (one pallas_call per tree node, whole-array BlockSpecs, gather-based
# compare-exchange — O(m log m) work per merge)
# ---------------------------------------------------------------------------

def _ce_gather(x, j, k):
    n = x.shape[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    partner = idx ^ j
    xp = x[partner]
    up = (idx & k) == 0
    lo, hi = jnp.minimum(x, xp), jnp.maximum(x, xp)
    want_lo = jnp.where(up, idx < partner, ~(idx < partner))
    return jnp.where(want_lo, lo, hi)


def _merge_kernel_baseline(a_ref, b_ref, o_ref):
    bi = jnp.concatenate([a_ref[...], b_ref[...][::-1]])
    m = bi.shape[0]
    j = m // 2
    while j >= 1:
        bi = _ce_gather(bi, j, m)
        j //= 2
    o_ref[...] = bi


def _merge_pair_baseline(a, b):
    n = a.shape[0]
    return pl.pallas_call(
        _merge_kernel_baseline,
        in_specs=[pl.BlockSpec((n,), lambda: (0,)),
                  pl.BlockSpec((n,), lambda: (0,))],
        out_specs=pl.BlockSpec((2 * n,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((2 * n,), a.dtype),
        interpret=True)(a, b)


def sort_u32_per_pair_baseline(x, *, tile=1024):
    n = x.shape[0]
    depth = int(math.log2(n // tile))
    if depth % 2 == 1 and n >> (depth + 1) >= 2:
        depth += 1
        tile = n >> depth
    st = ms.tile_sort(x, tile=tile)
    if depth == 0:
        return st
    plan = build_plan(bound_depth(
        SeqWork(0, n, align=tile, min_size=tile), depth))
    return plan.map_reduce(lambda w: st[w.start:w.stop], _merge_pair_baseline)


def argsort_per_pair_baseline(keys, *, tile=1024):
    n = keys.shape[0]
    packed = (keys.astype(jnp.uint32) << ms.IDX_BITS) | \
        jnp.arange(n, dtype=jnp.uint32)
    out = sort_u32_per_pair_baseline(packed, tile=tile)
    return (out & ms.IDX_MASK).astype(jnp.int32)


def run() -> None:
    keys = np.random.RandomState(0).randint(0, 1 << 30, N).astype(np.int32)

    t_np = time_fn(lambda: np.sort(keys, kind="stable"), iters=3)
    emit("sort_compare/np.sort", t_np, f"n={N}", n=N)

    jk = jnp.asarray(keys)
    t_jnp = time_fn(lambda: jnp.sort(jk).block_until_ready(), iters=3)
    emit("sort_compare/jnp.sort", t_jnp, f"ratio_vs_np={t_jnp/t_np:.2f}",
         n=N, ratio_vs_np=t_jnp / t_np)

    plan = build_plan(bound_depth(SeqWork(0, N, min_size=1 << 14), 6))
    t_ours = time_fn(lambda: composed_sort(keys, plan), iters=3)
    emit("sort_compare/kvik_composed", t_ours,
         f"ratio_vs_np={t_ours/t_np:.2f} tasks={plan.num_tasks()}",
         n=N, tasks=plan.num_tasks())

    # --- Pallas hillclimb: per-pair baseline (before) vs level-batched
    # merge-path (after), interpret mode, cold wall clock (includes trace —
    # the launch-count overhead *is* the quantity under test).  Both rows
    # pin strategy="merge": this comparison is about the merge *tree*'s
    # execution shape, not the PR 6 multi-tile path (measured below).
    small = jnp.asarray(keys[:N_PALLAS] & 0x7FF)
    # the after-path runs first so the baseline's interpreter allocations
    # don't pollute its measurement
    after_res: list = []
    with ms.trace_launches() as tr:
        after_res.append(np.asarray(
            kernel_argsort(small, tile=1024, interpret=True,
                           strategy="merge")))
    # median of 3 cold runs (each call re-traces; PR 4 left this row
    # unpinned because a single cold run's 2.2–4.6x spread flaked the gate)
    t_after = time_fn(
        lambda: np.asarray(kernel_argsort(small, tile=1024, interpret=True,
                                          strategy="merge")),
        warmup=0, iters=3)
    order_after = after_res[0]

    before_res: list = []
    t_before = time_fn(
        lambda: before_res.append(np.asarray(
            argsort_per_pair_baseline(small))),
        warmup=0, iters=1)
    order_before = before_res[0]
    # (n/tile − 1) per-pair merge launches + 1 tile-sort launch
    n_launches_before = N_PALLAS // 1024
    emit("sort_compare/pallas_per_pair_before", t_before,
         f"n={N_PALLAS} launches={n_launches_before}",
         n=N_PALLAS, phase="before", launches=n_launches_before)
    identical = bool((order_before == order_after).all())
    correct = bool((np.asarray(small)[order_after]
                    == np.sort(np.asarray(small))).all())
    emit("sort_compare/pallas_level_batched_after", t_after,
         f"n={N_PALLAS} launches={len(tr)} speedup={t_before/t_after:.2f}x "
         f"bit_identical={identical} correct={correct}",
         n=N_PALLAS, phase="after", launches=len(tr),
         speedup_vs_before=t_before / t_after, bit_identical=identical,
         correct=correct, pinned=True,
         max_block_elems=max(r.max_block_elems for r in tr))

    # --- Radix tile-sort hillclimb (PR 4): the seed's bitonic network
    # (before) vs the fused in-kernel LSD radix sort (after) on the same
    # job — 12-bit keys in, sorted packed uint32 tiles out.  Cold wall
    # clock per run (each interpret-mode call re-traces; that per-launch
    # overhead is the quantity under test), median of 3.
    keys12 = jnp.asarray(keys[:N_PALLAS] & ((1 << NUM_KEY_BITS) - 1))
    idx_bits = (N_PALLAS - 1).bit_length()

    def bitonic_tile_job():
        packed = (keys12.astype(jnp.uint32) << idx_bits) | \
            jnp.arange(N_PALLAS, dtype=jnp.uint32)
        return np.asarray(ms.tile_sort(packed, tile=TILE, interpret=True))

    def radix_tile_job():
        return np.asarray(radix_tile_sort_packed(
            keys12, n=N_PALLAS, tile=TILE, num_key_bits=NUM_KEY_BITS,
            idx_bits=idx_bits, interpret=True))

    tiles_before = bitonic_tile_job()
    t_tile_bit = time_fn(bitonic_tile_job, warmup=0, iters=3)
    tiles_after = radix_tile_job()
    t_tile_rad = time_fn(radix_tile_job, warmup=0, iters=3)
    tile_identical = bool((tiles_before == tiles_after).all())
    emit("sort_compare/tile_bitonic_before", t_tile_bit,
         f"n={N_PALLAS} tile={TILE} num_key_bits={NUM_KEY_BITS}",
         n=N_PALLAS, tile=TILE, num_key_bits=NUM_KEY_BITS, phase="before",
         calibration=True)
    emit("sort_compare/tile_radix_after", t_tile_rad,
         f"n={N_PALLAS} tile={TILE} num_key_bits={NUM_KEY_BITS} "
         f"speedup={t_tile_bit/t_tile_rad:.2f}x "
         f"bit_identical={tile_identical}",
         n=N_PALLAS, tile=TILE, num_key_bits=NUM_KEY_BITS, phase="after",
         speedup_vs_bitonic=t_tile_bit / t_tile_rad,
         bit_identical=tile_identical, pinned=True)

    # --- Fused pack/unpack: end-to-end argsort(jit=True) launch count.
    # The seed ran pack/unpack as jnp elementwise ops — standalone XLA
    # launches *outside* the sort kernels, invisible to trace_launches;
    # fused=False reconstructs them as explicit pallas kernels so the two
    # elementwise launches are countable.  The fused path runs zero either
    # way (traced once inside the jit; caches cleared so the trace runs).
    small_keys = jnp.asarray(keys[:1 << 14] & 0x7FF).astype(jnp.int32)
    jax.clear_caches()
    with ms.trace_launches() as tr_fused:
        of = np.asarray(kernel_argsort(small_keys, tile=TILE,
                                       interpret=True, jit=True,
                                       strategy="merge"))
    jax.clear_caches()
    with ms.trace_launches() as tr_unfused:
        ou = np.asarray(kernel_argsort(small_keys, tile=TILE,
                                       interpret=True, jit=True,
                                       fused=False))
    drop = len(tr_unfused) - len(tr_fused)
    emit("sort_compare/argsort_jit_launches", float(len(tr_fused)),
         f"fused={len(tr_fused)} unfused={len(tr_unfused)} drop={drop} "
         f"identical={bool((of == ou).all())}",
         fused_launches=len(tr_fused), unfused_launches=len(tr_unfused),
         launch_drop=drop, identical=bool((of == ou).all()))

    # --- Multi-tile LSD radix vs the merge tree (PR 6 tentpole): global
    # argsort at n=2^18, jit-cached (hot) wall clock, median of 3.  The
    # merge row is a calibration peer (same kind of interpret-mode pallas
    # work); the multi-tile row pins the ≥1.5x win.
    n_mt = 1 << 18
    keys_mt = jnp.asarray(keys[:n_mt] & ((1 << NUM_KEY_BITS) - 1))

    def mt_job():
        return np.asarray(kernel_argsort(keys_mt, tile=TILE, interpret=True,
                                         jit=True, strategy="multi_tile"))

    def merge_job():
        return np.asarray(kernel_argsort(keys_mt, tile=TILE, interpret=True,
                                         jit=True, strategy="merge"))

    order_mt = mt_job()                       # compile
    t_mt = time_fn(mt_job, warmup=0, iters=3)
    order_mg = merge_job()                    # compile
    t_mg = time_fn(merge_job, warmup=0, iters=3)
    mt_identical = bool((order_mt == order_mg).all())
    emit("sort_compare/merge_tree_argsort_2e18", t_mg,
         f"n={n_mt} tile={TILE} num_key_bits={NUM_KEY_BITS}",
         n=n_mt, tile=TILE, num_key_bits=NUM_KEY_BITS, phase="before",
         calibration=True)
    emit("sort_compare/multi_tile_argsort_2e18", t_mt,
         f"n={n_mt} tile={TILE} speedup={t_mg/t_mt:.2f}x "
         f"bit_identical={mt_identical}",
         n=n_mt, tile=TILE, num_key_bits=NUM_KEY_BITS, phase="after",
         speedup_vs_merge=t_mg / t_mt, bit_identical=mt_identical,
         pinned=True)

    # launch-count independence of n, pinned as exact integers: the
    # multi-tile count is 3·ceil(num_key_bits/digit_bits) at ANY n, while
    # the merge tree pays 1 + log2(n/tile)
    with ms.trace_launches() as mt16:
        kernel_argsort(small, tile=TILE, interpret=True)
    with ms.trace_launches() as mt18:
        kernel_argsort(keys_mt, tile=TILE, interpret=True)
    with ms.trace_launches() as mg16:
        kernel_argsort(small, tile=TILE, interpret=True, strategy="merge")
    merge_launches_mt = 1 + int(math.log2(n_mt // TILE))   # 1 + tree depth
    emit("sort_compare/multi_tile_launch_counts", 0.0,
         f"multi_tile n=2^16:{len(mt16)} n=2^18:{len(mt18)} "
         f"merge n=2^16:{len(mg16)} n=2^18:{merge_launches_mt}",
         multi_tile_launches_n64k=len(mt16),
         multi_tile_launches_n256k=len(mt18),
         merge_launches_n64k=len(mg16),
         merge_launches_n256k=merge_launches_mt,
         pinned_ints=["multi_tile_launches_n64k",
                      "multi_tile_launches_n256k"])

    # Parallel scaling (the paper's actual 1.5× claim) on the unified
    # virtual-time runtime: the merge sort's even_levels+bound_depth adaptor
    # stack under join vs depjoin.  In this discrete-event model an owner is
    # never parked on a join (it keeps working and reduces when idle), so
    # depjoin's reduce-by-last-finisher measures as *parity* (gain ≈ 1.0)
    # rather than the thread-parking win real executors see; the row is here
    # to pin that parity, same engine for both policies.
    sort_cost = CostModel(per_item=1.0, split_overhead=8.0,
                          reduce_cost=200.0, steal_latency=2.0)
    for p in (4, 16):
        work = lambda: even_levels(bound_depth(
            SeqWork(0, N, min_size=1 << 14), 8))
        join = Runtime(p, sort_cost, JoinPolicy(), seed=0).run(work())
        dep = Runtime(p, sort_cost, DepJoinPolicy(), seed=0).run(work())
        emit(f"sort_compare/sim_p{p}/join", join.makespan,
             f"speedup={join.speedup_vs_serial:.2f} "
             f"reductions={join.reductions}",
             p=p, speedup=join.speedup_vs_serial)
        emit(f"sort_compare/sim_p{p}/depjoin", dep.makespan,
             f"speedup={dep.speedup_vs_serial:.2f} "
             f"gain={join.makespan/dep.makespan:.2f}x",
             p=p, speedup=dep.speedup_vs_serial)
