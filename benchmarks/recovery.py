"""Recovery cost under injected faults — the BENCH_recovery.json trajectory.

The paper's robustness claims are *cost* claims (by_blocks bounds wasted
work; adaptive re-spreads load through steal-linked splitting), so this
benchmark measures what a failure actually costs each policy on the unified
virtual-time Runtime, deterministic per (plan, seed):

* **worker death** (the kill-a-host scenario at simulator granularity):
  one of p workers dies a quarter of the way into the region.  Static
  partitioning fails over whole chunks — one survivor re-runs the orphaned
  chunk serially, and everything the dead worker had executed since its
  chunk began is lost.  Adaptive (with the mid-region preemption hook)
  loses at most one truncated grant and re-spreads the orphan across all
  survivors via steals.  `recovery_makespan_ratio` = static/adaptive
  makespan under the SAME fault plan; the ≥1.3x bar is pinned as an
  integer row (ratio_x100, exact under bit-identical virtual time) gated
  by tools/bench_delta.py.
* **slowdown** (the straggler scenario): one worker at 1/4 speed; the
  preemption hook is what lets late steal requests be served at all —
  without it adaptive degenerates to the pinned zero-recovery roofline
  row.
* **lost-work fraction**: items whose fold state died with a worker and
  had to be re-executed, as a fraction of total — the Dask-overheads-paper
  question ("what does recovery cost"), not just "does it recover".
"""

from __future__ import annotations

from repro.core import (AdaptivePolicy, CostModel, FaultPlan, Slowdown,
                        StaticPartitionPolicy, WorkerDeath, WorkRange,
                        simulate)

from .common import emit, time_fn

P = 8
ITEMS = 200_000
COST = CostModel(per_item=1.0)
# death a quarter of the way through a perfectly balanced region
DEATH = FaultPlan(deaths=(WorkerDeath(0, ITEMS / P / 2.0),))
SLOW = FaultPlan(slowdowns=(Slowdown(0, 0.0, 1e12, 0.25),))


def _run(policy, faults):
    return simulate(WorkRange(0, ITEMS), policy, P, COST, seed=0,
                    faults=faults)


def run() -> None:
    # --- worker death: static whole-chunk failover vs adaptive re-spread --
    static = _run(StaticPartitionPolicy(), DEATH)
    adaptive = _run(AdaptivePolicy(preempt=True), DEATH)
    ratio = static.makespan / adaptive.makespan
    us = time_fn(lambda: _run(AdaptivePolicy(preempt=True), DEATH))
    emit("recovery/death/adaptive_vs_static", us,
         f"ratio={ratio:.2f}x static={static.makespan:.0f} "
         f"adaptive={adaptive.makespan:.0f} (>=1.3x bar)",
         pinned_ints=["ratio_x100", "meets_bar_130", "items_conserved"],
         ratio_x100=int(ratio * 100),
         meets_bar_130=int(ratio >= 1.3),
         items_conserved=int(
             static.items_processed == adaptive.items_processed == ITEMS),
         static_makespan=static.makespan,
         adaptive_makespan=adaptive.makespan,
         deaths=adaptive.deaths, recoveries=adaptive.recoveries)

    # --- lost work: what the death cost beyond the makespan ---------------
    emit("recovery/death/lost_work", 0.0,
         f"static_lost={static.lost_items} adaptive_lost={adaptive.lost_items} "
         f"static_frac={static.lost_work_fraction:.4f} "
         f"adaptive_frac={adaptive.lost_work_fraction:.4f}",
         pinned_ints=["adaptive_loses_less"],
         adaptive_loses_less=int(
             adaptive.lost_items < static.lost_items),
         static_lost_items=static.lost_items,
         adaptive_lost_items=adaptive.lost_items,
         static_lost_frac=static.lost_work_fraction,
         adaptive_lost_frac=adaptive.lost_work_fraction)

    # --- slowdown: the straggler gap, closed by the preemption hook -------
    st_slow = _run(StaticPartitionPolicy(), SLOW)
    ad_plain = _run(AdaptivePolicy(), SLOW)
    ad_pre = _run(AdaptivePolicy(preempt=True), SLOW)
    ratio_slow = st_slow.makespan / ad_pre.makespan
    emit("recovery/slowdown/preempt_hook", 0.0,
         f"ratio={ratio_slow:.2f}x static={st_slow.makespan:.0f} "
         f"plain={ad_plain.makespan:.0f} preempt={ad_pre.makespan:.0f}",
         pinned_ints=["hook_beats_plain", "meets_bar_130"],
         hook_beats_plain=int(ad_pre.makespan < ad_plain.makespan),
         meets_bar_130=int(ratio_slow >= 1.3),
         static_makespan=st_slow.makespan,
         plain_makespan=ad_plain.makespan,
         preempt_makespan=ad_pre.makespan)

    # --- determinism: the whole table is replayable from (plan, seed) -----
    again = _run(AdaptivePolicy(preempt=True), DEATH)
    emit("recovery/determinism", 0.0,
         f"replay_identical={int(again.makespan == adaptive.makespan)}",
         pinned_ints=["replay_identical"],
         replay_identical=int(
             (again.makespan, again.lost_items, again.recoveries)
             == (adaptive.makespan, adaptive.lost_items,
                 adaptive.recoveries)))


if __name__ == "__main__":
    from .common import header, write_json
    header()
    run()
    write_json("recovery")
