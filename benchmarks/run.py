"""Benchmark harness entrypoint — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only name]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (all_scan, fannkuch, find_first, moe_dispatch, roofline,
                   sort_adaptors, sort_compare, task_counts)
    from .common import header

    modules = {
        "find_first": find_first,        # paper Fig. 3/4
        "all_scan": all_scan,            # paper Fig. 5
        "sort_adaptors": sort_adaptors,  # paper Fig. 6
        "sort_compare": sort_compare,    # paper Fig. 7
        "fannkuch": fannkuch,            # paper Fig. 8
        "task_counts": task_counts,      # §2.1 / §3.6 claims
        "moe_dispatch": moe_dispatch,    # sort-dispatch application
        "roofline": roofline,            # §Roofline summary
    }
    header()
    failed = []
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
