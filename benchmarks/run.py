"""Benchmark harness entrypoint — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only name]

Prints ``name,us_per_call,derived`` CSV rows and writes machine-readable
``results/bench/BENCH_<stem>.json`` trajectory files (benchmarks/common.py).
The sort benchmarks share the ``sort`` stem: ``BENCH_sort.json`` carries the
before/after rows the perf trajectory tracks.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing results/bench/BENCH_*.json")
    args = ap.parse_args()

    from . import (all_scan, fannkuch, find_first, moe_dispatch, recovery,
                   roofline, scan_ssm, serve_load, slo_load, sort_adaptors,
                   sort_compare, task_counts)
    from .common import header, reset, write_json

    # module name -> (module, JSON stem); sort benches share one trajectory
    modules = {
        "find_first": (find_first, "find_first"),        # paper Fig. 3/4
        "all_scan": (all_scan, "all_scan"),              # paper Fig. 5
        "sort_adaptors": (sort_adaptors, "sort"),        # paper Fig. 6
        "sort_compare": (sort_compare, "sort"),          # paper Fig. 7
        "fannkuch": (fannkuch, "fannkuch"),              # paper Fig. 8
        "task_counts": (task_counts, "task_counts"),     # §2.1 / §3.6 claims
        "moe_dispatch": (moe_dispatch, "moe_dispatch"),  # sort dispatch
        "roofline": (roofline, "roofline"),              # §Roofline summary
        "recovery": (recovery, "recovery"),              # fault recovery cost
        "serve_load": (serve_load, "serve"),             # continuous batching
        "slo_load": (slo_load, "slo"),                   # SLO degradation
        "scan_ssm": (scan_ssm, "scan_ssm"),              # chunked SSM scan
    }
    header()
    failed = []
    # group modules by stem so shared trajectories land in one file
    by_stem: dict = {}
    for name, (mod, stem) in modules.items():
        if args.only and name != args.only:
            continue
        by_stem.setdefault(stem, []).append((name, mod))
    for stem, mods in by_stem.items():
        reset()
        ran_any = False
        for name, mod in mods:
            try:
                mod.run()
                ran_any = True
            except Exception:
                failed.append(name)
                traceback.print_exc()
        if ran_any and not args.no_json:
            path = write_json(stem)
            print(f"# wrote {path}", file=sys.stderr)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
