"""Paper Fig. 6 — the same sort, different task-splitting adaptors.

The paper's point is *composability*: one implementation, six schedules, and
scheduling visibly changes the execution profile.  We sort 2^20 int32 keys
with tile-sort + plan-driven merges; the sort phase's division policy is the
swappable adaptor.  Reported per variant: wall time on this host and the
plan's task/division counts (the quantity the schedules actually control).
"""

from __future__ import annotations

import numpy as np

from repro.core import (SeqWork, bound_depth, build_plan, join_context,
                        thief_splitting, StealContext)

from .common import emit, time_fn

N = 1 << 20
TILE = 1 << 14


def composed_sort(keys: np.ndarray, plan) -> np.ndarray:
    """Stable merge sort driven by a Kvik plan (numpy leaves/merges)."""
    def leaf(work):
        return np.sort(keys[work.start:work.stop], kind="stable")

    def merge(a, b):
        out = np.empty(len(a) + len(b), a.dtype)
        ia = ib = io = 0
        # numpy-vectorized two-way merge via searchsorted
        pos = np.searchsorted(a, b, side="right")
        out[pos + np.arange(len(b))] = b
        mask = np.ones(len(out), bool)
        mask[pos + np.arange(len(b))] = False
        out[mask] = a
        return out

    return plan.map_reduce(leaf, merge)


def run() -> None:
    keys = np.random.RandomState(0).randint(0, 1 << 30, N).astype(np.int32)
    expect = np.sort(keys)

    variants = {
        "bound_depth(6)": bound_depth(SeqWork(0, N, min_size=TILE), 6),
        "thief_splitting(p=16)": thief_splitting(
            SeqWork(0, N, min_size=TILE), p=16),
        "join_context(6)": join_context(SeqWork(0, N, min_size=TILE), 6),
        "join_context(6)+steal": None,  # built below with a stolen context
    }
    for name, work in variants.items():
        if name.endswith("+steal"):
            ctx = StealContext(stolen=True, worker=1)
            plan = build_plan(join_context(SeqWork(0, N, min_size=TILE), 6),
                              ctx=ctx)
        else:
            plan = build_plan(work)
        out = composed_sort(keys, plan)
        assert np.array_equal(out, expect), name
        t = time_fn(lambda: composed_sort(keys, plan), iters=3)
        emit(f"sort_adaptors/{name}", t,
             f"tasks={plan.num_tasks()} divisions={plan.divisions} "
             f"depth={plan.depth()}")
