"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Per (arch × shape × mesh) cell, from the trip-count-aware HLO analysis:

  T_comp = FLOPs_per_chip / 197e12        (v5e bf16 peak)
  T_mem  = traffic_bytes_per_chip / 819e9 (HBM)
  T_coll = collective_bytes_per_chip / 50e9 (ICI per-chip link bw)

Dominant term = the bottleneck.  MODEL_FLOPS uses the 6·N·D convention
(2·N·D for forward-only kinds, N = active params); the ratio
MODEL_FLOPS / HLO_FLOPS exposes remat recompute, attention, dispatch
overheads and head-padding waste.  Roofline fraction = T_model_compute /
max(T_comp, T_mem, T_coll): the fraction of ideal-compute throughput this
lowering would achieve if the dominant term were perfectly overlapped with
the rest.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per chip link

RESULTS = Path("results/dryrun")


def model_flops(rec: Dict) -> float:
    n_active = rec["params_active"]
    kind = rec["kind"]
    B = rec["global_batch"]
    # enc-dec archs process seq/4 decoder tokens on train shapes and
    # decoder_prefill_len on prefill shapes (configs/specs.py conventions)
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.configs.specs import decoder_len
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_enc = cfg.encoder_param_count()
    n_dec = n_active - n_enc
    enc_tokens = B * shape.seq_len if cfg.is_encdec else 0
    if kind == "train":
        tokens = B * decoder_len(cfg, shape)
        return 6.0 * (n_dec * tokens + n_enc * enc_tokens)
    if kind == "prefill":
        tokens = B * decoder_len(cfg, shape)
        return 2.0 * (n_dec * tokens + n_enc * enc_tokens)
    return 2.0 * n_dec * B        # decode: one token per sequence


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    hlo = rec["hlo"]
    chips = rec["chips"]
    t_comp = hlo["flops_per_chip"] / PEAK_FLOPS
    t_mem = hlo["traffic_bytes_per_chip"] / HBM_BW
    t_coll = hlo["collective_bytes_per_chip"] / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(rec)
    hlo_total = hlo["flops_per_chip"] * chips
    t_model = mf / chips / PEAK_FLOPS
    frac = t_model / max(t_comp, t_mem, t_coll, 1e-12)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_comp_s": t_comp, "t_mem_s": t_mem, "t_coll_s": t_coll,
        "dominant": dominant[0],
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / max(hlo_total, 1e-9),
        "roofline_fraction": frac,
        "mem_gib": rec["memory"]["peak_bytes_per_device"] / 2**30,
        "per_collective": hlo.get("per_collective_bytes", {}),
    }


def suggestion(row: Dict) -> str:
    d = row["dominant"]
    if d == "collective":
        top = max(row["per_collective"].items(), key=lambda kv: kv[1],
                  default=("-", 0))
        return (f"cut {top[0]} volume (overlap via collective-matmul / "
                f"compress grads / reshard)")
    if d == "memory":
        return "raise arithmetic intensity (fuse, bigger tiles, bf16 temps)"
    if row["useful_ratio"] < 0.4:
        return "reduce non-model FLOPs (remat policy, dispatch, head padding)"
    return "near compute roof — overlap remaining collectives"


def load_all(path: Path = RESULTS) -> List[Dict]:
    rows = []
    for f in sorted(path.glob("*.json")):
        rec = json.loads(f.read_text())
        # patch dec_len for enc-dec train cells
        if rec.get("status") == "ok":
            r = analyze_record(rec)
            if r:
                rows.append(r)
    return rows


def markdown_table(rows: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | dominant "
           "| useful FLOP ratio | roofline frac | GiB/dev | next move |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_comp_s']:.4f} | "
            f"{r['t_mem_s']:.4f} | {r['t_coll_s']:.4f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['mem_gib']:.1f} | {suggestion(r)} |")
    return "\n".join(out)


def straggler_sim(name: str, *, p: int = 16, slow: float = 0.85) -> None:
    """Unified-runtime cross-check of the roofline's perfect-speed
    assumption: partition the dominant stream over ``p`` chips with one
    straggler at ``slow``× speed.  A static partition is gated by the
    straggler (frac ≈ slow) — multiply the roofline fraction by this factor
    for a skewed mesh.  The plain-adaptive row is pinned alongside: with
    grants growing unchecked, adaptive steals only at region start, so it
    does *not* recover the straggler gap.  The ``adaptive_preempt`` row is
    the fix (PR 7): the mid-region preemption hook clips grants while idle
    demand exists, so late steal requests are served and the straggler's
    remainder re-spreads — frac recovers toward 1.0.
    """
    from repro.core import (AdaptivePolicy, CostModel, StaticPartitionPolicy,
                            WorkRange, simulate)
    from .common import emit
    items = 200_000
    speeds = [1.0] * p
    speeds[0] = slow
    ideal = items / sum(speeds)
    cost_adap = CostModel(per_item=1.0, split_overhead=4.0,
                          steal_latency=0.0)
    stat = simulate(WorkRange(0, items), StaticPartitionPolicy(), p,
                    CostModel(per_item=1.0), seed=0, speeds=speeds)
    # steal_latency=0: this row isolates the *partitioning* question (can
    # work migrate off the straggler at all), not steal-protocol costs
    adap = simulate(WorkRange(0, items), AdaptivePolicy(), p, cost_adap,
                    seed=0, speeds=speeds)
    pre = simulate(WorkRange(0, items), AdaptivePolicy(preempt=True), p,
                   cost_adap, seed=0, speeds=speeds)
    emit(f"roofline/straggler_sim/{name}", stat.makespan,
         f"static_frac={ideal/stat.makespan:.2f} "
         f"adaptive_frac={ideal/adap.makespan:.2f} "
         f"adaptive_preempt_frac={ideal/pre.makespan:.2f} p={p} slow={slow}",
         p=p, slow=slow, static_frac=ideal / stat.makespan,
         adaptive_frac=ideal / adap.makespan,
         adaptive_preempt_frac=ideal / pre.makespan)


def run() -> None:
    from .common import emit
    rows = load_all() if RESULTS.exists() else []
    if not rows:
        emit("roofline/missing", 0.0, "run launch/dryrun.py first")
        # artifacts absent: still exercise the unified-runtime overlap model
        # on a nominal cell so the trajectory has the straggler rows
        straggler_sim("nominal")
        return
    for r in rows:
        emit(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}",
             max(r["t_comp_s"], r["t_mem_s"], r["t_coll_s"]) * 1e6,
             f"dominant={r['dominant']} useful={r['useful_ratio']:.2f} "
             f"frac={r['roofline_fraction']:.2f} mem={r['mem_gib']:.1f}GiB",
             dominant=r["dominant"], useful_ratio=r["useful_ratio"],
             roofline_fraction=r["roofline_fraction"],
             mem_gib=r["mem_gib"])
        straggler_sim(f"{r['mesh']}/{r['arch']}/{r['shape']}")


if __name__ == "__main__":
    rows = load_all()
    print(markdown_table(rows, "16x16"))
    print()
    print(markdown_table(rows, "2x16x16"))
