"""Continuous batching vs the synchronous batch engine under bursty,
heavy-tailed traffic — the BENCH_serve.json trajectory.

A seeded generator emits a trace with the two properties that break static
batching: bursty arrivals (≈35% of gaps are zero — requests pile up, then
silence) and heavy-tailed prompt/output lengths (Pareto prompts, a long
``max_new`` tail).  Both engines replay the SAME wall-clock arrival trace;
the gaps are scaled by the measured per-token decode cost so the trace
stresses the scheduler, not the host's absolute speed.

What the synchronous engine loses on this trace is structural: every
admitted batch pads to its longest prompt, decodes to its largest
``max_new``, and blocks the queue until the whole batch retires
(head-of-line).  The continuous engine retires each slot at its own EOS or
budget, backfills the freed lane immediately, and interleaves chunked
prefill between decode ticks — plus its decode shapes are fixed, so the hot
loop never recompiles.

Wall-clock ratios cannot be pinned exactly across machines, so the pinned
rows are booleans recomputed per run: goodput ratio ≥ 1.3×, p99 latency
improved, and — timing-independent, hence exact — both engines' tokens
equal serving every request one at a time.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np

from .common import emit

EOS = 7
SEED = 0
N_REQUESTS = 28
MAX_BATCH = 4
MAX_SEQ = 224
GOODPUT_BAR = 1.3


def _model():
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.models.model import Model
    cfg = get_smoke_config("llama3-8b")
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(SEED))
    return model, params


def _trace(rng: np.random.RandomState, n: int, vocab: int, spt: float
           ) -> Tuple[List, List[float]]:
    """(request specs, arrival offsets in seconds).  Pareto prompt lengths,
    heavy-tailed max_new, bursty gaps in units of measured decode time."""
    specs = []
    t = 0.0
    arrivals = []
    for i in range(n):
        plen = int(np.clip(8 * (1.0 + rng.pareto(1.1)), 8, 96))
        max_new = int(rng.choice([4, 8, 12, 24, 48],
                                 p=[0.35, 0.25, 0.20, 0.12, 0.08]))
        prompt = rng.randint(3, vocab, size=plen).astype(np.int32)
        specs.append((i, prompt, max_new))
        gap = 0.0 if rng.rand() < 0.35 else float(rng.exponential(6.0)) * spt
        t += gap
        arrivals.append(t)
    return specs, arrivals


def _requests(specs) -> List:
    from repro.serve.engine import Request
    return [Request(rid=i, prompt=p, max_new=m) for i, p, m in specs]


def _pending(eng) -> bool:
    if hasattr(eng, "pending"):
        return eng.pending
    return bool(eng.queue) or eng._residual is not None


def _replay(eng, reqs: List, arrivals: List[float]) -> Tuple[Dict, float]:
    """Feed the arrival trace in wall-clock time; returns (done, makespan)."""
    done: Dict[int, object] = {}
    i = 0
    t0 = time.perf_counter()
    while i < len(reqs) or _pending(eng):
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            reqs[i].t_submit = t0 + arrivals[i]
            eng.submit(reqs[i])
            i += 1
        if not _pending(eng):
            time.sleep(max(0.0, arrivals[i] - now))
            continue
        for r in eng.step():
            done[r.rid] = r
    return done, time.perf_counter() - t0


def _latencies(done: Dict) -> np.ndarray:
    return np.asarray(sorted(r.t_done - r.t_submit for r in done.values()))


def run() -> None:
    from repro.serve.engine import ContinuousEngine, Engine, EngineConfig

    model, params = _model()
    vocab = model.cfg.vocab_size
    sync = Engine(model, params, EngineConfig(
        max_batch=MAX_BATCH, eos_id=EOS, max_seq=MAX_SEQ))
    cont = ContinuousEngine(model, params, EngineConfig(
        max_batch=MAX_BATCH, eos_id=EOS, max_seq=MAX_SEQ,
        decode_tick=8, prefill_block_budget=4))

    # Warm both engines on a same-distribution trace (arrivals compressed to
    # zero) so the timed replay measures scheduling, not first-touch jit —
    # the sync engine still pays any shape-diversity compiles its batching
    # produces, which is part of what the trace measures.
    warm_specs, _ = _trace(np.random.RandomState(SEED + 1), 10, vocab, 0.0)
    _replay(sync, _requests(warm_specs), [0.0] * len(warm_specs))
    _replay(cont, _requests(warm_specs), [0.0] * len(warm_specs))
    spt = max(cont.telemetry.decode_s_per_token, 1e-6)

    specs, arrivals = _trace(np.random.RandomState(SEED), N_REQUESTS,
                             vocab, spt)
    # one untimed replay of the real trace first: the batch compositions it
    # produces compile whatever shapes the timed replay will reuse
    _replay(sync, _requests(specs), arrivals)
    _replay(cont, _requests(specs), arrivals)
    sync_done, sync_make = _replay(sync, _requests(specs), arrivals)
    cont_done, cont_make = _replay(cont, _requests(specs), arrivals)

    sync_toks = sum(len(r.result) for r in sync_done.values())
    cont_toks = sum(len(r.result) for r in cont_done.values())
    sync_good = sync_toks / sync_make
    cont_good = cont_toks / cont_make
    ratio = cont_good / sync_good
    emit("serve/load/goodput_continuous_vs_sync", cont_make * 1e6,
         f"ratio={ratio:.2f}x cont={cont_good:.1f}tok/s "
         f"sync={sync_good:.1f}tok/s (>= {GOODPUT_BAR}x bar)",
         pinned_ints=["meets_bar_130"],
         meets_bar_130=int(ratio >= GOODPUT_BAR),
         ratio_x100=int(ratio * 100),
         cont_goodput_tok_s=cont_good, sync_goodput_tok_s=sync_good,
         cont_makespan_s=cont_make, sync_makespan_s=sync_make,
         cont_tokens=cont_toks, sync_tokens=sync_toks,
         requests=N_REQUESTS)

    slat, clat = _latencies(sync_done), _latencies(cont_done)
    sp50, sp99 = np.percentile(slat, [50, 99])
    cp50, cp99 = np.percentile(clat, [50, 99])
    emit("serve/load/p99_latency", cp99 * 1e6,
         f"cont_p50={cp50:.3f}s cont_p99={cp99:.3f}s "
         f"sync_p50={sp50:.3f}s sync_p99={sp99:.3f}s",
         pinned_ints=["p99_improved"],
         p99_improved=int(cp99 < sp99),
         cont_p50_s=float(cp50), cont_p99_s=float(cp99),
         sync_p50_s=float(sp50), sync_p99_s=float(sp99))

    # Correctness is timing-independent (greedy decode, row-independent
    # batches), so exact equality against serve-one-at-a-time is pinned.
    ref_eng = Engine(model, params, EngineConfig(
        max_batch=1, eos_id=EOS, max_seq=MAX_SEQ))
    refs: Dict[int, np.ndarray] = {}
    for req in _requests(specs):
        ref_eng.submit(req)
        while _pending(ref_eng):
            for r in ref_eng.step():
                refs[r.rid] = np.asarray(r.result)
    matches = all(
        np.array_equal(refs[i], np.asarray(sync_done[i].result))
        and np.array_equal(refs[i], np.asarray(cont_done[i].result))
        for i in range(N_REQUESTS))
    emit("serve/load/correctness_mixed_lengths", 0.0,
         f"matches_one_at_a_time={int(matches)} over {N_REQUESTS} "
         f"mixed-length requests",
         pinned_ints=["matches_one_at_a_time"],
         matches_one_at_a_time=int(matches))

    snap = cont.telemetry.snapshot()
    emit("serve/load/telemetry", spt * 1e6,
         f"ticks={snap['ticks']} admissions={snap['admissions']} "
         f"preemptions={snap['prefill_preemptions']} "
         f"deferred_pages={snap['deferred_pages']} "
         f"cap_peak={snap['cap_live_peak']}",
         **{k: v for k, v in snap.items()})


if __name__ == "__main__":
    from .common import header
    header()
    run()
