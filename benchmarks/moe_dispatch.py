"""MoE dispatch: sort-based (the paper's stable sort) vs GShard einsum.

Wall time on host for a smoke-scale MoE layer — jnp stable argsort vs the
level-batched Pallas merge sort (the §3.7 kernel wired into the layer) —
plus the analytic FLOP overhead of the einsum dispatch at production scale
(the quantity the sort path eliminates, §Perf hillclimb evidence), plus the
dispatch-scaling picture on the unified virtual-time Runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.core import (AdaptivePolicy, CostModel, StaticPartitionPolicy,
                        WorkRange, simulate)
from repro.models.moe import capacity_per_group, moe_einsum, moe_init, \
    moe_sort_dispatch, sort_route

from .common import emit, time_fn


def run() -> None:
    cfg = get_smoke_config("llama4-scout-17b-a16e")
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256, cfg.d_model)
                          ).astype(cfg.dtype())
    tokens = 4 * 256

    f_s = jax.jit(lambda p, x: moe_sort_dispatch(p, cfg, x)[0])
    t_s = time_fn(lambda: f_s(params, x).block_until_ready(), iters=3)
    emit("moe_dispatch/sort_smoke", t_s, f"tokens={tokens}", tokens=tokens)

    f_e = jax.jit(lambda p, x: moe_einsum(p, cfg, x)[0])
    t_e = time_fn(lambda: f_e(params, x).block_until_ready(), iters=3)
    emit("moe_dispatch/einsum_smoke", t_e, f"ratio_vs_sort={t_e/t_s:.2f}",
         tokens=tokens, ratio_vs_sort=t_e / t_s)

    # the paper's kernel inside the layer: the fused radix merge sort
    # (interpret mode — structure/correctness on host, not device speed)
    f_p = jax.jit(lambda p, x: moe_sort_dispatch(p, cfg, x,
                                                 sort_fn="pallas")[0])
    t_p = time_fn(lambda: f_p(params, x).block_until_ready(),
                  warmup=1, iters=1)
    same = bool(np.allclose(np.asarray(f_p(params, x), np.float32),
                            np.asarray(f_s(params, x), np.float32),
                            atol=1e-5))
    emit("moe_dispatch/sort_pallas_smoke", t_p,
         f"tokens={tokens} matches_jnp_sort={same}",
         tokens=tokens, matches_jnp_sort=same)

    # hot (jit-cached) rows, median of 3: dispatch speed with trace/compile
    # amortized away — the steady-state number a training step sees
    t_s_hot = time_fn(lambda: f_s(params, x).block_until_ready(),
                      warmup=1, iters=3)
    emit("moe_dispatch/sort_smoke_hot", t_s_hot, f"tokens={tokens}",
         tokens=tokens, hot=True)
    t_p_hot = time_fn(lambda: f_p(params, x).block_until_ready(),
                      warmup=1, iters=3)
    emit("moe_dispatch/sort_pallas_hot", t_p_hot,
         f"tokens={tokens} ratio_vs_jnp={t_p_hot/t_s_hot:.2f}",
         tokens=tokens, hot=True, ratio_vs_jnp=t_p_hot / t_s_hot)

    # one-launch dispatch (PR 6): the stable sort by expert id AND the
    # activation-row gather run inside a single pallas_call — pinned as an
    # exact integer so CI gates the structure, not a timing
    from repro.kernels.merge_sort import trace_launches
    jax.clear_caches()
    with trace_launches() as trd:
        sort_route(params, cfg, x, "pallas")
    emit("moe_dispatch/dispatch_launches", 0.0,
         f"launches={len(trd)} kinds={[r.kind for r in trd]}",
         dispatch_launches=len(trd),
         pinned_ints=["dispatch_launches"])

    # radix-vs-bitonic inside the layer, cold (trace + compile + run):
    # the radix tile phase's ~20-op fori_loop body vs the bitonic
    # network's ~550 unrolled stages is a compile-graph-size win, so the
    # comparison is first-call wall clock with fresh jit caches
    import functools
    import math

    from repro.kernels.merge_sort import argsort as kernel_argsort
    bits = max(1, math.ceil(math.log2(max(2, cfg.num_experts))))
    bitonic_sort = functools.partial(kernel_argsort, num_key_bits=bits,
                                     interpret=True, jit=True,
                                     method="bitonic")
    jax.clear_caches()
    f_p2 = jax.jit(lambda p, x: moe_sort_dispatch(p, cfg, x,
                                                  sort_fn="pallas")[0])
    t_p_cold = time_fn(lambda: f_p2(params, x).block_until_ready(),
                       warmup=0, iters=1)
    jax.clear_caches()
    f_pb = jax.jit(lambda p, x: moe_sort_dispatch(p, cfg, x,
                                                  sort_fn=bitonic_sort)[0])
    t_pb_cold = time_fn(lambda: f_pb(params, x).block_until_ready(),
                        warmup=0, iters=1)
    same_b = bool(np.allclose(np.asarray(f_pb(params, x), np.float32),
                              np.asarray(f_p(params, x), np.float32),
                              atol=1e-5))
    emit("moe_dispatch/sort_pallas_bitonic_cold", t_pb_cold,
         f"tokens={tokens} matches_radix={same_b}",
         tokens=tokens, matches_radix=same_b)
    emit("moe_dispatch/sort_pallas_radix_cold", t_p_cold,
         f"tokens={tokens} radix_speedup={t_pb_cold/t_p_cold:.2f}x",
         tokens=tokens, radix_speedup=t_pb_cold / t_p_cold)

    # dispatch scaling on the unified Runtime: the T·K routed keys as
    # divisible work, static expert partition vs adaptive stealing — the
    # imbalance adaptive absorbs is exactly routing skew
    flat = tokens * cfg.top_k
    cost = CostModel(per_item=1.0, split_overhead=4.0, steal_latency=2.0)
    for p in (4, 16):
        stat = simulate(WorkRange(0, flat), StaticPartitionPolicy(), p, cost,
                        seed=0)
        adap = simulate(WorkRange(0, flat), AdaptivePolicy(), p, cost, seed=0)
        emit(f"moe_dispatch/sim_p{p}/static", stat.makespan,
             f"speedup={stat.speedup_vs_serial:.2f}",
             p=p, speedup=stat.speedup_vs_serial)
        emit(f"moe_dispatch/sim_p{p}/adaptive", adap.makespan,
             f"speedup={adap.speedup_vs_serial:.2f} "
             f"tasks={adap.tasks_created}",
             p=p, speedup=adap.speedup_vs_serial,
             tasks=adap.tasks_created)

    # analytic dispatch overhead at production scale (per MoE layer)
    for arch in ("llama4-scout-17b-a16e", "deepseek-v2-lite-16b",
                 "jamba-1.5-large-398b"):
        c = get_config(arch)
        prod_tokens = 256 * 4096                 # train_4k micrototal
        g = 256
        G = prod_tokens // g
        C = capacity_per_group(g, c.num_experts, c.top_k, c.capacity_factor)
        dispatch_flops = 2 * G * g * c.num_experts * C * c.d_model * 2
        expert_flops = 2 * prod_tokens * c.top_k * 3 * c.d_model * \
            c.expert_d_ff
        emit(f"moe_dispatch/analytic/{arch}", 0.0,
             f"dispatch_gflops={dispatch_flops/1e9:.0f} "
             f"expert_gflops={expert_flops/1e9:.0f} "
             f"overhead={dispatch_flops/expert_flops:.2%}",
             dispatch_gflops=dispatch_flops / 1e9,
             expert_gflops=expert_flops / 1e9,
             overhead=dispatch_flops / expert_flops)
