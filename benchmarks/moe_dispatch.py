"""MoE dispatch: sort-based (the paper's stable sort) vs GShard einsum.

Wall time on host for a smoke-scale MoE layer, plus the analytic FLOP
overhead of the einsum dispatch at production scale — the quantity the sort
path eliminates (§Perf hillclimb evidence).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models.moe import capacity_per_group, moe_einsum, moe_init, \
    moe_sort_dispatch

from .common import emit, time_fn


def run() -> None:
    cfg = get_smoke_config("llama4-scout-17b-a16e")
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256, cfg.d_model)
                          ).astype(cfg.dtype())

    f_e = jax.jit(lambda p, x: moe_einsum(p, cfg, x)[0])
    f_s = jax.jit(lambda p, x: moe_sort_dispatch(p, cfg, x)[0])
    t_e = time_fn(lambda: f_e(params, x).block_until_ready(), iters=3)
    t_s = time_fn(lambda: f_s(params, x).block_until_ready(), iters=3)
    emit("moe_dispatch/einsum_smoke", t_e, "tokens=1024")
    emit("moe_dispatch/sort_smoke", t_s, f"ratio={t_s/t_e:.2f}")

    # analytic dispatch overhead at production scale (per MoE layer)
    for arch in ("llama4-scout-17b-a16e", "deepseek-v2-lite-16b",
                 "jamba-1.5-large-398b"):
        c = get_config(arch)
        tokens = 256 * 4096                      # train_4k micrototal
        g = 256
        G = tokens // g
        C = capacity_per_group(g, c.num_experts, c.top_k, c.capacity_factor)
        dispatch_flops = 2 * G * g * c.num_experts * C * c.d_model * 2
        expert_flops = 2 * tokens * c.top_k * 3 * c.d_model * c.expert_d_ff
        emit(f"moe_dispatch/analytic/{arch}", 0.0,
             f"dispatch_gflops={dispatch_flops/1e9:.0f} "
             f"expert_gflops={expert_flops/1e9:.0f} "
             f"overhead={dispatch_flops/expert_flops:.2%}")
