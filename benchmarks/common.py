"""Shared benchmark utilities: timing, CSV stdout rows, JSON trajectory files.

Every ``emit`` call both prints a ``name,us_per_call,derived`` CSV row and
records the row (plus any structured ``meta`` kwargs) in ``ROWS``;
``write_json`` flushes the accumulated rows of one benchmark module to
``results/bench/BENCH_<stem>.json`` so the perf trajectory is
machine-readable (the CI job archives the directory).
"""

from __future__ import annotations

import json
import os
import platform
import time
from pathlib import Path
from typing import Any, Callable, Dict, List

ROWS: List[Dict[str, Any]] = []

BENCH_DIR = Path(os.environ.get("BENCH_OUT", "results/bench"))


def emit(name: str, us_per_call: float, derived: str = "", **meta: Any) -> None:
    row: Dict[str, Any] = {"name": name, "us_per_call": float(us_per_call),
                           "derived": derived}
    if meta:
        row["meta"] = meta
    ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")


def reset() -> None:
    ROWS.clear()


def write_json(stem: str) -> Path:
    """Flush ``ROWS`` to ``results/bench/BENCH_<stem>.json`` and return the
    path.  Rows are left intact (callers reset between modules)."""
    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    path = BENCH_DIR / f"BENCH_{stem}.json"
    payload = {
        "benchmark": stem,
        "unix_time": time.time(),
        "host": {"platform": platform.platform(),
                 "python": platform.python_version()},
        "rows": ROWS,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def time_fn(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def header() -> None:
    print("name,us_per_call,derived")
